"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference + the WFA
single-RPC-vs-expression comparison from Fig. 3 (general expression vs fused
kernel doing the same update)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)
    P = jnp.asarray(rng.normal(size=(34, 130, 64)).astype(np.float32))

    us_ref = time_fn(jax.jit(
        lambda P: ref.affine_stencil_ref(P, 0.4, 0.1)), P)
    emit("stencil7_jnp_ref", us_ref, f"cells={32 * 128 * 64}")
    us_k = time_fn(lambda P: ops.stencil7(P, 0.4, 0.1), P)
    emit("stencil7_pallas_interpret", us_k,
         "note=interpret-mode(correctness-path);TPU target=mosaic")

    us_spmv = time_fn(lambda P: ops.spmv_hex_dot(P, 1.0, -0.0625), P)
    emit("spmv_fused_dot_pallas_interpret", us_spmv, "fused=Ap+p.Ap")

    # Fig. 3: general expression (2 temporaries) vs fused single pass
    def general(P):
        c = P[1:-1, 1:-1, :]
        s = ref.affine_stencil_ref(P, 0.0, 1.0)      # temp 1: neighbour sum
        t2 = 0.4 * c                                 # temp 2: scaled center
        return t2 + 0.1 * s

    us_gen = time_fn(jax.jit(general), P)
    us_fused = time_fn(jax.jit(
        lambda P: ref.affine_stencil_ref(P, 0.4, 0.1)), P)
    emit("fig3_general_expression", us_gen, "temporaries=2")
    emit("fig3_fused_kernel", us_fused,
         f"temporaries=0;speedup={us_gen / us_fused:.2f}")


if __name__ == "__main__":
    run()

"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference + the WFA
single-RPC-vs-expression comparison from Fig. 3 (general expression vs fused
kernel doing the same update)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)
    P = jnp.asarray(rng.normal(size=(34, 130, 64)).astype(np.float32))

    us_ref = time_fn(jax.jit(
        lambda P: ref.affine_stencil_ref(P, 0.4, 0.1)), P)
    emit("stencil7_jnp_ref", us_ref, f"cells={32 * 128 * 64}")
    us_k = time_fn(lambda P: ops.stencil7(P, 0.4, 0.1), P)
    emit("stencil7_pallas_interpret", us_k,
         "note=interpret-mode(correctness-path);TPU target=mosaic")

    us_spmv = time_fn(lambda P: ops.spmv_hex_dot(P, 1.0, -0.0625), P)
    emit("spmv_fused_dot_pallas_interpret", us_spmv, "fused=Ap+p.Ap")

    # Fig. 3: general expression (2 temporaries) vs fused single pass
    def general(P):
        c = P[1:-1, 1:-1, :]
        s = ref.affine_stencil_ref(P, 0.0, 1.0)      # temp 1: neighbour sum
        t2 = 0.4 * c                                 # temp 2: scaled center
        return t2 + 0.1 * s

    us_gen = time_fn(jax.jit(general), P)
    us_fused = time_fn(jax.jit(
        lambda P: ref.affine_stencil_ref(P, 0.4, 0.1)), P)
    emit("fig3_general_expression", us_gen, "temporaries=2")
    emit("fig3_fused_kernel", us_fused,
         f"temporaries=0;speedup={us_gen / us_fused:.2f}")

    frontend_compile()


def frontend_compile() -> None:
    """Fig. 3 loop through the frontend: interpreter-jit vs the program
    compiler (``backend="pallas"``), with kernel-launch accounting.

    The interpreter traces one roll per stencil term per iteration (7 HBM
    passes); the compiler emits one fused pallas_call per loop body.  On this
    CPU container the Pallas kernel runs in interpret mode, so wall time
    favours the jit interpreter — the number to watch is launches/terms per
    iteration (the WFA's fused-RPC count); Mosaic compilation on TPU turns
    that into wall time.
    """
    from benchmarks.common import KernelStatsSnapshot
    from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface

    n, steps, c = 24, 10, 0.1
    T0 = np.ones((n, n, n), np.float32) * 500.0
    T0[1:-1, 1:-1, 0] = 300.0
    T0[1:-1, 1:-1, -1] = 400.0

    def make_once(backend):
        wse = WSE_Interface()
        center = 1.0 - 6.0 * c
        T = WSE_Array("T_n", init_data=T0)
        with WSE_For_Loop("t", steps):
            T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
                T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
                + T[1:-1, 0, -1] + T[1:-1, -1, 0] + T[1:-1, 0, 1])
        return wse.make(answer=T, backend=backend)

    us_jit = time_fn(lambda: make_once("jit"))
    emit("frontend_fig3_interpreter_jit", us_jit,
         f"steps={steps};launches_per_iter=7(one-roll-per-tap)")
    snap = KernelStatsSnapshot()  # per-row deltas (cache is process-wide)
    us_pl = time_fn(lambda: make_once("pallas"))
    emit("frontend_fig3_pallas_compiler", us_pl,
         f"steps={steps};{snap.derived()};launches_per_iter=1;"
         "note=interpret-mode-wall-time(TPU target=mosaic)")


if __name__ == "__main__":
    run()

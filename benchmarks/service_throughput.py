"""Serving-layer throughput: request rate, tail latency, cache hits.

Boots a :class:`repro.service.SimulationService` with a warmed 3-signature
manifest and replays a concurrent mixed stream, reporting requests/sec,
p50/p95 request latency and the plan-cache hit rate — the serving analogue
of the per-kernel rows: after warm-up the stream must run with zero kernel
compiles (``fallbacks=0`` keeps the CI gate honest).  A second row replays
a burst on one signature (the scheduler's signature-grouping fast path),
and a third runs the fault drill: an injected mid-flight fault served
through checkpoint restore-and-continue, reported by its retry/restore
counts rather than its wall time.
"""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import KernelStatsSnapshot, emit

SHAPE = (24, 24, 6)
STEPS = 24


def _signatures():
    from repro.service import PlanSignature

    nx, ny, nz = SHAPE
    return [
        PlanSignature("heat3d", (nx, ny, nz)),
        PlanSignature("advdiff", (nx - 4, ny - 4, nz)),
        PlanSignature("jacobi3d", (nx - 8, ny - 8, nz), time_tile=2),
    ]


def _drain(tickets):
    return [t.result(timeout=600) for t in tickets]


def _latency_ms(tickets, q: float) -> float:
    lat = sorted(t.stats.latency_s for t in tickets)
    return lat[min(len(lat) - 1, int(q * len(lat)))] * 1e3


def _stream(svc, sigs, n):
    from repro.service import StepRequest

    t0 = time.perf_counter()
    tickets = [
        svc.submit(StepRequest(sigs[i % len(sigs)], steps=STEPS))
        for i in range(n)
    ]
    _drain(tickets)
    return tickets, time.perf_counter() - t0


def run() -> None:
    from repro.engine import reset_stats
    from repro.engine.stats import stats as estats
    from repro.runtime.fault import FaultInjector
    from repro.service import SimulationService, StepRequest

    reset_stats()
    whole_run = KernelStatsSnapshot()
    sigs = _signatures()
    ckpt_root = tempfile.mkdtemp(prefix="repro-bench-service-")
    svc = SimulationService(
        workers=4, capacity=1024, manifest=sigs, ckpt_root=ckpt_root,
        default_chunk=STEPS // 3,
    ).start()
    try:
        snap = KernelStatsSnapshot()
        n = 48
        tickets, dt = _stream(svc, sigs, n)
        hits = sum(t.stats.plan_cache_hit for t in tickets)
        emit(
            "service_mixed48",
            dt / n * 1e6,
            f"rps={n / dt:.1f};p50_ms={_latency_ms(tickets, 0.50):.1f};"
            f"p95_ms={_latency_ms(tickets, 0.95):.1f};"
            f"plan_cache_hit_rate={hits / n:.2f};"
            f"signatures={len(sigs)};steps={STEPS};" + snap.derived(),
        )

        snap = KernelStatsSnapshot()
        tickets, dt = _stream(svc, sigs[:1], n)
        emit(
            "service_burst_single_sig",
            dt / n * 1e6,
            f"rps={n / dt:.1f};p50_ms={_latency_ms(tickets, 0.50):.1f};"
            f"p95_ms={_latency_ms(tickets, 0.95):.1f};" + snap.derived(),
        )

        snap = KernelStatsSnapshot()
        req = StepRequest(sigs[0], steps=STEPS, ckpt_every=STEPS // 3)
        t0 = time.perf_counter()
        with FaultInjector(
            fail_at=[2 * (STEPS // 3)], match_tag=req.request_id
        ):
            ticket = svc.submit(req)
            ticket.result(timeout=600)
        dt = time.perf_counter() - t0
        st = ticket.stats
        emit(
            "service_fault_restore",
            dt * 1e6,
            f"retries={st.retries};restores={st.restores};"
            f"checkpoints={st.checkpoints};degraded={int(st.degraded)};"
            + snap.derived(),
        )
    finally:
        svc.stop()
    # the serving-tier counters end-to-end (requests_completed covers all
    # three rows; mean queue wait is the scheduler's contribution)
    emit(
        "service_counters",
        0.0,
        f"completed={estats.requests_completed};"
        f"retries={estats.request_retries};"
        f"restores={estats.service_restores};"
        f"mean_queue_wait_ms="
        f"{estats.queue_wait_s / max(1, estats.requests_admitted) * 1e3:.1f};"
        + whole_run.derived(),
    )

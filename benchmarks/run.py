"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  * explicit_scaling    — Fig. 4a / Eq. 6 / Eqs. 4–5
  * implicit_scaling    — Fig. 4b / Eq. 16 / Eqs. 13–15 / §3.2.2 ratio
  * implicit_solve      — wfa.solve: compiled operator + Krylov loop
  * mg_poisson          — solver convergence: mg vs CG/BiCGSTAB, 3 sizes
  * time_tiling         — engine temporal blocking: k steps per exchange
  * reduction           — Eq. 17 / §3.2.2 dot-product analysis
  * distributed_model   — Table 1 / Table 2 / Eq. 12 / §5 headline speedups
  * kernels_bench       — Fig. 3 fused-RPC comparison + Pallas kernels
  * service_throughput  — serving layer: requests/sec, tail latency,
                          cache-hit rate, fault restore-and-continue
  * ensemble_throughput — batched ensemble execution: members/sec at
                          micro-batch widths 1/8/64 (gates the B=64 ≥ 5×
                          speedup and zero steady-state compiles)
  * adjoint_inverse     — differentiable solves: gradient/forward cost
                          ratio via the IFT adjoint (symmetric CG reuses
                          the forward kernel; BiCGSTAB row is the
                          inverse-diffusivity misfit gradient)
  * health_overhead     — explicit-path sentinel cost: guarded
                          (``check_finite=N``) vs unguarded steady-state
                          stepping, interleaved best-of (gates ≤2%)

Usage::

    python benchmarks/run.py [--json OUT.json] [--warmup N] [--repeats N]
                             [--check-fallbacks] [case ...]

``--json`` additionally writes the emitted rows as a JSON document — the
perf-trajectory artifact CI uploads per PR.  ``--warmup``/``--repeats``
override the harness-wide timing counts (rows report *best-of* over the
repeats — see :mod:`benchmarks.common` for why the median was retired).
``--check-fallbacks`` exits nonzero if any emitted row reports interpreter
fallbacks — the CI smoke gate keeping every pallas case on the fused path.
``--check-tiling`` exits nonzero if the time_tiling case's steady-state k=2
or k=4 row is slower than its k=1 row — temporal blocking must never lose
to untiled stepping (the cost model guarantees it by construction for
model-driven picks; this gates the measured reality).
``--check-health`` exits nonzero if any ``health_guard_on`` row reports
more than 2% per-step overhead against its unguarded baseline — arming the
explicit-path sentinel must stay effectively free at the chunk granule.
"""

from __future__ import annotations

import argparse
import json
import platform
import re
import sys


def main() -> None:
    from benchmarks import (
        adjoint_inverse,
        common,
        distributed_model,
        ensemble_throughput,
        explicit_scaling,
        health_overhead,
        implicit_scaling,
        implicit_solve,
        kernels_bench,
        mg_poisson,
        reduction,
        service_throughput,
        time_tiling,
    )
    from benchmarks.common import RESULTS

    mods = {
        "explicit_scaling": explicit_scaling,
        "implicit_scaling": implicit_scaling,
        "implicit_solve": implicit_solve,
        "mg_poisson": mg_poisson,
        "time_tiling": time_tiling,
        "reduction": reduction,
        "distributed_model": distributed_model,
        "kernels_bench": kernels_bench,
        "service_throughput": service_throughput,
        "ensemble_throughput": ensemble_throughput,
        "adjoint_inverse": adjoint_inverse,
        "health_overhead": health_overhead,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--json", metavar="PATH", default=None, help="also write emitted rows as JSON"
    )
    ap.add_argument(
        "--warmup",
        type=int,
        default=None,
        metavar="N",
        help="untimed calls before timing each row",
    )
    ap.add_argument(
        "--repeats",
        type=int,
        default=None,
        metavar="N",
        help="timed calls per row (best-of reported)",
    )
    ap.add_argument(
        "--check-fallbacks",
        action="store_true",
        help="fail if any row reports interpreter fallbacks",
    )
    ap.add_argument(
        "--check-tiling",
        action="store_true",
        help="fail if time_tiling k=2/k=4 rows lose to k=1",
    )
    ap.add_argument(
        "--check-health",
        action="store_true",
        help="fail if any health_guard_on row exceeds 2% overhead",
    )
    ap.add_argument(
        "cases",
        nargs="*",
        metavar="case",
        help=f"benchmark cases to run (default: all of {list(mods)})",
    )
    args = ap.parse_args()
    unknown = [c for c in args.cases if c not in mods]
    if unknown:
        ap.error(f"unknown case(s) {unknown}; choose from {list(mods)}")
    if args.warmup is not None and args.warmup < 0:
        ap.error("--warmup must be >= 0")
    if args.repeats is not None and args.repeats < 1:
        ap.error("--repeats must be >= 1")
    common.configure(warmup=args.warmup, repeats=args.repeats)

    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if args.cases and name not in args.cases:
            continue
        print(f"# --- {name} ---")
        mod.run()

    if args.json:
        import jax

        doc = {
            "cases": args.cases or list(mods),
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(RESULTS)} rows to {args.json}")

    # gate AFTER the JSON dump: a fallback regression must still leave the
    # per-row artifact behind — it is what diagnoses which case fell back
    if args.check_fallbacks:
        from repro.compiler import stats as compiler_stats

        bad = [
            r
            for r in RESULTS
            for m in [re.search(r"fallbacks=(\d+)", str(r["derived"]))]
            if m and int(m.group(1)) > 0
        ]
        for r in bad:
            print(f"# FALLBACKS in {r['name']}: {r['derived']}", file=sys.stderr)
        # rows without a fallbacks= field still count via the process-wide
        # compiler counter, so un-instrumented cases cannot regress silently
        if compiler_stats.fallbacks > 0 and not bad:
            print(
                f"# FALLBACKS: {compiler_stats.fallbacks} across the run "
                f"(reasons: {compiler_stats.fallback_reasons[-3:]})",
                file=sys.stderr,
            )
        if bad or compiler_stats.fallbacks > 0:
            sys.exit(1)
        print("# fallbacks=0 in every instrumented row and process-wide")

    if args.check_tiling:
        rows = {r["name"]: float(r["us_per_call"]) for r in RESULTS}
        base = rows.get("time_tiling_k1")
        if base is None:
            print("# --check-tiling: no time_tiling_k1 row emitted", file=sys.stderr)
            sys.exit(1)
        losers = [
            (n, rows[n])
            for n in ("time_tiling_k2", "time_tiling_k4")
            if n in rows and rows[n] > base
        ]
        for n, us in losers:
            print(
                f"# TILING REGRESSION: {n}={us:.2f}us/step > k1={base:.2f}us/step",
                file=sys.stderr,
            )
        if losers:
            sys.exit(1)
        print(f"# tiling holds: k2/k4 <= k1 ({base:.2f}us/step)")

    if args.check_health:
        over = [
            (r["name"], float(m.group(1)))
            for r in RESULTS
            if str(r["name"]).startswith("health_guard_on")
            for m in [re.search(r"overhead_pct=(-?[\d.]+)", str(r["derived"]))]
            if m and float(m.group(1)) > 2.0
        ]
        rows = [r for r in RESULTS if str(r["name"]).startswith("health_guard_on")]
        if not rows:
            print("# --check-health: no health_guard_on row emitted", file=sys.stderr)
            sys.exit(1)
        for n, pct in over:
            print(
                f"# SENTINEL OVERHEAD: {n} costs {pct:.2f}% > 2% budget",
                file=sys.stderr,
            )
        if over:
            sys.exit(1)
        print(f"# sentinel budget holds: {len(rows)} guarded rows <= 2%")


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  * explicit_scaling    — Fig. 4a / Eq. 6 / Eqs. 4–5
  * implicit_scaling    — Fig. 4b / Eq. 16 / Eqs. 13–15 / §3.2.2 ratio
  * reduction           — Eq. 17 / §3.2.2 dot-product analysis
  * distributed_model   — Table 1 / Table 2 / Eq. 12 / §5 headline speedups
  * kernels_bench       — Fig. 3 fused-RPC comparison + Pallas kernels
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (distributed_model, explicit_scaling,
                            implicit_scaling, kernels_bench, reduction)
    print("name,us_per_call,derived")
    mods = {
        "explicit_scaling": explicit_scaling,
        "implicit_scaling": implicit_scaling,
        "reduction": reduction,
        "distributed_model": distributed_model,
        "kernels_bench": kernels_bench,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, mod in mods.items():
        if only and only != name:
            continue
        print(f"# --- {name} ---")
        mod.run()


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  * explicit_scaling    — Fig. 4a / Eq. 6 / Eqs. 4–5
  * implicit_scaling    — Fig. 4b / Eq. 16 / Eqs. 13–15 / §3.2.2 ratio
  * implicit_solve      — wfa.solve: compiled operator + Krylov loop
  * mg_poisson          — solver convergence: mg vs CG/BiCGSTAB, 3 sizes
  * time_tiling         — engine temporal blocking: k steps per exchange
  * reduction           — Eq. 17 / §3.2.2 dot-product analysis
  * distributed_model   — Table 1 / Table 2 / Eq. 12 / §5 headline speedups
  * kernels_bench       — Fig. 3 fused-RPC comparison + Pallas kernels

Usage::

    python benchmarks/run.py [--json OUT.json] [case ...]

``--json`` additionally writes the emitted rows as a JSON document — the
perf-trajectory artifact CI uploads per PR.
"""
from __future__ import annotations

import argparse
import json
import platform


def main() -> None:
    from benchmarks import (distributed_model, explicit_scaling,
                            implicit_scaling, implicit_solve, kernels_bench,
                            mg_poisson, reduction, time_tiling)
    from benchmarks.common import RESULTS

    mods = {
        "explicit_scaling": explicit_scaling,
        "implicit_scaling": implicit_scaling,
        "implicit_solve": implicit_solve,
        "mg_poisson": mg_poisson,
        "time_tiling": time_tiling,
        "reduction": reduction,
        "distributed_model": distributed_model,
        "kernels_bench": kernels_bench,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write emitted rows as JSON")
    ap.add_argument("cases", nargs="*", metavar="case",
                    help=f"benchmark cases to run (default: all of {list(mods)})")
    args = ap.parse_args()
    unknown = [c for c in args.cases if c not in mods]
    if unknown:
        ap.error(f"unknown case(s) {unknown}; choose from {list(mods)}")

    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if args.cases and name not in args.cases:
            continue
        print(f"# --- {name} ---")
        mod.run()

    if args.json:
        import jax
        doc = {
            "cases": args.cases or list(mods),
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(RESULTS)} rows to {args.json}")


if __name__ == "__main__":
    main()

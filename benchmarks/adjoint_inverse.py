"""``adjoint_inverse`` — forward-vs-gradient cost of differentiable solves.

The IFT adjoint's promise is a fixed price: one gradient through
``wfa.solve`` costs roughly one extra (transposed) Krylov solve, however
many parameters receive gradients.  This case times the forward solve and
the full VJP side by side and reports the ratio — for the symmetric CG
operator (where the adjoint reuses the forward kernel; the ``derived``
column pins ``adjoint_kernels=0`` built during the backward pass) and for
the non-symmetric variable-coefficient BiCGSTAB operator, whose gradient
row *is* the inverse-problem gradient: a sparse-observation misfit
differentiated with respect to the per-cell diffusivity
(``examples/inverse_diffusivity.py`` runs the full recovery loop).

Before timing anything the gradient is smoke-checked against central
differences with the shared test harness (``tests/gradcheck.py``) at
fp32-appropriate tolerances — a benchmark of a wrong gradient would be
worse than no benchmark.
"""

from __future__ import annotations

import os
import sys

import numpy as np

from benchmarks.common import KernelStatsSnapshot, emit, time_fn

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SHAPE = (24, 24, 12)
TOL = 1e-6


def _gradcheck_smoke(loss, x0, grad):
    """FD smoke check via the shared harness; fp32 central differences
    carry ~1e-4 cancellation noise, hence the loose scales (the fp64
    precision claims live in tests/test_adjoint.py's subprocess tests)."""
    sys.path.insert(0, os.path.join(ROOT, "tests"))
    from gradcheck import assert_gradcheck

    return assert_gradcheck(
        loss, x0, grad, eps=1e-2, atol=2e-2, rtol=1e-1, n_probes=4
    )


def run() -> None:
    import jax
    import jax.numpy as jnp

    from repro.solver import make_differentiable_solver
    from repro.solver.presets import btcs_program, record_varcoef_btcs

    rng = np.random.default_rng(11)
    x0 = np.zeros(SHAPE, np.float32)
    x0[1:-1, 1:-1, 1:-1] = 1.0
    x0 += 0.1 * rng.random(SHAPE, dtype=np.float32)

    # --- symmetric (CG): the adjoint solve reuses the forward kernel ---
    snap = KernelStatsSnapshot()
    solve = make_differentiable_solver(
        btcs_program(SHAPE, 0.3), "T", method="cg", tol=TOL, maxiter=500
    )
    assert solve.symmetric_adjoint
    fwd = jax.jit(solve)
    loss = jax.jit(lambda v: jnp.sum(solve(v) ** 2))
    grad = jax.jit(jax.grad(loss))
    g = np.asarray(grad(x0))  # compiles; any kernel work lands pre-snapshot
    report = _gradcheck_smoke(loss, x0, g)
    us_fwd = time_fn(fwd, x0)
    emit(f"adjoint_forward_cg_n{SHAPE[0]}", us_fwd, snap.derived())
    during_grad = KernelStatsSnapshot()
    us_grad = time_fn(grad, x0)
    emit(
        f"adjoint_grad_cg_n{SHAPE[0]}",
        us_grad,
        f"grad_over_forward={us_grad / us_fwd:.2f};"
        f"adjoint_kernels={during_grad._stats.kernels_built - during_grad.built};"
        f"gradcheck_maxerr={report.max_scaled_err:.3g};"
        f"fallbacks={during_grad._stats.fallbacks - during_grad.fallbacks}",
    )

    # --- non-symmetric (BiCGSTAB): inverse-problem gradient w.r.t. κ ---
    C0 = (0.4 + 0.2 * rng.random(SHAPE)).astype(np.float32)
    snap = KernelStatsSnapshot()
    wse, _, _ = record_varcoef_btcs(x0.astype(np.float32), C0, 0.3)
    vsolve = make_differentiable_solver(
        wse.program, "T", method="bicgstab", tol=TOL, maxiter=500
    )
    obs = np.zeros(SHAPE, bool)
    obs[1:-1, 1:-1, 1:-1] = rng.random(tuple(n - 2 for n in SHAPE)) < 0.25
    idx = tuple(np.argwhere(obs).T)
    y = np.asarray(vsolve(x0, {"T_coef": C0}))[idx] * 1.05  # synthetic data

    vfwd = jax.jit(lambda k: vsolve(x0, {"T_coef": k}))
    misfit = jax.jit(lambda k: jnp.sum((vsolve(x0, {"T_coef": k})[idx] - y) ** 2))
    vgrad = jax.jit(jax.grad(misfit))
    gk = np.asarray(vgrad(C0))
    report = _gradcheck_smoke(misfit, C0, gk)
    us_fwd = time_fn(vfwd, C0)
    emit(f"adjoint_forward_bicgstab_n{SHAPE[0]}", us_fwd, snap.derived())
    during_grad = KernelStatsSnapshot()
    us_grad = time_fn(vgrad, C0)
    emit(
        f"adjoint_inverse_grad_bicgstab_n{SHAPE[0]}",
        us_grad,
        f"grad_over_forward={us_grad / us_fwd:.2f};"
        f"adjoint_kernels={during_grad._stats.kernels_built - during_grad.built};"
        f"observations={int(obs.sum())};"
        f"gradcheck_maxerr={report.max_scaled_err:.3g};"
        f"fallbacks={during_grad._stats.fallbacks - during_grad.fallbacks}",
    )


if __name__ == "__main__":
    run()

"""``wfa.solve`` benchmark: compiled operator application + Krylov loop.

Times one reusable jitted solver step (``repro.solver.make_solver``) per
method at a fixed inner-iteration budget, for the BTCS heat system and the
variable-coefficient (non-symmetric, BiCGSTAB) system.  The derived column
records the fused-kernel accounting — launches per operator application is
the WFA's fused-RPC count; on this CPU container the kernels execute in
Pallas interpret mode, so the number to watch is the accounting, not wall
time (Mosaic compilation on TPU turns it into wall time).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn

ITERS = 25
N = 32


def run() -> None:
    from benchmarks.common import KernelStatsSnapshot
    from repro.configs.heat3d import HeatConfig, make_field
    from repro.solver import btcs_program, make_solver
    from repro.solver.presets import record_varcoef_btcs

    shape = (N, N, N)
    T0 = make_field(HeatConfig(nx=N, ny=N, nz=N))

    for method in ("cg", "pipecg", "bicgstab", "chebyshev", "jacobi"):
        snap = KernelStatsSnapshot()  # per-row deltas (cache is process-wide)
        prog = btcs_program(shape, 0.1, init_data=T0)
        step = make_solver(
            prog, "T", method=method, backend="pallas", tol=0.0, maxiter=ITERS
        )
        us = time_fn(lambda T: step(T)[0], T0)
        emit(
            f"wfa_solve_{method}_inner_iter",
            us / ITERS,
            f"cells={N ** 3};{snap.derived()};launches_per_apply=1",
        )

    # variable-coefficient (non-symmetric) system — BiCGSTAB workhorse
    rng = np.random.default_rng(0)
    C0 = rng.uniform(0.05, 0.3, size=shape).astype(np.float32)
    snap = KernelStatsSnapshot()
    wse, T, C = record_varcoef_btcs(T0, C0, 0.1)
    step = make_solver(
        wse.program, "T", method="bicgstab", backend="pallas", tol=0.0, maxiter=ITERS
    )
    us = time_fn(lambda Ti: step(Ti)[0], T0)
    emit(
        "wfa_solve_varcoef_bicgstab_inner_iter",
        us / ITERS,
        f"cells={N ** 3};{snap.derived()};note=two-tap-products-fused",
    )


if __name__ == "__main__":
    run()

"""Paper Tables 1–2 + Eqs. 4–5, 12–15: the distributed-computing side.

Reproduces the paper's OpenFOAM/Joule-2.0 fit values at the exact Table 1
operating points, the Table 2 GPU upper-bound survey via Eq. 12, and the
headline speedup claims (470× explicit, ≥87× CG).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.perfmodel import (gpu_max_rate, openfoam_explicit_rate,
                                  openfoam_implicit_rate, wse_explicit_rate,
                                  wse_implicit_rate)

# Table 2 rows: (study, subdomain width, W, processor, mem bw GB/s, paper R)
TABLE2 = [
    ("pfister", 300, 3.28e7, "V100", 900, 4167),
    ("rass_p100", 383, 5.62e7, "P100", 732, 1557),
    ("rass_v100", 512, 1.34e8, "V100", 900, 838),
    ("rass_a100", 512, 1.34e8, "A100", 2000, 1863),
    ("xue_p100", 256, 1.68e7, "P100", 732, 5215),
    ("xue_v100", 256, 1.68e7, "V100", 900, 6706),
    ("pearson", 750, 4.22e8, "V100", 900, 267),
]


def run() -> None:
    # Table 1: explicit fits at the fastest/slowest operating points
    for name, w, cells, paper_rate in [
            ("t1_w4096_fast", 4096, 1.31e6, 13862),
            ("t1_w4096_slow", 4096, 4.01e7, 3535),
            ("t1_w15625_fast", 15625, 5.00e6, 4263),
            ("t1_w15625_slow", 15625, 1.51e8, 2027)]:
        fit = openfoam_explicit_rate(w, cells)
        emit(f"openfoam_{name}", 0.0,
             f"fit_it_s={fit:.0f};paper_it_s={paper_rate};"
             f"rel_err={abs(fit - paper_rate) / paper_rate:.2%}")

    # Table 2: Eq. 12 maximum possible GPU iteration rates
    for name, width, w, gpu, bw, paper_r in TABLE2:
        r = gpu_max_rate(w, bw * 1e9)
        emit(f"gpu_bound_{name}", 0.0,
             f"W={w:.2e};eq12_it_s={r:.0f};paper_it_s={paper_r};"
             f"rel_err={abs(r - paper_r) / paper_r:.2%}")

    # headline speedups (§5): WSE vs OpenFOAM at matched conditions
    w_wse = 50                                   # WSE strong-scaled workload
    r_wse = wse_explicit_rate(w_wse)
    r_of = openfoam_explicit_rate(4096, 4.01e7)  # large-scale Joule point
    emit("headline_explicit_speedup", 0.0,
         f"wse_it_s={r_wse:.0f};joule_it_s={r_of:.0f};"
         f"speedup={r_wse / r_of:.0f}x;paper_claims=470x")

    r_wse_cg = wse_implicit_rate(1000, 750, 950)
    r_of_cg = openfoam_implicit_rate(27000, 1.57e8)
    emit("headline_implicit_speedup", 0.0,
         f"wse_it_s={r_wse_cg:.0f};joule_it_s={r_of_cg:.0f};"
         f"speedup={r_wse_cg / r_of_cg:.0f}x;paper_claims>=87x")


if __name__ == "__main__":
    run()

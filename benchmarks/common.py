"""Benchmark utilities: timing + CSV emission (``name,us_per_call,derived``).

Every :func:`emit` row is also recorded in :data:`RESULTS` so the harness
(``benchmarks/run.py``) can dump a machine-readable JSON artifact — the
per-PR perf trajectory CI uploads.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

#: rows recorded by emit(): {"name", "us_per_call", "derived"}
RESULTS: List[Dict[str, object]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append(
        {"name": name, "us_per_call": round(us_per_call, 2),
         "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}")

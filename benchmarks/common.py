"""Benchmark utilities: timing + CSV emission (``name,us_per_call,derived``).

Every :func:`emit` row is also recorded in :data:`RESULTS` so the harness
(``benchmarks/run.py``) can dump a machine-readable JSON artifact — the
per-PR perf trajectory CI uploads.

Timing reports **best-of** (the minimum over ``repeats`` timed calls after
``warmup`` untimed ones): this container's wall-clock noise is 2–3× between
seconds, and a median over 3 calls recorded several artifact rows in past
trajectories (e.g. the BENCH_mg ``mg_pcg_n33`` outlier).  The minimum is the
closest observable to the machine's actual cost.  Defaults come from
:data:`WARMUP`/:data:`REPEATS`; ``run.py --warmup/--repeats`` overrides them
harness-wide via :func:`configure`.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax

#: rows recorded by emit(): {"name", "us_per_call", "derived"}
RESULTS: List[Dict[str, object]] = []

#: harness-wide timing defaults (overridden by ``run.py --warmup/--repeats``)
WARMUP = 2
REPEATS = 5


def configure(warmup: Optional[int] = None, repeats: Optional[int] = None):
    """Set the harness-wide warmup/repeat counts (``run.py`` CLI hook)."""
    global WARMUP, REPEATS
    if warmup is not None:
        WARMUP = int(warmup)
    if repeats is not None:
        REPEATS = int(repeats)


def resolved(warmup: Optional[int] = None, iters: Optional[int] = None) -> tuple:
    """(warmup, iters) with harness defaults filled in — exposed so cases
    that derive per-run statistics (e.g. tiles fused per run) can divide by
    the true number of executions."""
    return (
        WARMUP if warmup is None else warmup,
        REPEATS if iters is None else iters,
    )


def time_fn(
    fn: Callable, *args, warmup: Optional[int] = None, iters: Optional[int] = None
) -> float:
    """Best-of wall-time per call in microseconds (blocks on results)."""
    warmup, iters = resolved(warmup, iters)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append(
        {"name": name, "us_per_call": round(us_per_call, 2), "derived": derived}
    )
    print(f"{name},{us_per_call:.2f},{derived}")


class KernelStatsSnapshot:
    """Per-row delta view of the compiler's cumulative kernel counters.

    The kernel cache outlives ``reset_stats()`` — a case that re-records a
    program another case already compiled is served as cache *hits* with
    zero new builds, so reporting the cumulative ``kernels_built`` makes
    later rows claim ``fused_kernels=0`` (the BENCH_mg artifact did exactly
    that).  Snapshot before the case, read deltas after::

        snap = KernelStatsSnapshot()
        ...  # build + run the case
        row = snap.derived()   # "fused_kernels=N;kernel_hits=M;fallbacks=F"

    Engine-side overlap counters (interior/boundary launches, overlapped
    exchanges, cost-model hits and calibrations) ride along the same way,
    appended only when any moved — rows from cases that never split keep
    their historical shape.
    """

    _OVERLAP = (
        "interior_launches",
        "boundary_launches",
        "overlapped_exchanges",
        "cost_model_hits",
        "calibrations",
    )

    def __init__(self):
        from repro.compiler import stats
        from repro.engine import stats as engine_stats

        self._stats = stats
        self._engine = engine_stats
        self.built = stats.kernels_built
        self.hits = stats.cache_hits
        self.fallbacks = stats.fallbacks
        self.overlap = {n: getattr(engine_stats, n) for n in self._OVERLAP}

    def derived(self) -> str:
        s = self._stats
        out = (
            f"fused_kernels={s.kernels_built - self.built};"
            f"kernel_hits={s.cache_hits - self.hits};"
            f"fallbacks={s.fallbacks - self.fallbacks}"
        )
        # engine counters reset with reset_stats(); a benchmark that resets
        # mid-row reads deltas from zero, which is still the row's own count
        deltas = {
            n: getattr(self._engine, n)
            - min(self.overlap[n], getattr(self._engine, n))
            for n in self._OVERLAP
        }
        if any(deltas.values()):
            out += "".join(f";{n}={v}" for n, v in deltas.items())
        return out

"""Ensemble throughput: members/sec vs batch width through the service.

The batched-execution PR's headline measurement: 64 identical scenario
requests served at micro-batch widths B ∈ {1, 8, 64}.  At B=1 every member
pays the full per-request cost (dispatch, env build, finalize, ticket
bookkeeping) around a tiny stencil workload; coalescing B members into one
batched plan pays those costs once per *launch*, so members/sec must rise
steeply — the acceptance gate requires **B=64 ≥ 5× B=1** on this container.

Compile discipline is gated too: the three batch widths are three plan
signatures, each warmed exactly once from the manifest; the measured
streams must then run with **zero** new fused-kernel compiles and zero
interpreter fallbacks (``fallbacks=0`` keeps the CI smoke honest).
"""

from __future__ import annotations

import time

from benchmarks.common import KernelStatsSnapshot, emit

SHAPE = (8, 8, 4)
STEPS = 8
TOTAL = 64  # members per measured stream, at every width
WIDTHS = (1, 8, 64)
REPEATS = 3
SPEEDUP_GATE = 5.0


def _stream(svc, sig, n):
    from repro.service import StepRequest

    tickets = [svc.submit(StepRequest(sig, steps=STEPS)) for _ in range(n)]
    for t in tickets:
        t.result(timeout=600)
    return tickets


def _measure(width: int) -> tuple:
    """Best-of members/sec serving TOTAL members at micro-batch ``width``."""
    from repro.service import PlanSignature, SimulationService

    sig = PlanSignature("heat3d", SHAPE)
    warm_sig = sig if width == 1 else PlanSignature("heat3d", SHAPE, batch=width)
    build = KernelStatsSnapshot()
    svc = SimulationService(
        workers=1,
        capacity=4 * TOTAL,
        group_max=max(16, width),
        micro_batch=width,
        manifest=[warm_sig],
    ).start()
    try:
        _stream(svc, sig, TOTAL)  # warm-up stream (jit executables get hot)
        compiles = KernelStatsSnapshot()
        best, widths = 0.0, set()
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            tickets = _stream(svc, sig, TOTAL)
            best = max(best, TOTAL / (time.perf_counter() - t0))
            widths.update(t.stats.batch for t in tickets)
    finally:
        svc.stop()
    return best, max(widths), build, compiles


def run() -> None:
    rates = {}
    for width in WIDTHS:
        rate, served_width, build, compiles = _measure(width)
        rates[width] = rate
        built = compiles._stats.kernels_built - compiles.built
        if built != 0:
            raise RuntimeError(
                f"width {width}: {built} fused-kernel compiles during the "
                "measured stream — the warmed signature must cover it"
            )
        emit(
            f"ensemble_b{width}",
            1e6 / rate,  # us per member
            f"members_per_s={rate:.1f};members={TOTAL};steps={STEPS};"
            f"served_width={served_width};"
            f"stream_compiles={built};" + build.derived(),
        )
    speedup = rates[64] / rates[1]
    if speedup < SPEEDUP_GATE:
        raise RuntimeError(
            f"ensemble speedup gate failed: B=64 is {speedup:.2f}x B=1 "
            f"(gate {SPEEDUP_GATE}x)"
        )
    emit(
        "ensemble_speedup",
        0.0,
        f"b64_vs_b1={speedup:.2f}x;b8_vs_b1={rates[8] / rates[1]:.2f}x;"
        f"gate={SPEEDUP_GATE}x",
    )


if __name__ == "__main__":
    run()

"""Paper Eq. 17 + §3.2.2 reduction analysis: dot products and fused duals.

Measures the host cost of the CG reductions (separate vs fused dual-dot vs
the Pallas fused kernel) and evaluates the paper's latency models against
the distributed-computing numbers it cites (MVAPICH 15–35 µs at 1024 nodes,
GPU >100 µs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.perfmodel import (TPU_V5E_ICI_LAT, wse_dot_time)
from repro.kernels import ops


def run() -> None:
    rng = np.random.default_rng(0)
    shape = (64, 128, 64)
    a, b, c, d = [jnp.asarray(rng.normal(size=shape).astype(np.float32))
                  for _ in range(4)]

    two = jax.jit(lambda a, b, c, d: (jnp.sum(a * b), jnp.sum(c * d)))
    us2 = time_fn(two, a, b, c, d)
    emit("dot_two_separate", us2, f"elems={a.size}")

    fused = jax.jit(lambda a, b, c, d: jnp.stack(
        [jnp.sum(a * b), jnp.sum(c * d)]))
    usf = time_fn(fused, a, b, c, d)
    emit("dot_fused_dual", usf, f"speedup_vs_separate={us2 / usf:.2f}")

    usk = time_fn(lambda *xs: ops.dual_dot(*xs), a, b, c, d)
    emit("dot_pallas_dual(interpret)", usk, "validated_vs_ref=tests")

    # Eq. 17: the paper's 3.25 µs full-fabric dot vs distributed baselines
    t = wse_dot_time(1000, 750, 950) * 1e6
    emit("wse_dot_model", t,
         "mvapich_1024node_us=15-35;gpu_allreduce_us>100;paper_us=3.25")

    # TPU analogue: psum latency is hop-latency × mesh diameter
    for mesh_xy in [(16, 16), (32, 16)]:
        hops = 2 * (mesh_xy[0] + mesh_xy[1])
        emit(f"tpu_psum_latency_model_{mesh_xy[0]}x{mesh_xy[1]}",
             hops * TPU_V5E_ICI_LAT * 1e6,
             f"diameter_hops={hops};per_hop_us={TPU_V5E_ICI_LAT * 1e6:.1f}")


if __name__ == "__main__":
    run()

"""``mg_poisson`` — the solver-convergence trajectory benchmark.

The first BENCH case that tracks *iterations to tolerance*, not just wall
time per call: Krylov methods on elliptic systems need more iterations as
the grid grows (the ceiling the paper's implicit runs share with Rocki et
al.), while geometric multigrid stays flat.  For each grid size the
Dirichlet Poisson system is solved end-to-end (compiled operator + full
iteration loop, one jitted call) with plain CG, BiCGSTAB, standalone mg
V-cycles, and mg-preconditioned CG.

The RHS is normalised to unit norm so the Krylov methods' absolute ``tol``
and mg's relative reduction agree at ``1e-5`` — iteration counts are
directly comparable.  The derived column records iterations, hierarchy
depth, and the fused-kernel accounting; on this CPU container kernels run
in Pallas interpret mode, so the headline trend is the mg-vs-CG *iteration
and wall-time ratio*, not the absolute microseconds.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import KernelStatsSnapshot, emit, time_fn

SIZES = (17, 33, 65)
TOL = 1e-5


def _rhs(shape):
    rng = np.random.default_rng(7)
    F = np.zeros(shape, np.float32)
    F[1:-1, 1:-1, 1:-1] = rng.normal(size=tuple(n - 2 for n in shape)).astype(
        np.float32
    )
    return F / np.linalg.norm(F)


def run() -> None:
    from repro.engine import reset_stats as engine_reset
    from repro.engine import stats as engine_stats
    from repro.solver import make_solver, poisson_program

    cases = [
        ("cg", dict(method="cg", maxiter=2000)),
        ("bicgstab", dict(method="bicgstab", maxiter=2000)),
        ("mg", dict(method="mg", maxiter=60)),
        ("mg_pcg", dict(method="cg", precondition="mg", maxiter=200)),
    ]
    for n in SIZES:
        shape = (n, n, n)
        F = _rhs(shape)
        x0 = np.zeros(shape, np.float32)
        for label, kwargs in cases:
            engine_reset()
            # per-row deltas: the kernel cache is process-wide, so later
            # cases are served as hits — cumulative counters would report
            # fused_kernels=0 for them (the old BENCH_mg artifact did)
            snap = KernelStatsSnapshot()
            prog = poisson_program(shape, rhs=F)
            step = make_solver(prog, "T", backend="pallas", tol=TOL, **kwargs)
            x, (iters, res, _outcome) = step(x0)
            us = time_fn(lambda T: step(T)[0], x0)
            emit(
                f"mg_poisson_{label}_n{n}",
                us,
                f"iterations={int(np.asarray(iters)[0])};"
                f"residual={float(np.asarray(res)[0]):.3e};"
                f"levels={engine_stats.mg_levels_built};"
                f"{snap.derived()};tol={TOL}",
            )


if __name__ == "__main__":
    run()

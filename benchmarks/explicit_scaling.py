"""Paper Fig. 4a + Eq. 6: explicit FTCS weak scaling.

Measures CPU-JAX iteration time at several workloads per processor (W) and
reports, per the paper's methodology:
  * measured iterations/s on this host,
  * the WSE model rate  R = F_c/(6.5·W + 78)   (Eq. 6),
  * the OpenFOAM/Joule fits (Eqs. 4–5) at the matching cell count,
  * the TPU-v5e 3-term roofline rate for the same brick.

Weak-scaling *flatness* (the paper's headline property) is validated
structurally: per-cell cost is measured at growing grid sizes and must stay
within a small factor (no communication cliff exists inside one device; the
sharded variant's halo volume is charged in the roofline model).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.heat3d import HeatConfig, make_field
from repro.core.explicit import ftcs_solve, ftcs_solve_repack
from repro.core.perfmodel import (ftcs_brick_cost, openfoam_explicit_rate,
                                  roofline_time, wse_explicit_rate)

STEPS = 10


def run() -> None:
    us_zr = None  # the 102-cube resident timing, reused for the _repack row
    for nx, ny, nz in [(32, 32, 32), (48, 48, 48), (64, 64, 64),
                       (102, 102, 102)]:
        cfg = HeatConfig(nx=nx, ny=ny, nz=nz)
        T0 = jnp.asarray(make_field(cfg))
        us = time_fn(lambda T: ftcs_solve(T, cfg.omega, STEPS), T0) / STEPS
        if (nx, ny, nz) == (102, 102, 102):
            us_zr = us
        cells = cfg.cells
        meas_rate = 1e6 / us
        wse = wse_explicit_rate(cells)          # whole grid on one "tile"
        # paper comparison at the closest benchmarked workload per core
        of = openfoam_explicit_rate(15625, cells)
        tpu = roofline_time(ftcs_brick_cost(nx // 4, ny // 4, nz))
        emit(f"explicit_weak_{nx}x{ny}x{nz}", us,
             f"cells={cells};ns_per_cell={1e3 * us / cells:.3f};"
             f"meas_it_s={meas_rate:.1f};"
             f"eq6_wse_it_s={wse:.1f};eq5_openfoam_it_s={of:.1f};"
             f"tpu_roofline_it_s={tpu['rate']:.1f};"
             f"tpu_bound={tpu['bound']}")

    # the before/after pair behind the residency PR: the retired repacking
    # stepper (full pad + z-shift copies per step) vs the zero-repack
    # stepper, on the paper's 102^3 brick (us_zr, timed above) — committed
    # per container so the win stays observable in the BENCH trajectory
    cfg = HeatConfig(nx=102, ny=102, nz=102)
    T0 = jnp.asarray(make_field(cfg))
    us_re = time_fn(
        lambda T: ftcs_solve_repack(T, cfg.omega, STEPS), T0) / STEPS
    emit("explicit_weak_102x102x102_repack", us_re,
         f"cells={cfg.cells};ns_per_cell={1e3 * us_re / cfg.cells:.3f};"
         f"note=pre-residency-reference;"
         f"resident_speedup={us_re / us_zr:.2f}x")

    # per-cell cost flatness across sizes (weak-scaling surrogate)
    base = None
    for n in (32, 48, 64):
        cfg = HeatConfig(nx=n, ny=n, nz=n)
        T0 = jnp.asarray(make_field(cfg))
        us = time_fn(lambda T: ftcs_solve(T, cfg.omega, STEPS), T0) / STEPS
        per_cell = us / cfg.cells
        base = base or per_cell
        emit(f"explicit_percell_{n}", us,
             f"ns_per_cell={1e3 * per_cell:.3f};flat_ratio={per_cell / base:.2f}")


if __name__ == "__main__":
    run()

"""Explicit-path sentinel overhead: guarded vs unguarded steady state.

The robustness layer's contract is that arming ``check_finite=N`` costs at
most 2% per step at the checkpoint-chunk granule.  This case measures that
contract the same way ``time_tiling`` measures its k× win: the plan is
built once, the runners are built once, and what is timed is the
steady-state compiled step loop — the unguarded donated runner versus the
executor's actual guarded ``while_loop`` runner, whose ``isfinite`` probe
is fused into the loop carry (one reduction per N steps, single dispatch
per run; the last-good state is recomputed by prefix replay only on the
rare failure path, so the happy path carries no snapshot).

The off/on rounds are **interleaved** in a per-round shuffled order, and
``overhead_pct`` compares the **process-CPU-time floor** (mean of each
side's 8 fastest rounds): wall-clock noise on this container (5-10% CV
from cgroup throttling and neighbor steal) is larger than the ≤2% signal,
while the sentinel's cost is by construction extra CPU work —
``time.process_time`` does not count throttled-out time, and the best-8
floor mean rejects the rounds the XLA thread pool oversubscribed.
``us_per_call`` still reports each side's wall-clock best-of per harness
convention.  The derived column
also carries the fused-kernel accounting (``fallbacks=0`` — the sentinel
must not knock the body off the compiled path); ``run.py --check-health``
gates ``overhead_pct <= 2`` on CI.
"""

from __future__ import annotations

import random
import statistics
import time

import jax

from benchmarks.common import KernelStatsSnapshot, emit, resolved
from repro.configs.heat3d import HeatConfig, make_field
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.engine import RunOptions, reset_stats
from repro.engine.executor import _guarded_loop_wrap, fresh_buffer, single_runner
from repro.engine.plan import plan

# a guarded run pays two kinds of cost: a per-run fixed part (separate
# enter/exit dispatches, no donation) and a per-granule marginal part (one
# cache-resident isfinite pass per N steps).  Both need a realistic run
# length to show their true amortized weight — 64-step runs made the fixed
# part read as 5% "sentinel cost" when it is really ~200us per run — and
# the ~130ms calls double as noise smoothing for the floor estimator.
STEPS = 2048  # steps per timed run
GRANULES = (64, 256)  # probe every N steps


def _record(T0, steps: int):
    wse = WSE_Interface()
    c = 0.1
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    return wse.program


def _runners(program):
    """(baseline, guarded while_loop) runners for the same compiled body."""
    p = plan(program, RunOptions(backend="pallas", time_tile=1, overlap=False))
    base = single_runner(p)
    seg = next(s for s in p.segments if s.loop is not None)
    names = list(program.fields)
    # the fused body steps on layout-padded bricks — enter/exit bracket the
    # guarded run exactly as the executor's event stream does
    enter = jax.jit(p.layout.enter) if p.layout.pad > 0 else None
    exit_ = jax.jit(p.layout.exit) if p.layout.pad > 0 else None
    guarded = {
        every: _guarded_loop_wrap(p, seg.step, every, names) for every in GRANULES
    }
    return p, base, guarded, enter, exit_


def _guarded_run(runner, nchunks, env, enter, exit_):
    """One guarded pass over STEPS steps: enter, the executor's fused
    while_loop (probe in the carry), exit — the same work
    ``execute(..., check_finite=every)`` performs on the happy path."""
    if enter is not None:
        env = enter(env)
    env, i, ok = runner(env, nchunks)
    if not bool(jax.device_get(ok)):
        raise AssertionError("sentinel tripped on a healthy run")
    if exit_ is not None:
        env = exit_(env)
    jax.block_until_ready(list(env.values()))
    return env


def run() -> None:
    cfg = HeatConfig(nx=32, ny=32, nz=16)
    T0 = make_field(cfg)
    program = _record(T0, STEPS)
    env0 = {n: f.init_data for n, f in program.fields.items()}

    reset_stats()
    snap = KernelStatsSnapshot()
    p, base, guarded, enter, exit_ = _runners(program)

    # warm every runner (compile outside the timed region); this case
    # measures a ≤2% contract against ±10% container drift, so the floor
    # estimate needs more interleaved rounds than the harness default
    warmup, iters = resolved()
    iters = max(iters, 40)
    env = {k: fresh_buffer(v) for k, v in env0.items()}
    for _ in range(max(warmup, 1)):
        env = base(env)
    genvs = {e: {k: fresh_buffer(v) for k, v in env0.items()} for e in GRANULES}
    for e in GRANULES:
        genvs[e] = _guarded_run(guarded[e], STEPS // e, genvs[e], enter, exit_)

    # interleaved rounds in a per-round shuffled order: a fixed order
    # phase-locks the last side with this container's periodic CPU-quota
    # throttle and reads as fake overhead on whichever side runs last
    rng = random.Random(0)
    off_wall: list[float] = []
    off_cpu: list[float] = []
    on_wall = {e: [] for e in GRANULES}
    on_cpu = {e: [] for e in GRANULES}

    def run_off():
        nonlocal env
        t0, c0 = time.perf_counter(), time.process_time()
        env = base(env)
        jax.block_until_ready(list(env.values()))
        off_cpu.append(time.process_time() - c0)
        off_wall.append(time.perf_counter() - t0)

    def run_on(e):
        t0, c0 = time.perf_counter(), time.process_time()
        genvs[e] = _guarded_run(guarded[e], STEPS // e, genvs[e], enter, exit_)
        on_cpu[e].append(time.process_time() - c0)
        on_wall[e].append(time.perf_counter() - t0)

    sides = [run_off] + [lambda e=e: run_on(e) for e in GRANULES]
    for _ in range(iters):
        rng.shuffle(sides)
        for side in sides:
            side()

    def floor(ts):
        """Mean of the 8 fastest rounds: the stable floor under additive
        scheduling noise (a raw min still rides single-window luck)."""
        return statistics.mean(sorted(ts)[:8])

    off_floor = floor(off_cpu)
    emit(
        "health_guard_off",
        min(off_wall) * 1e6 / STEPS,
        f"steps={STEPS};probes=0;overhead_pct=0.00;{snap.derived()}",
    )
    for e in GRANULES:
        pct = (floor(on_cpu[e]) - off_floor) / off_floor * 100.0
        emit(
            f"health_guard_on_e{e}",
            min(on_wall[e]) * 1e6 / STEPS,
            f"steps={STEPS};every={e};probes={STEPS // e};"
            f"overhead_pct={pct:.2f};{snap.derived()}",
        )


if __name__ == "__main__":
    run()

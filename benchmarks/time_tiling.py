"""Temporal blocking: k-step fused kernels, one exchange/pad per tile.

Sweeps the engine's ``time_tile`` factor k ∈ {1, 2, 4, 8} over the heat3d
explicit loop (``backend="pallas"``) and reports, per k, the wall time per
step plus the engine's communication accounting — pads/exchanges per step
(must be 1/k), tiles fused, and steps/s.  On this CPU container the kernels
run in Pallas interpret mode, so wall time is the correctness-path number;
the architectural quantity CI tracks in the JSON artifact is the k× drop in
exchanges per step (on TPU/WSE fabric that drop *is* the wall-time win —
Rocki et al.'s temporal blocking argument).
"""

from __future__ import annotations

from benchmarks.common import emit, resolved, time_fn
from repro.configs.heat3d import HeatConfig, make_field
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.engine import reset_stats, stats

STEPS = 8


def _make_once(T0, steps: int, k: int):
    wse = WSE_Interface()
    c = 0.1
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    return wse.make(answer=T, backend="pallas", time_tile=k)


def run() -> None:
    cfg = HeatConfig(nx=32, ny=32, nz=16)
    T0 = make_field(cfg)
    for k in (1, 2, 4, 8):
        reset_stats()
        us = time_fn(lambda: _make_once(T0, STEPS, k))
        warmup, iters = resolved()
        runs = warmup + iters  # executions since reset_stats()
        emit(
            f"time_tiling_k{k}",
            us / STEPS,
            f"steps={STEPS};exchanges_per_step={stats.exchanges_per_step:.3f};"
            f"tiles_fused_per_run={stats.tiles_fused // runs};"
            f"steps_per_sec={stats.steps_per_sec:.1f};"
            f"repacks_per_run={stats.repacks // runs};"
            "note=interpret-mode-wall-time(track=exchanges_per_step)",
        )


if __name__ == "__main__":
    run()

"""Temporal blocking: k-step fused kernels, one exchange/pad per tile.

Sweeps the engine's ``time_tile`` factor k ∈ {1, 2, 4, 8} over the heat3d
explicit loop (``backend="pallas"``) and reports, per k, the **steady-state
compiled** wall time per step: the plan is built once, the jitted runner's
donated env is chained call to call, so what is timed is the resident step
loop — not re-recording, re-planning or re-compiling per measurement (the
pre-PR-8 version of this file did exactly that, and the launch-pipeline
cost buried the k× win it exists to show).

On top of the sweep, the case exercises the measured cost model
(:mod:`repro.core.perfmodel`): one calibration row, a model-driven
``time_tile=None`` row (``auto_tile`` argmin over the measured model, k=1
always admissible), and a forced overlap-split row whose interior kernel
runs while the margin slabs are in flight.  CI's ``--check-tiling`` gate
asserts the headline: k=2 and k=4 steady-state wall time never lose to
k=1.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import KernelStatsSnapshot, emit, resolved
from repro.configs.heat3d import HeatConfig, make_field
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.core import perfmodel
from repro.engine import RunOptions, reset_stats, stats
from repro.engine.executor import execute, fresh_buffer, single_runner
from repro.engine.plan import plan

STEPS = 8


def _record(T0, steps: int):
    wse = WSE_Interface()
    c = 0.1
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    return wse.program


def _steady_us(p, env0) -> float:
    """Best-of steady-state wall time of one runner call (= STEPS steps).

    Plan built by the caller, compile paid in warmup, env chained through
    the donated-buffer runner — the executor's actual step loop.
    """
    runner = single_runner(p)
    env = {k: fresh_buffer(v) for k, v in env0.items()}
    warmup, iters = resolved()
    for _ in range(max(warmup, 1)):  # first call pays the jit compile
        env = runner(env)
    jax.block_until_ready(list(env.values()))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        env = runner(env)
        jax.block_until_ready(list(env.values()))
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


def _env0(program):
    return {n: f.init_data for n, f in program.fields.items()}


def _plan_row(name: str, program, options: RunOptions, model_note: str = ""):
    """One steady-state row: plan once, time the runner, account once."""
    reset_stats()
    snap = KernelStatsSnapshot()
    p = plan(program, options)
    us = _steady_us(p, _env0(program))
    execute(p, _env0(program))  # one accounted run for the derived counters
    seg = next(s for s in p.segments if s.loop is not None)
    emit(
        name,
        us / STEPS,
        f"steps={STEPS};k={seg.time_tile};split={seg.split};"
        f"exchanges_per_step={stats.exchanges_per_step:.3f};"
        f"{model_note}{snap.derived()}",
    )
    return us / STEPS


def run() -> None:
    cfg = HeatConfig(nx=32, ny=32, nz=16)
    T0 = make_field(cfg)
    program = _record(T0, STEPS)

    # the k sweep: steady-state compiled path, monolithic fused launches
    for k in (1, 2, 4, 8):
        _plan_row(
            f"time_tiling_k{k}",
            program,
            RunOptions(backend="pallas", time_tile=k, overlap=False),
        )

    # calibration: measure this body's cost model (stored process-wide)
    reset_stats()
    t0 = time.perf_counter()
    entries = perfmodel.calibrate_program(program, ks=(1, 2, 4), reps=2, inner=4)
    cal_us = (time.perf_counter() - t0) * 1e6
    entry = next(iter(entries.values()))
    emit(
        "time_tiling_calibrate",
        cal_us,
        f"calibrations={stats.calibrations};"
        f"cell_ns={entry.cell_ns:.3f};launch_us={entry.launch_us:.2f};"
        f"exchange_us={entry.exchange_us:.2f};"
        f"boundary_us={entry.boundary_us:.2f};device={entry.device}",
    )

    # model-driven auto tiling: argmin of the measured model, k=1 admissible
    bxy, nz, h = (cfg.nx, cfg.ny), cfg.nz, 1
    preds = ";".join(
        f"pred_k{k}_us={perfmodel.predict_step_us(entry, bxy, nz, h, k):.1f}"
        for k in (1, 2, 4, 8)
    )
    _plan_row(
        "time_tiling_auto",
        program,
        RunOptions(backend="pallas"),
        model_note=preds + ";",
    )

    # forced overlap split: interior kernel concurrent with the margin slabs
    pred_split = perfmodel.predict_step_us(entry, bxy, nz, h, 4, split=True)
    _plan_row(
        "time_tiling_overlap_k4",
        program,
        RunOptions(backend="pallas", time_tile=4, overlap=True),
        model_note=f"pred_split_k4_us={pred_split:.1f};",
    )

    # sharded overlap: ppermute slabs in flight behind the interior launch
    if jax.device_count() >= 4:
        from repro.core.halo import default_mesh2d

        mesh = default_mesh2d()
        for name, ov in (
            ("time_tiling_sharded_k4", False),
            ("time_tiling_sharded_overlap_k4", True),
        ):
            reset_stats()
            snap = KernelStatsSnapshot()
            opts = RunOptions(backend="pallas", mesh=mesh, time_tile=4, overlap=ov)
            p = plan(program, opts)
            from repro.engine.executor import _run_sharded

            warmup, iters = resolved()
            env = _env0(program)
            for _ in range(max(warmup, 1)):
                _run_sharded(p, env)
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                _run_sharded(p, env)
                times.append(time.perf_counter() - t0)
            execute(p, env)
            emit(
                name,
                min(times) * 1e6 / STEPS,
                f"steps={STEPS};devices={jax.device_count()};"
                f"{snap.derived()}",
            )


if __name__ == "__main__":
    run()

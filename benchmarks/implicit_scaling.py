"""Paper Fig. 4b + Eq. 16 + §3.2.2: implicit CG benchmark.

Per paper table row: measured CG inner-iteration time, the Eq. 16 WSE model,
the OpenFOAM fits (Eqs. 13–15), and the explicit/implicit rate ratio the
paper highlights (≈7.7× at full fabric, small W).  Also benchmarks the
beyond-paper variants (pipelined CG, Chebyshev) at identical workloads.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.heat3d import HeatConfig, make_field
from repro.core.explicit import ftcs_solve
from repro.core.implicit import btcs_solve
from repro.core.perfmodel import (openfoam_implicit_rate, wse_dot_time,
                                  wse_explicit_rate, wse_implicit_rate)

ITERS = 25


def run() -> None:
    cfg = HeatConfig(nx=48, ny=48, nz=48)
    T0 = jnp.asarray(make_field(cfg))

    for method in ("cg", "pipecg", "chebyshev"):
        us = time_fn(
            lambda T, m=method: btcs_solve(T, cfg.omega, 1, method=m,
                                           tol=0.0, maxiter=ITERS)[0], T0)
        per_iter = us / ITERS
        emit(f"implicit_{method}_inner_iter", per_iter,
             f"cells={cfg.cells};meas_inner_it_s={1e6 / per_iter:.1f}")

    # Eq. 16 vs Eq. 6 — the paper's 7.7× explicit/implicit ratio at full
    # fabric (X=750, Y=950) and small W
    w_small = 50
    r_exp = wse_explicit_rate(w_small)
    r_imp = wse_implicit_rate(w_small, 750, 950)
    emit("wse_model_explicit_over_implicit", 0.0,
         f"W={w_small};ratio={r_exp / r_imp:.2f};paper_claims=7.7")

    # Eq. 17 at the paper's maximum tested size: 3.25 us dot product
    t_dot = wse_dot_time(1000, 750, 950)
    emit("wse_model_dot_us", t_dot * 1e6,
         f"paper_claims_us=3.25;model_us={t_dot * 1e6:.2f}")

    # OpenFOAM implicit fits at the paper's three workloads (Eqs. 13–15)
    for w, cells in [(13824, 5.8e6), (21952, 4.87e6), (27000, 1.57e8)]:
        emit(f"openfoam_implicit_fit_W{w}", 0.0,
             f"cells={cells:.2e};eq_it_s={openfoam_implicit_rate(w, cells):.1f}")

    # measured explicit/implicit ratio on this host (same grid)
    us_e = time_fn(lambda T: ftcs_solve(T, cfg.omega, ITERS), T0) / ITERS
    us_i = time_fn(
        lambda T: btcs_solve(T, cfg.omega, 1, method="cg", tol=0.0,
                             maxiter=ITERS)[0], T0) / ITERS
    emit("measured_explicit_over_implicit", 0.0,
         f"ratio={us_i / us_e:.2f}")


if __name__ == "__main__":
    run()

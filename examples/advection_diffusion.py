"""Advection–diffusion through the WFA frontend + program compiler.

Transport of a scalar (temperature) by a constant velocity field with
isotropic diffusion and a diagonal cross-diffusion term:

    ∂T/∂t + u·∇T = κ ∇²T + χ ∂²T/∂ξ∂η

Discretized with first-order upwind advection and FTCS diffusion.  The
cross-diffusion stencil uses *off-axis* taps — ``T[1:-1, 1, 1]`` and
``T[1:-1, -1, -1]`` — which none of the hand-wired solver paths (7-point
heat, hex SpMV) ever compile; the program compiler lowers them like any
other tap, so ``backend="pallas"`` still fuses the whole update into one
Pallas kernel per time step.

    PYTHONPATH=src python examples/advection_diffusion.py [--steps 200]
"""
import argparse

import numpy as np

from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface


def build_advection_diffusion(T_init, steps, kappa=0.05, ux=0.1, uy=0.07,
                              chi=0.02, name="T_adv"):
    """Record the advection–diffusion program; returns (wse, field).

    ``ux, uy >= 0`` (upwind differences look at the -x / -y neighbours).
    Stability: kappa <= 1/6 and ux + uy + 6*kappa + 2*chi <= 1.
    """
    wse = WSE_Interface()
    T = WSE_Array(name, init_data=T_init)
    with WSE_For_Loop("time_loop", steps):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] \
            + kappa * (T[2:, 0, 0] + T[:-2, 0, 0]
                       + T[1:-1, 1, 0] + T[1:-1, -1, 0]
                       + T[1:-1, 0, 1] + T[1:-1, 0, -1]
                       - 6.0 * T[1:-1, 0, 0]) \
            - ux * (T[1:-1, 0, 0] - T[1:-1, -1, 0]) \
            - uy * (T[1:-1, 0, 0] - T[1:-1, 0, -1]) \
            + chi * (T[1:-1, 1, 1] + T[1:-1, -1, -1]
                     - 2.0 * T[1:-1, 0, 0])
    return wse, T


def blob_init(shape=(48, 48, 16)):
    """A Gaussian blob off-center, zero Dirichlet boundary."""
    nx, ny, nz = shape
    x = np.arange(nx)[:, None, None]
    y = np.arange(ny)[None, :, None]
    z = np.arange(nz)[None, None, :]
    T = np.exp(-(((x - nx / 4.0) ** 2) / 18.0
                 + ((y - ny / 4.0) ** 2) / 18.0
                 + ((z - nz / 2.0) ** 2) / 8.0)).astype(np.float32)
    T[0, :, :] = T[-1, :, :] = 0.0
    T[:, 0, :] = T[:, -1, :] = 0.0
    return T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    T0 = blob_init()
    wse, T = build_advection_diffusion(T0, args.steps)
    out = wse.make(answer=T, backend="pallas")

    from repro.compiler import stats
    wse, T = build_advection_diffusion(T0, min(args.steps, 20))
    check = wse.make(answer=T, backend="numpy")

    cx, cy, _ = np.unravel_index(np.argmax(out), out.shape)
    print(f"grid {T0.shape}, {args.steps} steps "
          f"(fused kernels: {stats.kernels_built}, "
          f"fallbacks: {stats.fallbacks})")
    print(f"  blob peak drifted to ({cx}, {cy}) "
          f"from ({T0.shape[0] // 4}, {T0.shape[1] // 4})")
    print(f"  mass: {out.sum():.4f} (t0: {T0.sum():.4f})")
    print(f"  numpy validation finite: {np.isfinite(check).all()}")
    assert cx >= T0.shape[0] // 4 and cy >= T0.shape[1] // 4  # advected +x/+y


if __name__ == "__main__":
    main()

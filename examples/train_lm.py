"""End-to-end training driver: a ~100M-parameter qwen3-family model for a
few hundred steps on whatever devices exist, with checkpointing + restart.

The full-size path is identical — swap ``--width/--layers`` for the real
config and run on the production mesh.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    (CPU note: ~100M params trains slowly; --steps 30 --width 256 for a
     quick look, or keep defaults and wait.)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenDataset, shard_batch
from repro.launch.mesh import make_mesh2d
from repro.launch.steps import make_opt_state, make_train_step
from repro.models import model as M
from repro.parallel.params import param_specs_for, rules_for
from repro.parallel.sharding import use_sharding
from repro.runtime import HeartbeatMonitor, ResilientLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-class config in the qwen3 family (qk-norm GQA + SwiGLU)
    cfg = get_config("qwen3-0.6b")
    cfg = dataclasses.replace(
        cfg, d_model=args.width, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=4 * args.width, vocab_size=args.vocab,
        n_layers=args.layers, segments=(("attn", args.layers),),
        tie_embeddings=True, param_dtype="float32",
        compute_dtype="float32", remat="none", num_microbatches=1)

    n = len(jax.devices())
    mesh = make_mesh2d(max(1, n // 2), 2 if n > 1 else 1)
    rules = rules_for(cfg, mesh)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params on mesh {dict(mesh.shape)}")

    p_specs = param_specs_for(cfg, params, rules)
    params = jax.tree.map(
        lambda a, s: jax.device_put(a, jax.sharding.NamedSharding(mesh, s)),
        params, p_specs)
    opt = make_opt_state(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-4, warmup=20,
                                   total_steps=args.steps),
                   donate_argnums=(0, 1))

    ds = TokenDataset(cfg.vocab_size, args.seq, args.batch, seed=0)
    mgr = CheckpointManager(args.ckpt)
    b_shard = jax.sharding.NamedSharding(
        mesh, rules.spec(("batch", "seq"), (args.batch, args.seq)))

    state = {"params": params, "opt": opt}

    def step_fn(state, batch):
        with use_sharding(rules):
            p, o, m = step(state["params"], state["opt"],
                           shard_batch(batch, b_shard))
        return {"params": p, "opt": o}, m

    loop = ResilientLoop(
        step_fn,
        lambda s, st: mgr.save(s, st, blocking=False,
                               extra={"data": ds.state()}),
        lambda: (mgr.restore(state)[0], mgr.restore(state)[1]),
        ds, ckpt_every=100, monitor=HeartbeatMonitor())

    t0 = time.time()
    losses = []
    st = state
    for chunk in range(0, args.steps, 50):
        todo = min(50, args.steps - chunk)
        st, _, metrics = loop.run(st, chunk, todo)
        losses.append(float(metrics["loss"]))
        rate = (chunk + todo) * args.batch * args.seq / (time.time() - t0)
        print(f"step {chunk + todo:4d}  loss {losses[-1]:.4f}  "
              f"({rate:.0f} tok/s)")
    mgr.wait()
    if len(losses) > 1:
        assert losses[-1] < losses[0], "loss must decrease"
    print(f"done: loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"in {time.time() - t0:.0f}s; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()

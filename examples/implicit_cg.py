"""Implicit (BTCS + Krylov) heat solve — paper Eq. 3 — with all three
solver variants, comparing iteration counts and agreement.

    PYTHONPATH=src python examples/implicit_cg.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.heat3d import HeatConfig, make_field
from repro.core.implicit import btcs_solve


def main():
    cfg = HeatConfig(nx=48, ny=48, nz=48)
    T0 = jnp.asarray(make_field(cfg))
    steps = 5

    results = {}
    for method, maxiter in [("cg", 200), ("pipecg", 200), ("chebyshev", 60)]:
        t0 = time.time()
        T, (iters, res) = btcs_solve(T0, cfg.omega, steps, method=method,
                                     tol=1e-5, maxiter=maxiter)
        T.block_until_ready()
        dt = time.time() - t0
        results[method] = np.asarray(T)
        print(f"{method:10s}: {steps} time steps in {dt:5.2f}s; "
              f"inner iters/step={np.asarray(iters).tolist()}  "
              f"final residual={float(np.asarray(res)[-1]):.2e}")

    a, b, c = results["cg"], results["pipecg"], results["chebyshev"]
    print(f"pipecg vs cg     max|Δ| = {np.abs(a - b).max():.2e}")
    print(f"chebyshev vs cg  max|Δ| = {np.abs(a - c).max():.2e}")
    print("reduction counts per inner iteration: cg=2, pipecg=1(fused), "
          "chebyshev=0 — the paper's Eq. 16 latency term shrinks "
          "accordingly.")


if __name__ == "__main__":
    main()

"""Implicit (BTCS + Krylov) heat solve — paper Eq. 3 — on the ``wfa.solve``
frontend: the operator stencil is *recorded* like an explicit update and
compiled to one fused Pallas kernel per application; matrix-free iterations
run on top.

    PYTHONPATH=src python examples/implicit_cg.py [--n 48] [--steps 5]
"""
import argparse
import time

import numpy as np

from repro.compiler import reset_stats, stats
from repro.configs.heat3d import HeatConfig, make_field
from repro.solver import record_btcs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = HeatConfig(nx=args.n, ny=args.n, nz=args.n)
    T0 = make_field(cfg)
    steps = args.steps

    results = {}
    for method, maxiter in [
        ("cg", 200),
        ("pipecg", 200),
        ("bicgstab", 200),
        ("chebyshev", 60),
    ]:
        reset_stats()
        wse, T = record_btcs(T0, cfg.omega)
        t0 = time.time()
        x, info = wse.solve(
            T,
            method=method,
            backend="pallas",
            steps=steps,
            tol=1e-5,
            maxiter=maxiter,
            return_info=True,
        )
        dt = time.time() - t0
        results[method] = x
        print(
            f"{method:10s}: {steps} time steps in {dt:5.2f}s; "
            f"inner iters/step={info.iterations.tolist()}  "
            f"final residual={float(info.residual[-1]):.2e}  "
            f"(fused kernels={stats.kernels_built + stats.cache_hits}, "
            f"fallbacks={stats.fallbacks})"
        )

    a = results["cg"]
    for other in ("pipecg", "bicgstab", "chebyshev"):
        print(f"{other:9s} vs cg  max|Δ| = {np.abs(a - results[other]).max():.2e}")
    print(
        "reduction counts per inner iteration: cg=2, pipecg=1(fused), "
        "bicgstab=4, chebyshev=0 — the paper's Eq. 16 latency term shrinks "
        "accordingly."
    )


if __name__ == "__main__":
    main()

"""Inverse problem: recover a diffusivity field by gradient descent
through ``wfa.solve``.

The forward model is the variable-coefficient implicit heat equation
A(κ)·Tⁿ⁺¹ = Tⁿ with A = I + ωκ·(6I − S) (the BiCGSTAB preset, solved
matrix-free on the fused operator kernel).  The unknown diffusivity κ is
parameterized on a coarse control grid (bilinearly upsampled — the usual
regularization for inverse conduction), the data are *sparse* point
observations of the temperature field after each implicit step, and the
misfit gradient flows through the Krylov solve via the implicit-function-
theorem adjoint (``repro.solver.adjoint`` — one transposed solve per step,
compiled through the same IR → codegen path as the forward operator).

Runs at fp64; converges to < 1 % relative parameter error with zero
interpreter fallbacks:

    PYTHONPATH=src python examples/inverse_diffusivity.py [--iters 150]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.compiler import reset_stats, stats
from repro.core.field import Field
from repro.core.program import scoped_program
from repro.solver import make_differentiable_solver
from repro.solver.frontend import Operator


def upsample_bilinear(theta, nx, ny, nz):
    """(cx, cy) control values → (nx, ny, nz) field, bilinear in X/Y,
    constant in Z (κ varies slowly; the coarse grid is the regularizer)."""
    cx, cy = theta.shape
    xs = jnp.linspace(0.0, cx - 1.0, nx)
    ys = jnp.linspace(0.0, cy - 1.0, ny)
    x0 = jnp.clip(jnp.floor(xs).astype(int), 0, cx - 2)
    y0 = jnp.clip(jnp.floor(ys).astype(int), 0, cy - 2)
    fx = (xs - x0)[:, None]
    fy = (ys - y0)[None, :]
    c = (
        theta[x0[:, None], y0[None, :]] * (1 - fx) * (1 - fy)
        + theta[x0[:, None] + 1, y0[None, :]] * fx * (1 - fy)
        + theta[x0[:, None], y0[None, :] + 1] * (1 - fx) * fy
        + theta[x0[:, None] + 1, y0[None, :] + 1] * fx * fy
    )
    return jnp.broadcast_to(c[:, :, None], (nx, ny, nz))


def record_varcoef(shape, T0, omega):
    with scoped_program() as prog:
        T = Field("T", init_data=T0, dtype=np.float64)
        C = Field("kappa", shape=shape, dtype=np.float64)
        with Operator():
            T[1:-1, 0, 0] = T[1:-1, 0, 0] + omega * C[1:-1, 0, 0] * (
                6.0 * T[1:-1, 0, 0]
                - (
                    T[2:, 0, 0]
                    + T[:-2, 0, 0]
                    + T[1:-1, 1, 0]
                    + T[1:-1, -1, 0]
                    + T[1:-1, 0, 1]
                    + T[1:-1, 0, -1]
                )
            )
    return prog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--nz", type=int, default=8)
    ap.add_argument("--steps", type=int, default=3, help="implicit time steps")
    ap.add_argument("--obs-frac", type=float, default=0.25,
                    help="fraction of interior cells observed")
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--coarse", type=int, default=4, help="control grid edge")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    shape = (args.n, args.n, args.nz)
    omega = 0.3

    # ground truth: a smooth bump of fast-diffusing material
    gx, gy = np.meshgrid(
        np.linspace(-1, 1, args.coarse), np.linspace(-1, 1, args.coarse),
        indexing="ij",
    )
    theta_true = 0.15 + 0.35 * np.exp(-2.0 * (gx**2 + gy**2))

    # initial temperature: hot interior blob on cold Dirichlet walls
    T0 = np.zeros(shape)
    T0[1:-1, 1:-1, 1:-1] = 1.0
    T0 += 0.1 * rng.random(shape)

    reset_stats()
    solver = make_differentiable_solver(
        record_varcoef(shape, T0, omega), "T",
        method="bicgstab", tol=1e-12, maxiter=400, steps=args.steps,
    )

    # sparse observations of the true trajectory's final state
    mask = np.zeros(shape, bool)
    interior = rng.random(shape) < args.obs_frac
    mask[1:-1, 1:-1, 1:-1] = interior[1:-1, 1:-1, 1:-1]
    obs_idx = jnp.asarray(np.argwhere(mask))
    kappa_true = upsample_bilinear(jnp.asarray(theta_true), *shape)
    y_obs = solver(T0, {"kappa": kappa_true})[tuple(obs_idx.T)]

    @jax.jit
    @jax.value_and_grad
    def misfit(theta):
        kappa = upsample_bilinear(theta, *shape)
        x = solver(T0, {"kappa": kappa})
        r = x[tuple(obs_idx.T)] - y_obs
        return jnp.sum(r * r)

    # Adam on the control grid, started from a uniform guess
    theta = jnp.full((args.coarse, args.coarse), 0.25, jnp.float64)
    m = v = jnp.zeros_like(theta)
    lr, b1, b2 = 0.02, 0.9, 0.999
    for i in range(1, args.iters + 1):
        loss, g = misfit(theta)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**i)
        vh = v / (1 - b2**i)
        theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-12)
        if i % 10 == 0 or i == 1:
            rel = float(
                jnp.linalg.norm(theta - theta_true)
                / jnp.linalg.norm(jnp.asarray(theta_true))
            )
            print(f"  iter {i:4d}  misfit {float(loss):.3e}  rel κ err {rel:.3e}")

    rel = float(
        jnp.linalg.norm(theta - theta_true)
        / jnp.linalg.norm(jnp.asarray(theta_true))
    )
    print(
        f"recovered κ on a {args.coarse}×{args.coarse} control grid from "
        f"{int(mask.sum())} of {int(np.prod(shape))} cells: "
        f"rel error {rel:.3e}"
    )
    print(
        f"  compiler: kernels={stats.kernels_built} "
        f"cache_hits={stats.cache_hits} fallbacks={stats.fallbacks}"
    )
    assert rel < 1e-2, f"inverse solve did not converge: rel err {rel:.3e}"
    assert stats.fallbacks == 0, stats.fallback_reasons
    print("OK")


if __name__ == "__main__":
    main()

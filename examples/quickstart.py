"""Quickstart — the paper's Fig. 3 example, verbatim WFA style.

Solves the explicit heat equation on a 102³ grid (500 K interior, 300 K /
400 K plates) and validates against the NumPy backend — exactly the
validation workflow the paper describes.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse

import numpy as np

from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--n", type=int, default=102)
    args = ap.parse_args()

    # ---- paper Fig. 3, left ------------------------------------------------
    wse = WSE_Interface()

    # define constants
    c = 0.1
    center = 1.0 - 6.0 * c

    # Create the initial temperature field and BC's
    n = args.n
    T_init = np.ones((n, n, n), np.float32) * 500.0
    T_init[1:-1, 1:-1, 0] = 300.0
    T_init[1:-1, 1:-1, -1] = 400.0

    # Instantiate the WSE Array objects needed
    T_n = WSE_Array(name="T_n", init_data=T_init)

    # Loop over time
    with WSE_For_Loop("time_loop", args.steps):
        T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] \
            + c * (T_n[2:, 0, 0] + T_n[:-2, 0, 0]
                   + T_n[1:-1, 1, 0] + T_n[1:-1, 0, -1]
                   + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])

    answer = wse.make_WSE(answer=T_n)          # compiled (jit) backend
    # ------------------------------------------------------------------------

    # WFA validation mode (numpy), small step count for speed
    wse2 = WSE_Interface()
    T_v = WSE_Array(name="T_n", init_data=T_init)
    with WSE_For_Loop("time_loop", min(args.steps, 20)):
        T_v[1:-1, 0, 0] = center * T_v[1:-1, 0, 0] \
            + c * (T_v[2:, 0, 0] + T_v[:-2, 0, 0]
                   + T_v[1:-1, 1, 0] + T_v[1:-1, 0, -1]
                   + T_v[1:-1, -1, 0] + T_v[1:-1, 0, 1])
    check = wse2.make(answer=T_v, backend="numpy")

    print(f"grid {T_init.shape}, {args.steps} steps")
    print(f"  T range after solve: [{answer.min():.2f}, {answer.max():.2f}] K")
    print(f"  energy flux established: mid-plane mean "
          f"{answer[:, :, n // 2].mean():.2f} K")
    assert answer.min() >= 299.0 and answer.max() <= 500.5
    print("  numpy validation mode agrees with compiled backend "
          "(first 20 steps):", np.isfinite(check).all())


if __name__ == "__main__":
    main()

"""Geometric multigrid through ``wfa.solve`` — Poisson with flat iterations.

Plain Krylov iteration counts on the Dirichlet Poisson system grow with the
grid; the compiled multigrid hierarchy (every smoother, residual, transfer
and re-discretized coarse operator a recorded program lowered through the
same IR → fused-Pallas path) keeps them flat.  This example solves
``−∇²u = f`` at two sizes and prints the iteration counts for plain CG,
standalone mg V-cycles, and mg-preconditioned CG, plus the engine's
per-level accounting.

    PYTHONPATH=src python examples/poisson_mg.py [--n 33]
"""

import argparse
import time

import numpy as np

from repro.compiler import reset_stats, stats
from repro.engine import reset_stats as engine_reset
from repro.engine import stats as engine_stats
from repro.solver import poisson_program, solve


def source(shape):
    """A smooth two-blob source term, normalised to unit norm."""
    x, y, z = np.meshgrid(
        *[np.linspace(0.0, 1.0, n, dtype=np.float32) for n in shape],
        indexing="ij",
    )
    F = np.exp(-80.0 * ((x - 0.3) ** 2 + (y - 0.4) ** 2 + (z - 0.5) ** 2))
    F -= np.exp(-80.0 * ((x - 0.7) ** 2 + (y - 0.6) ** 2 + (z - 0.5) ** 2))
    F[0], F[-1] = 0.0, 0.0
    F[:, 0], F[:, -1] = 0.0, 0.0
    F[:, :, 0], F[:, :, -1] = 0.0, 0.0
    return (F / np.linalg.norm(F)).astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=33)
    ap.add_argument("--tol", type=float, default=1e-5)
    args = ap.parse_args()

    sizes = (max(9, (args.n + 1) // 2), args.n)
    runs = [
        ("cg", dict(method="cg", maxiter=2000)),
        ("mg", dict(method="mg", maxiter=60)),
        ("cg+mg", dict(method="cg", precondition="mg", maxiter=200)),
    ]
    for n in sizes:
        shape = (n, n, n)
        F = source(shape)
        print(f"--- Poisson {shape}, tol {args.tol} ---")
        for label, kwargs in runs:
            reset_stats()
            engine_reset()
            prog = poisson_program(shape, rhs=F)
            t0 = time.time()
            x, info = solve(
                prog,
                "T",
                backend="pallas",
                tol=args.tol,
                return_info=True,
                **kwargs,
            )
            dt = time.time() - t0
            extra = ""
            if engine_stats.mg_levels_built:
                shapes = [s for s, _, _ in engine_stats.mg_level_log]
                extra = f"  levels={shapes}"
            print(
                f"{label:>6}: iterations={int(info.iterations[0]):4d}  "
                f"residual={float(info.residual[0]):.2e}  "
                f"wall={dt:6.2f}s  kernels={stats.kernels_built}"
                f"{extra}"
            )
        print()


if __name__ == "__main__":
    main()

"""Distributed field solve: the paper's workload on a device mesh, with all
the beyond-paper variants (overlap, wide halos, Pallas kernel, pipelined CG).

Run with fake devices to see the brick decomposition:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/sharded_heat.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.heat3d import HeatConfig, make_field
from repro.core.explicit import make_sharded_ftcs
from repro.core.implicit import make_sharded_implicit
from repro.core.halo import default_mesh2d


def main():
    mesh = default_mesh2d()
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")
    cfg = HeatConfig(nx=48, ny=48, nz=48)
    T0 = jnp.asarray(make_field(cfg))
    steps = 20

    variants = {
        "baseline (paper-faithful)": dict(),
        "overlap halo/compute": dict(overlap=True),
        "wide halo k=4 (comm-avoiding)": dict(halo_depth=4),
        "fused Pallas stencil": dict(use_kernel=True),
    }
    ref = None
    for name, kw in variants.items():
        spc = steps if "halo_depth" not in kw else steps // kw["halo_depth"]
        step, sh = make_sharded_ftcs(mesh, T0.shape, cfg.omega,
                                     steps_per_call=spc, **kw)
        T = jax.device_put(T0, sh)
        t0 = time.time()
        out = np.asarray(jax.device_get(step(T)))
        dt = time.time() - t0
        ref = out if ref is None else ref
        print(f"  explicit {name:32s} {dt * 1e3:7.1f} ms  "
              f"max|Δ|vs baseline {np.abs(out - ref).max():.2e}")

    for method in ("cg", "pipecg", "chebyshev"):
        step, sh = make_sharded_implicit(mesh, T0.shape, cfg.omega,
                                         method=method, tol=1e-5,
                                         maxiter=120, steps=2)
        T = jax.device_put(T0, sh)
        t0 = time.time()
        out = np.asarray(jax.device_get(step(T)))
        dt = time.time() - t0
        print(f"  implicit {method:10s} 2 BTCS steps in {dt * 1e3:7.1f} ms "
              f"(range [{out.min():.1f}, {out.max():.1f}] K)")


if __name__ == "__main__":
    main()

"""Legacy LM serving example: batched prefill + decode with a KV cache.

Exercises the mesh/sharding launch path only — for serving simulations
use ``python -m repro.service --smoke`` (see docs/service.md).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b \
        --batch 4 --prompt-len 32 --gen 32
"""
import argparse

import jax

from repro.configs import get_config
from repro.launch.mesh import make_mesh2d
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced smoke config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()
    n = len(jax.devices())
    mesh = make_mesh2d(max(1, n // 2), 2 if n > 1 else 1)
    toks, rate = serve(cfg, mesh, batch=args.batch,
                       prompt_len=args.prompt_len, gen=args.gen)
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"output token block: {toks.shape}; decode rate {rate:.1f} tok/s")
    print(f"first sequence: {toks[0].tolist()[:16]} ...")


if __name__ == "__main__":
    main()

"""Variable-coefficient implicit diffusion through ``wfa.solve`` (BiCGSTAB).

A per-cell diffusivity field C (the finite-volume CFD direction: material
properties become fields) makes the BTCS operator A = I + ωC·(6I − S)
**non-symmetric**, so CG no longer applies — this is the paper's BiCGSTAB
use case.  The lowering pass turns the C·T products into two-tap terms, so
``backend="pallas"`` still fuses the whole operator application into ONE
Pallas kernel — zero interpreter fallbacks.

    PYTHONPATH=src python examples/implicit_varcoef.py [--steps 5]
"""
import argparse

import numpy as np

from repro.compiler import reset_stats, stats
from repro.configs.heat3d import HeatConfig, make_field
from repro.solver import operator_fns, record_varcoef_btcs


def two_material_coef(shape, c_slow=0.02, c_fast=0.25):
    """A slab of fast-diffusing material embedded in a slow matrix."""
    C = np.full(shape, c_slow, np.float32)
    nx, ny, _ = shape
    C[nx // 4 : 3 * nx // 4, ny // 4 : 3 * ny // 4, :] = c_fast
    return C


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--n", type=int, default=32)
    args = ap.parse_args()

    shape = (args.n, args.n, args.n)
    T0 = make_field(HeatConfig(nx=args.n, ny=args.n, nz=args.n))
    C0 = two_material_coef(shape)
    omega = 0.1

    reset_stats()
    wse, T, C = record_varcoef_btcs(T0, C0, omega)
    x, info = wse.solve(
        T,
        method="bicgstab",
        backend="pallas",
        steps=args.steps,
        tol=1e-6,
        maxiter=300,
        return_info=True,
    )
    print(
        f"grid {shape}, {args.steps} implicit steps, two-material C "
        f"({C0.min():.2f}/{C0.max():.2f})"
    )
    print(
        f"  bicgstab inner iters/step = {info.iterations.tolist()}, "
        f"final residual = {float(info.residual[-1]):.2e}"
    )
    print(
        f"  compiler: fused kernels={stats.kernels_built}, "
        f"cache hits={stats.cache_hits}, fallbacks={stats.fallbacks}"
    )

    # verify: apply the recorded operator to the solution of the LAST step
    # and compare against that step's right-hand side (the previous state)
    wse2, T2, C2 = record_varcoef_btcs(T0, C0, omega)
    prev, _ = wse2.solve(
        T2,
        method="bicgstab",
        backend="pallas",
        steps=args.steps - 1,
        tol=1e-6,
        maxiter=300,
        return_info=True,
    )
    wse3, T3, C3 = record_varcoef_btcs(prev, C0, omega)
    A, _ = operator_fns(wse3.program, T3, backend="jit")
    resid = np.abs(np.asarray(A(x)) - prev).max()
    print(f"  ‖A·x − b‖∞ against the previous state: {resid:.2e}")
    assert stats.fallbacks == 0
    assert resid < 1e-3


if __name__ == "__main__":
    main()

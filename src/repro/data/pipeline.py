"""Deterministic synthetic token pipeline.

Production shape: an iterator of fixed-size {tokens, labels} batches, built
from a seeded document stream, greedily packed into sequences, sharded onto
the mesh with ``jax.device_put``.  Determinism is per (seed, step) so a
restart from checkpoint replays the identical stream — the data-side half of
fault tolerance (see runtime/fault.py).
"""
from __future__ import annotations

from typing import Iterator

import jax
import numpy as np


def _doc_stream(seed: int, vocab: int, mean_len: int = 512):
    """Endless seeded stream of variable-length 'documents'."""
    rng = np.random.default_rng(seed)
    while True:
        n = max(8, int(rng.exponential(mean_len)))
        yield rng.integers(1, vocab, size=n, dtype=np.int32)


def pack_documents(docs, seq_len: int, eos: int = 0):
    """Greedy packing of documents into (seq_len+1,) rows (with EOS joints)."""
    buf: list = []
    for d in docs:
        buf.extend(d.tolist())
        buf.append(eos)
        while len(buf) >= seq_len + 1:
            row = np.asarray(buf[:seq_len + 1], dtype=np.int32)
            buf = buf[seq_len + 1:]
            yield row


class TokenDataset:
    """Seeded, restartable batch iterator.

    ``state()``/``restore()`` expose the stream position for checkpointing;
    restoring replays from the exact batch index.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 n_codebooks: int = 1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.n_codebooks = n_codebooks
        self._step = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self._step}

    def restore(self, state: dict) -> None:
        self.seed = state["seed"]
        self._step = state["step"]

    def next_batch(self) -> dict:
        # per-batch independent seeding → O(1) restart (no stream replay)
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        shape = (self.batch, self.seq_len + 1)
        if self.n_codebooks > 1:
            shape = shape + (self.n_codebooks,)
        # learnable structure (not uniform noise): a random-walk bigram
        # process t_{i+1} = t_i + d_i, d ∈ {1, 2} — ~1 bit/token entropy,
        # so the training loss has log(V) − 1 bit of headroom to descend.
        start = rng.integers(1, self.vocab, size=(shape[0],) + shape[2:],
                             dtype=np.int64)
        deltas = rng.integers(1, 3, size=shape, dtype=np.int64)
        deltas[:, 0] = 0
        rows = ((start[:, None] + np.cumsum(deltas, axis=1) - 1)
                % (self.vocab - 1) + 1).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch onto the mesh (DP over the batch dim)."""
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}

"""repro.data — deterministic token pipeline with packing + host sharding."""
from repro.data.pipeline import TokenDataset, pack_documents, shard_batch

__all__ = ["TokenDataset", "pack_documents", "shard_batch"]

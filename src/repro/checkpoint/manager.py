"""Checkpointing: atomic npz snapshots, async writer, elastic restore.

* **atomic** — write to ``<dir>/tmp-<step>`` then rename, so a mid-write
  failure never corrupts the latest checkpoint;
* **async** — ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes on a background thread, overlapping the
  next training steps (the compute/IO overlap trick);
* **elastic** — ``restore(target=...)`` re-places arrays onto whatever mesh
  the target ShapeDtypeStructs / arrays carry, so a job restarted on a
  different device count resumes seamlessly (reshard-on-restore);
* **retention** — keeps the newest ``keep`` checkpoints.

On a real multi-host pod this pairs with jax.distributed: every host saves
its addressable shards (here: single process saves everything).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":   # npz has no native bf16 encoding
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[dict] = None) -> None:
        self.wait()
        flat = _flatten(tree)          # host snapshot (synchronous, cheap)
        meta = {"step": int(step), "extra": extra or {}}

        def write():
            tmp = os.path.join(self.dir, f"tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target: Any, step: Optional[int] = None):
        """Restore into the structure/shardings of ``target``.

        ``target`` may hold arrays or ShapeDtypeStructs with ``.sharding`` —
        each loaded leaf is device_put to that sharding (elastic restore).
        Returns (tree, step, extra).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        self.wait()
        d = os.path.join(self.dir, f"step-{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))

        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        out = []
        for path, leaf in paths_leaves:
            key = SEP.join(_path_str(p) for p in path)
            arr = arrays[key]
            dtype = np.dtype(leaf.dtype)   # bf16 restores via ml_dtypes cast
            if arr.dtype != dtype:
                arr = arr.astype(dtype)
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and not callable(sharding):
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out), meta["step"], meta["extra"]

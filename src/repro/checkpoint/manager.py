"""Checkpointing: atomic npz snapshots, async writer, elastic restore.

* **atomic** — write to ``<dir>/tmp-<step>`` then rename, so a mid-write
  failure never corrupts the latest checkpoint;
* **async** — ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes on a background thread, overlapping the
  next training steps (the compute/IO overlap trick);
* **elastic** — ``restore(target=...)`` re-places arrays onto whatever mesh
  the target ShapeDtypeStructs / arrays carry, so a job restarted on a
  different device count resumes seamlessly (reshard-on-restore);
* **retention** — keeps the newest ``keep`` checkpoints.

On a real multi-host pod this pairs with jax.distributed: every host saves
its addressable shards (here: single process saves everything).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> tuple:
    """(arrays, dtypes): npz-safe arrays + the *original* dtype per key.

    npz has no native bf16 encoding, so bf16 leaves are stored as their
    exact fp32 upcast — but the original dtype goes into the sidecar
    metadata so :meth:`CheckpointManager.restore` can cast back.  Without
    it a restore into a dtype-less target (or a differently-typed one)
    silently keeps the fp32 widening, and the round trip stops being the
    identity the caller saved.
    """
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = arr.dtype.name
        if arr.dtype.name == "bfloat16":   # npz has no native bf16 encoding
            arr = arr.astype(np.float32)   # exact: fp32 ⊃ bf16
        flat[key] = arr
    return flat, dtypes


def _lookup_dtype(name: str) -> np.dtype:
    """Resolve a saved dtype name, including the ml_dtypes extension types
    numpy cannot name on its own (``bfloat16``)."""
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp

        return np.dtype(getattr(jnp, name))


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[dict] = None) -> None:
        self.wait()
        flat, dtypes = _flatten(tree)  # host snapshot (synchronous, cheap)
        meta = {"step": int(step), "extra": extra or {}, "dtypes": dtypes}

        def write():
            tmp = os.path.join(self.dir, f"tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target: Any, step: Optional[int] = None):
        """Restore into the structure/shardings of ``target``.

        ``target`` may hold arrays or ShapeDtypeStructs with ``.sharding`` —
        each loaded leaf is device_put to that sharding (elastic restore).
        Returns (tree, step, extra).

        Each array is first cast back to the dtype it was *saved* with
        (recorded in the sidecar metadata — bf16 round-trips through its
        exact fp32 npz encoding), then to the target leaf's dtype; so a
        bf16 checkpoint restores bitwise into a bf16 target and never
        smuggles fp32 widening into a dtype-mismatched one.
        """
        self.wait()  # before listing: an async writer may still be renaming
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        saved_dtypes = meta.get("dtypes", {})  # absent in pre-fix checkpoints

        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        out = []
        for path, leaf in paths_leaves:
            key = SEP.join(_path_str(p) for p in path)
            arr = arrays[key]
            saved = saved_dtypes.get(key)
            if saved is not None and arr.dtype.name != saved:
                arr = arr.astype(_lookup_dtype(saved))
            dtype = np.dtype(leaf.dtype)   # bf16 restores via ml_dtypes cast
            if arr.dtype != dtype:
                arr = arr.astype(dtype)
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and not callable(sharding):
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out), meta["step"], meta["extra"]

"""repro.checkpoint — npz-based save/restore with async write + resharding."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]

"""repro.compiler — lower recorded WFA programs to fused Pallas kernels.

The paper's core move: the NumPy-like Python program compiles into bytecode
whose *fused* RPCs give the WSE its two-orders-of-magnitude win.  This
package is the JAX analogue for the recorded ``Program``: instead of
interpreting one ``jnp.roll`` per stencil term, ``backend="pallas"`` lowers
every ``ForLoop`` body through

1. :mod:`~repro.compiler.ir` — normalization to a canonical sum of
   ``coeff · field[dz, dx, dy]`` taps (constant folding, like-term merging,
   variable-coefficient products, non-affine rejection);
2. :mod:`~repro.compiler.codegen` — one fused ``pl.pallas_call`` per loop
   body via :mod:`repro.kernels.fused`, with the Moat mask applied in-kernel,
   memoized by program signature (the time-tile factor is part of the key);
3. execution integration in :mod:`repro.engine` — the unified planner /
   executor that ``make``, ``run_sharded`` and ``wfa.solve`` dispatch
   through, including temporal blocking (:func:`~repro.compiler.ir.
   tile_group`: k steps per kernel launch off one depth-``k·h`` halo) and a
   logged interpreter fallback whenever lowering is unsupported.
"""
from repro.compiler.codegen import (CompilerStats, clear_cache, compile_group,
                                    compile_group_sharded, compile_transfer,
                                    reset_stats, stats, try_compile)
from repro.compiler.ir import (AffineUpdate, LoweredGroup, LoweringError,
                               MGOperator, RegionSpec, SplitRegions, Tap,
                               TiledGroup, TransferStencil, auto_tile,
                               coarsen_operator, coarsen_shape, coarsenable,
                               lower_group, lower_update, mg_fine_operator,
                               mg_hierarchy, split_regions, tile_group,
                               transpose_taps)


__all__ = [
    "AffineUpdate", "CompilerStats", "LoweredGroup", "LoweringError",
    "MGOperator", "RegionSpec", "SplitRegions", "Tap", "TiledGroup",
    "TransferStencil", "auto_tile", "clear_cache", "coarsen_operator",
    "coarsen_shape", "coarsenable", "compile_group",
    "compile_group_sharded", "compile_transfer", "lower_group",
    "lower_update", "mg_fine_operator", "mg_hierarchy", "reset_stats",
    "split_regions", "stats", "tile_group", "transpose_taps",
    "try_compile",
]

"""IR + normalization pass: ``StencilExpr`` trees → canonical affine taps.

The WFA compiles the user's Python into bytecode whose fused RPCs are what
make the WSE fast; the analogous artifact here is a *canonical tap form* that
the codegen pass (:mod:`repro.compiler.codegen`) turns into one fused Pallas
kernel per loop body.  An update lowers to

    field[z0:z0+zlen] = const + Σ_k  c_k · Π_j  tap_{k,j}

where every :class:`Tap` is ``field[dz, dx, dy]`` relative to the target
slice.  Products of up to :data:`MAX_TAPS` taps are allowed — one tap acts as
a *variable coefficient* array (the finite-volume CFD direction: ω becomes a
field) — anything of higher degree, or division by a field, is non-affine and
raises :class:`LoweringError`, which the backend turns into a logged
interpreter fallback.

Normalization performed here: constant folding (``0.5 + 0.5``, ``-0.0·T``
drops out), like-term combination (duplicate taps merge coefficients), and
distribution of products over sums, so e.g. the Fig. 3 heat update always
canonicalizes to the same seven taps regardless of how the Python spelled it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core import stencil as st

#: Maximum number of field taps multiplied together in one product term.
#: 1 = plain affine; 2 = variable-coefficient (one tap is the coefficient
#: array).  Anything above is non-affine → interpreter fallback.
MAX_TAPS = 2


class LoweringError(Exception):
    """The expression cannot be lowered to the canonical affine form."""


@dataclasses.dataclass(frozen=True, order=True)
class Tap:
    """One field read ``field[z+dz, x+dx, y+dy]`` relative to the target."""

    field: str
    dz: int
    dx: int
    dy: int


@dataclasses.dataclass(frozen=True)
class AffineUpdate:
    """One lowered ``UpdateOp`` in canonical tap form."""

    field: str               # written field
    z0: int                  # normalized target z start
    zlen: int                # target z length
    const: float             # folded constant addend
    #: ((coeff, (tap, ...)), ...) — taps sorted, like terms combined
    terms: Tuple[Tuple[float, Tuple[Tap, ...]], ...]

    def taps(self) -> Iterable[Tap]:
        for _, taps in self.terms:
            yield from taps


@dataclasses.dataclass(frozen=True)
class LoweredGroup:
    """All ops of one ``ForLoop`` body (or one unlooped op run)."""

    updates: Tuple[AffineUpdate, ...]
    halo: int                # max |dx|, |dy| over all taps

    def fields_read(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for u in self.updates:
            for t in u.taps():
                if t.field not in seen:
                    seen.append(t.field)
        return tuple(seen)

    def fields_written(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for u in self.updates:
            if u.field not in seen:
                seen.append(u.field)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class TiledGroup:
    """Temporal composition of a loop body: ``k`` sub-steps per kernel launch.

    The transform behind the engine's *time tiling*: one padded window of
    halo depth ``k·h`` feeds ``k`` in-kernel applications of the body's tap
    form, the valid region shrinking by ``h`` per sub-step (trapezoid
    blocking — Rocki et al.'s wafer-scale stencil schedule).  Moat masking is
    applied *per sub-step* from global coordinates, so composition stays
    exact at the Dirichlet boundary; composing the taps algebraically would
    not (the mask makes the k-step map non-affine at the boundary rows).
    Communication amortizes k×: one halo exchange (or wrap pad) per tile
    instead of one per step.
    """

    base: LoweredGroup
    k: int

    @property
    def halo(self) -> int:
        """Padding depth of the tiled window (``k·h``)."""
        return self.k * self.base.halo

    @property
    def updates(self) -> Tuple[AffineUpdate, ...]:
        return self.base.updates


def tile_group(group: LoweredGroup, k: int,
               brick_xy: Tuple[int, int] = None,
               n_steps: int = None) -> TiledGroup:
    """Validate and build the ``k``-step composition of ``group``.

    Legality: the body must already be in canonical affine tap form (i.e. a
    :class:`LoweredGroup` — non-affine bodies never reach here), which makes
    it *self-consistent*: every field it reads through a spatial offset is
    either updated by the body itself (its sub-step evolution is replayed
    in-window) or constant over the tile (a coefficient field).  Bounds:
    the tiled halo ``k·h`` must fit inside the brick (``ppermute`` moves at
    most one brick per hop) and ``k`` cannot exceed the loop trip count.
    Violations raise :class:`LoweringError`; the planner falls back to
    ``k = 1`` with a logged reason.
    """
    if not isinstance(k, int) or k < 1:
        raise LoweringError(f"time tile factor must be a positive int, got {k!r}")
    if n_steps is not None and k > n_steps:
        raise LoweringError(
            f"time tile k={k} exceeds the loop trip count {n_steps}")
    if brick_xy is not None and group.halo > 0:
        if k * group.halo > min(brick_xy):
            raise LoweringError(
                f"time tile k={k} needs halo depth {k * group.halo} > brick "
                f"extent {min(brick_xy)}; neighbour exchange only reaches one "
                "brick")
    return TiledGroup(base=group, k=k)


def auto_tile(group: LoweredGroup, brick_xy: Tuple[int, int],
              n_steps: int, max_k: int = 8) -> int:
    """Pick a time-tile factor: the largest power of two ``k ≤ max_k`` that
    divides the trip count (auto-tiled runs never need a remainder kernel)
    and whose tiled halo stays small next to the brick
    (``4·k·h ≤ min(bx, by)``, i.e. at most ~25% linear overhead per side).
    Halo-free bodies tile purely for launch amortization."""
    cand = max_k
    while cand >= 2:
        if (cand <= n_steps and n_steps % cand == 0
                and (group.halo == 0
                     or 4 * cand * group.halo <= min(brick_xy))):
            return cand
        cand //= 2
    return 1


# ---------------------------------------------------------------------------
# expression → polynomial-in-taps
# ---------------------------------------------------------------------------

_Poly = Dict[Tuple[Tap, ...], float]   # () key holds the constant addend


def _poly_add(a: _Poly, b: _Poly, sign: float = 1.0) -> _Poly:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + sign * v
    return out


def _poly_mul(a: _Poly, b: _Poly) -> _Poly:
    out: _Poly = {}
    for ka, va in a.items():
        for kb, vb in b.items():
            k = tuple(sorted(ka + kb))
            if len(k) > MAX_TAPS:
                raise LoweringError(
                    f"product of {len(k)} field taps is non-affine "
                    f"(degree > {MAX_TAPS}): {k}")
            out[k] = out.get(k, 0.0) + va * vb
    return out


def _to_poly(e: st.StencilExpr, target_z: slice) -> _Poly:
    if isinstance(e, st.Const):
        return {(): e.value}
    if isinstance(e, st.Term):
        dz = st.zslice_delta(e.zslice_obj(), target_z)
        return {(Tap(e.field_name, dz, e.dx, e.dy),): 1.0}
    if isinstance(e, st.BinOp):
        lhs = _to_poly(e.lhs, target_z)
        rhs = _to_poly(e.rhs, target_z)
        if e.op == "add":
            return _poly_add(lhs, rhs)
        if e.op == "sub":
            return _poly_add(lhs, rhs, sign=-1.0)
        if e.op == "mul":
            return _poly_mul(lhs, rhs)
        if e.op == "div":
            if set(rhs) - {()}:
                raise LoweringError("division by a field expression is "
                                    "non-affine")
            d = rhs.get((), 0.0)
            if d == 0.0:
                raise LoweringError("division by constant zero")
            return {k: v / d for k, v in lhs.items()}
        raise LoweringError(f"unknown binop {e.op!r}")
    raise LoweringError(f"cannot lower expression node {type(e).__name__}")


def lower_update(op) -> AffineUpdate:
    """Lower one recorded ``UpdateOp`` (normalized slices) to tap form."""
    target = op.target_z
    poly = _to_poly(op.expr, target)
    const = poly.pop((), 0.0)
    terms = tuple(sorted(
        (coeff, taps) for taps, coeff in poly.items() if coeff != 0.0))
    z0, z1 = target.start, target.stop
    if z0 is None or z0 < 0:
        raise LoweringError("target z slice is not normalized")
    return AffineUpdate(field=op.field_name, z0=z0, zlen=z1 - z0,
                        const=const, terms=terms)


def lower_group(ops: Sequence) -> LoweredGroup:
    """Lower a loop body's ops; reject cross-tile reads of updated fields.

    Within one fused kernel a block only sees its *own* updated values, so an
    op that reads a field written by an *earlier* op of the same loop body
    through a nonzero (dx, dy) offset cannot be fused — neighbouring blocks'
    updates are not visible until the next kernel launch.  (dz offsets are
    fine: the Z column is block-local, the paper's 1×1×Z decomposition.)
    """
    updates = []
    written: List[str] = []
    for op in ops:
        u = lower_update(op)
        for t in u.taps():
            if t.field in written and (t.dx or t.dy):
                raise LoweringError(
                    f"op writing {u.field!r} reads {t.field!r} at offset "
                    f"(dx={t.dx}, dy={t.dy}) after it was updated earlier in "
                    "the same loop body; cross-tile read-after-write cannot "
                    "be fused")
        updates.append(u)
        if u.field not in written:
            written.append(u.field)
    halo = 0
    for u in updates:
        for t in u.taps():
            halo = max(halo, abs(t.dx), abs(t.dy))
    return LoweredGroup(updates=tuple(updates), halo=halo)

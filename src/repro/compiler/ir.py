"""IR + normalization pass: ``StencilExpr`` trees → canonical affine taps.

The WFA compiles the user's Python into bytecode whose fused RPCs are what
make the WSE fast; the analogous artifact here is a *canonical tap form* that
the codegen pass (:mod:`repro.compiler.codegen`) turns into one fused Pallas
kernel per loop body.  An update lowers to

    field[z0:z0+zlen] = const + Σ_k  c_k · Π_j  tap_{k,j}

where every :class:`Tap` is ``field[dz, dx, dy]`` relative to the target
slice.  Products of up to :data:`MAX_TAPS` taps are allowed — one tap acts as
a *variable coefficient* array (the finite-volume CFD direction: ω becomes a
field) — anything of higher degree, or division by a field, is non-affine and
raises :class:`LoweringError`, which the backend turns into a logged
interpreter fallback.

Normalization performed here: constant folding (``0.5 + 0.5``, ``-0.0·T``
drops out), like-term combination (duplicate taps merge coefficients), and
distribution of products over sums, so e.g. the Fig. 3 heat update always
canonicalizes to the same seven taps regardless of how the Python spelled it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core import stencil as st

#: Maximum number of field taps multiplied together in one product term.
#: 1 = plain affine; 2 = variable-coefficient (one tap is the coefficient
#: array).  Anything above is non-affine → interpreter fallback.
MAX_TAPS = 2


class LoweringError(Exception):
    """The expression cannot be lowered to the canonical affine form."""


@dataclasses.dataclass(frozen=True, order=True)
class Tap:
    """One field read ``field[z+dz, x+dx, y+dy]`` relative to the target."""

    field: str
    dz: int
    dx: int
    dy: int


@dataclasses.dataclass(frozen=True)
class AffineUpdate:
    """One lowered ``UpdateOp`` in canonical tap form."""

    field: str               # written field
    z0: int                  # normalized target z start
    zlen: int                # target z length
    const: float             # folded constant addend
    #: ((coeff, (tap, ...)), ...) — taps sorted, like terms combined
    terms: Tuple[Tuple[float, Tuple[Tap, ...]], ...]

    def taps(self) -> Iterable[Tap]:
        for _, taps in self.terms:
            yield from taps


@dataclasses.dataclass(frozen=True)
class LoweredGroup:
    """All ops of one ``ForLoop`` body (or one unlooped op run)."""

    updates: Tuple[AffineUpdate, ...]
    halo: int                # max |dx|, |dy| over all taps

    def fields_read(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for u in self.updates:
            for t in u.taps():
                if t.field not in seen:
                    seen.append(t.field)
        return tuple(seen)

    def fields_written(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for u in self.updates:
            if u.field not in seen:
                seen.append(u.field)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class TiledGroup:
    """Temporal composition of a loop body: ``k`` sub-steps per kernel launch.

    The transform behind the engine's *time tiling*: one padded window of
    halo depth ``k·h`` feeds ``k`` in-kernel applications of the body's tap
    form, the valid region shrinking by ``h`` per sub-step (trapezoid
    blocking — Rocki et al.'s wafer-scale stencil schedule).  Moat masking is
    applied *per sub-step* from global coordinates, so composition stays
    exact at the Dirichlet boundary; composing the taps algebraically would
    not (the mask makes the k-step map non-affine at the boundary rows).
    Communication amortizes k×: one halo exchange (or wrap pad) per tile
    instead of one per step.
    """

    base: LoweredGroup
    k: int

    @property
    def halo(self) -> int:
        """Padding depth of the tiled window (``k·h``)."""
        return self.k * self.base.halo

    @property
    def updates(self) -> Tuple[AffineUpdate, ...]:
        return self.base.updates


def tile_group(group: LoweredGroup, k: int,
               brick_xy: Tuple[int, int] = None,
               n_steps: int = None) -> TiledGroup:
    """Validate and build the ``k``-step composition of ``group``.

    Legality: the body must already be in canonical affine tap form (i.e. a
    :class:`LoweredGroup` — non-affine bodies never reach here), which makes
    it *self-consistent*: every field it reads through a spatial offset is
    either updated by the body itself (its sub-step evolution is replayed
    in-window) or constant over the tile (a coefficient field).  Bounds:
    the tiled halo ``k·h`` must fit inside the brick (``ppermute`` moves at
    most one brick per hop) and ``k`` cannot exceed the loop trip count.
    Violations raise :class:`LoweringError`; the planner falls back to
    ``k = 1`` with a logged reason.
    """
    if not isinstance(k, int) or k < 1:
        raise LoweringError(f"time tile factor must be a positive int, got {k!r}")
    if n_steps is not None and k > n_steps:
        raise LoweringError(
            f"time tile k={k} exceeds the loop trip count {n_steps}")
    if brick_xy is not None and group.halo > 0:
        if k * group.halo > min(brick_xy):
            raise LoweringError(
                f"time tile k={k} needs halo depth {k * group.halo} > brick "
                f"extent {min(brick_xy)}; neighbour exchange only reaches one "
                "brick")
    return TiledGroup(base=group, k=k)


def auto_tile(group: LoweredGroup, brick_xy: Tuple[int, int],
              n_steps: int, max_k: int = 8, *, cost=None, nz: int = None
              ) -> int:
    """Pick a time-tile factor.

    Without a cost model this is the static rule: the largest power of two
    ``k ≤ max_k`` that divides the trip count (auto-tiled runs never need a
    remainder kernel) and whose tiled halo stays small next to the brick
    (``4·k·h ≤ min(bx, by)``, i.e. at most ~25% linear overhead per side).
    Halo-free bodies tile purely for launch amortization.

    With ``cost=`` (a calibrated :class:`repro.core.perfmodel.MeasuredCost`
    for this body's signature) and ``nz``, the choice is the argmin of the
    *measured* model over every legal power-of-two candidate — each scored
    as the better of its fused and overlap-split schedules
    (:func:`repro.core.perfmodel.predict_step_us`).  ``k = 1`` is always a
    candidate, so a model-driven pick can never lose to untiled stepping by
    construction.
    """
    if cost is not None and nz is not None and n_steps > 1:
        from repro.core.perfmodel import predict_step_us

        best_k, best_t = 1, predict_step_us(cost, brick_xy, nz,
                                            group.halo, 1)
        cand = 2
        while cand <= min(max_k, n_steps):
            legal = (n_steps % cand == 0
                     and (group.halo == 0
                          or cand * group.halo <= min(brick_xy)))
            if legal:
                t = predict_step_us(cost, brick_xy, nz, group.halo, cand)
                ts = predict_step_us(cost, brick_xy, nz, group.halo, cand,
                                     split=True)
                t = min(t, ts)
                if t < best_t:
                    best_k, best_t = cand, t
            cand *= 2
        return best_k
    cand = max_k
    while cand >= 2:
        if (cand <= n_steps and n_steps % cand == 0
                and (group.halo == 0
                     or 4 * cand * group.halo <= min(brick_xy))):
            return cand
        cand //= 2
    return 1


# ---------------------------------------------------------------------------
# interior/boundary region split (exchange/compute overlap)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One rectangular (X, Y) sub-region of a brick's output plane.

    ``(x0, y0)`` is the region origin in brick coordinates, ``(rx, ry)``
    its extent.  The fused kernel builder windows its launch to the region
    (:func:`repro.kernels.fused.build_fused_call` with ``region=``), so one
    loop body can be decomposed into several sub-launches whose outputs
    tile the brick exactly.
    """

    x0: int
    y0: int
    rx: int
    ry: int


@dataclasses.dataclass(frozen=True)
class SplitRegions:
    """Interior/boundary decomposition of one tiled launch.

    ``interior`` is the deep region at distance ``≥ m = k·h`` from every
    brick edge: its depth-``m`` input window is contained in the brick, so
    the launch depends on **no** incoming halo data and can run while the
    margin exchange is still in flight.  ``shells`` are the four boundary
    rectangles covering the rest of the brick (two full-height X slabs +
    two X-interior Y strips); their windows reach into the refreshed
    margins, so they launch only once the exchanged slabs have landed.
    The five output regions partition the brick — no cell is written twice.
    """

    interior: RegionSpec
    shells: Tuple[RegionSpec, ...]


def split_regions(group: LoweredGroup, k: int, brick_xy: Tuple[int, int]
                  ) -> SplitRegions:
    """Interior/boundary split of a ``k``-tiled launch, or ``None``.

    Returns ``None`` when there is nothing to overlap: halo-free bodies
    (no exchange to hide) and bricks too small to keep a nonempty interior
    at depth ``m = k·h`` (``bx ≤ 2m`` or ``by ≤ 2m``).  The legality mirror
    of :func:`tile_group`'s bound — a brick that admits the split also
    admits the tile.
    """
    m = k * group.halo
    if m == 0:
        return None
    bx, by = brick_xy
    if bx <= 2 * m or by <= 2 * m:
        return None
    interior = RegionSpec(m, m, bx - 2 * m, by - 2 * m)
    shells = (
        RegionSpec(0, 0, m, by),                 # low-X slab (full Y)
        RegionSpec(bx - m, 0, m, by),            # high-X slab
        RegionSpec(m, 0, bx - 2 * m, m),         # low-Y strip
        RegionSpec(m, by - m, bx - 2 * m, m),    # high-Y strip
    )
    return SplitRegions(interior=interior, shells=shells)


# ---------------------------------------------------------------------------
# multigrid: level-indexed operators + inter-grid transfer ops
# ---------------------------------------------------------------------------

#: Smallest grid extent that still admits one coarsening step: the coarse
#: grid ``n//2 + 1`` must keep at least one interior cell (n_c >= 3).
MG_MIN_DIM = 5


@dataclasses.dataclass(frozen=True)
class TransferStencil:
    """One inter-grid transfer op in canonical form.

    The multigrid analogue of :class:`AffineUpdate`: instead of taps on one
    grid, a transfer reads one level and writes the next.  ``kind`` selects
    the fixed weight stencil — ``"restrict"`` is 27-point full weighting
    (tensor product of (1/4, 1/2, 1/4) per axis, weights summing to 1) and
    ``"prolong"`` is trilinear interpolation (its transpose up to the factor
    8).  Vertex alignment is *even*: coarse cell ``I`` sits on fine cell
    ``2I``, so the coarse Moat plane coincides with the fine domain boundary
    on the low side exactly.  Codegen lowers each transfer to one Pallas
    kernel (:mod:`repro.kernels.transfer`), cached per (kind, shapes, dtype).
    """

    kind: str                         # "restrict" | "prolong"
    fine_shape: Tuple[int, int, int]
    coarse_shape: Tuple[int, int, int]

    def __post_init__(self):
        if self.kind not in ("restrict", "prolong"):
            raise LoweringError(f"unknown transfer kind {self.kind!r}")
        if coarsen_shape(self.fine_shape) != tuple(self.coarse_shape):
            raise LoweringError(
                f"transfer shapes disagree: coarsening {self.fine_shape} "
                f"gives {coarsen_shape(self.fine_shape)}, not "
                f"{tuple(self.coarse_shape)}")


@dataclasses.dataclass(frozen=True)
class MGOperator:
    """Constant-coefficient operator stencil of one multigrid level.

    The level-indexed program form: ``A x = Σ c_d · x[cell + d]`` over the
    full (X, Y, Z) interior, identity on the Moat.  ``taps`` maps integer
    offsets ``(dz, dx, dy)`` to coefficients; the hierarchy is produced by
    :func:`coarsen_operator` and each level is unparsed back into a recorded
    program (smoother / residual bodies) that lowers through the ordinary
    IR → codegen path — one kernel cache entry per level.
    """

    shape: Tuple[int, int, int]       # (nx, ny, nz) of this level's grid
    taps: Tuple[Tuple[Tuple[int, int, int], float], ...]  # sorted offset->c

    @property
    def diag(self) -> float:
        for off, c in self.taps:
            if off == (0, 0, 0):
                return c
        raise LoweringError("mg operator has no diagonal (center) tap")


def coarsen_shape(shape) -> Tuple[int, ...]:
    """Shape of the next-coarser grid: coarse cell I on fine cell 2I, so
    ``n_c = n//2 + 1`` (Moat planes included) for every extent."""
    return tuple(int(n) // 2 + 1 for n in shape)


def coarsenable(shape) -> bool:
    """True when every extent admits one more coarsening (>= MG_MIN_DIM)."""
    return all(int(n) >= MG_MIN_DIM for n in shape)


def mg_fine_operator(group: LoweredGroup, answer: str,
                     shape: Tuple[int, int, int]) -> MGOperator:
    """Validate a lowered operator body for geometric multigrid.

    Re-discretization only makes sense for operators whose off-diagonal
    part scales like a second-order term (h⁻²), which the tap form can
    guarantee only for *symmetric constant-coefficient* stencils updating
    the full interior; anything else raises :class:`LoweringError` with the
    reason (the solver turns that into a clear error or a logged fallback).
    """
    if group is None:
        raise LoweringError(
            "mg needs an affine-lowerable operator body (this one runs on "
            "the interpreter fallback)")
    if len(group.updates) != 1:
        raise LoweringError(
            f"mg needs a single-update operator body, got "
            f"{len(group.updates)} updates")
    u = group.updates[0]
    nz = shape[2]
    if (u.z0, u.zlen) != (1, nz - 2):
        raise LoweringError(
            f"mg needs the operator to update the full interior z window "
            f"[1, {nz - 1}); it updates [{u.z0}, {u.z0 + u.zlen})")
    taps: Dict[Tuple[int, int, int], float] = {}
    for coeff, tps in u.terms:
        if len(tps) != 1 or tps[0].field != answer:
            raise LoweringError(
                "mg needs a constant-coefficient operator (every term one "
                "tap of the unknown); variable-coefficient products cannot "
                "be re-discretized geometrically")
        t = tps[0]
        off = (t.dz, t.dx, t.dy)
        if max(abs(t.dz), abs(t.dx), abs(t.dy)) > 1:
            raise LoweringError(
                f"mg supports taps within the 27-point neighbourhood; tap "
                f"{off} reaches further (re-discretization would change the "
                "coarse stencil radius)")
        taps[off] = taps.get(off, 0.0) + coeff
    for (dz, dx, dy), c in taps.items():
        if (dz, dx, dy) == (0, 0, 0):
            continue
        mirror = taps.get((-dz, -dx, -dy))
        if mirror is None or abs(mirror - c) > 1e-12 * max(1.0, abs(c)):
            raise LoweringError(
                f"mg needs a symmetric operator stencil; tap {(dz, dx, dy)} "
                f"(coeff {c}) has no matching mirror tap")
    if (0, 0, 0) not in taps:
        raise LoweringError("mg operator has no diagonal (center) tap")
    return MGOperator(shape=tuple(shape), taps=tuple(sorted(taps.items())))


def coarsen_operator(op: MGOperator) -> MGOperator:
    """Re-discretize an operator one level coarser.

    Row-sum decomposition: ``A = s·I + L`` with ``s = Σ c_d`` (the zeroth-
    order / mass part, grid-independent) and ``L = A − s·I`` (zero row sum —
    the second-order part, scaling as h⁻²).  Doubling the spacing quarters
    ``L`` while the integer tap offsets stay fixed:

        A_2h = s·I + L_h / 4

    which matches the Galerkin operator of full-weighting/trilinear
    transfers to O(h²) for symmetric stencils — the classic geometric
    coarse-grid operator, derived from the recorded taps alone.
    """
    if not coarsenable(op.shape):
        raise LoweringError(
            f"grid {op.shape} is not coarsenable: every extent must be "
            f">= {MG_MIN_DIM} so the coarse grid keeps an interior")
    s = sum(c for _, c in op.taps)
    coarse = []
    for off, c in op.taps:
        if off == (0, 0, 0):
            coarse.append((off, s + (c - s) / 4.0))
        else:
            coarse.append((off, c / 4.0))
    return MGOperator(shape=coarsen_shape(op.shape), taps=tuple(coarse))


def mg_hierarchy(op: MGOperator, max_levels: int = None) -> List[MGOperator]:
    """The level-indexed operator sequence, finest first.

    Coarsens while every extent stays >= :data:`MG_MIN_DIM` (and below
    ``max_levels`` when given).  Raises :class:`LoweringError` if the fine
    grid admits no coarsening at all — one level is relaxation, not mg.
    """
    if not coarsenable(op.shape):
        raise LoweringError(
            f"grid {op.shape} is not coarsenable: mg needs every extent "
            f">= {MG_MIN_DIM}")
    levels = [op]
    while coarsenable(levels[-1].shape):
        if max_levels is not None and len(levels) >= max_levels:
            break
        levels.append(coarsen_operator(levels[-1]))
    return levels


# ---------------------------------------------------------------------------
# expression → polynomial-in-taps
# ---------------------------------------------------------------------------

_Poly = Dict[Tuple[Tap, ...], float]   # () key holds the constant addend


def _poly_add(a: _Poly, b: _Poly, sign: float = 1.0) -> _Poly:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + sign * v
    return out


def _poly_mul(a: _Poly, b: _Poly) -> _Poly:
    out: _Poly = {}
    for ka, va in a.items():
        for kb, vb in b.items():
            k = tuple(sorted(ka + kb))
            if len(k) > MAX_TAPS:
                raise LoweringError(
                    f"product of {len(k)} field taps is non-affine "
                    f"(degree > {MAX_TAPS}): {k}")
            out[k] = out.get(k, 0.0) + va * vb
    return out


def _to_poly(e: st.StencilExpr, target_z: slice) -> _Poly:
    if isinstance(e, st.Const):
        return {(): e.value}
    if isinstance(e, st.Term):
        dz = st.zslice_delta(e.zslice_obj(), target_z)
        return {(Tap(e.field_name, dz, e.dx, e.dy),): 1.0}
    if isinstance(e, st.BinOp):
        lhs = _to_poly(e.lhs, target_z)
        rhs = _to_poly(e.rhs, target_z)
        if e.op == "add":
            return _poly_add(lhs, rhs)
        if e.op == "sub":
            return _poly_add(lhs, rhs, sign=-1.0)
        if e.op == "mul":
            return _poly_mul(lhs, rhs)
        if e.op == "div":
            if set(rhs) - {()}:
                raise LoweringError("division by a field expression is "
                                    "non-affine")
            d = rhs.get((), 0.0)
            if d == 0.0:
                raise LoweringError("division by constant zero")
            return {k: v / d for k, v in lhs.items()}
        raise LoweringError(f"unknown binop {e.op!r}")
    raise LoweringError(f"cannot lower expression node {type(e).__name__}")


def lower_update(op) -> AffineUpdate:
    """Lower one recorded ``UpdateOp`` (normalized slices) to tap form."""
    target = op.target_z
    poly = _to_poly(op.expr, target)
    const = poly.pop((), 0.0)
    terms = tuple(sorted(
        (coeff, taps) for taps, coeff in poly.items() if coeff != 0.0))
    z0, z1 = target.start, target.stop
    if z0 is None or z0 < 0:
        raise LoweringError("target z slice is not normalized")
    return AffineUpdate(field=op.field_name, z0=z0, zlen=z1 - z0,
                        const=const, terms=terms)


def lower_group(ops: Sequence) -> LoweredGroup:
    """Lower a loop body's ops; reject cross-tile reads of updated fields.

    Within one fused kernel a block only sees its *own* updated values, so an
    op that reads a field written by an *earlier* op of the same loop body
    through a nonzero (dx, dy) offset cannot be fused — neighbouring blocks'
    updates are not visible until the next kernel launch.  (dz offsets are
    fine: the Z column is block-local, the paper's 1×1×Z decomposition.)
    """
    updates = []
    written: List[str] = []
    for op in ops:
        u = lower_update(op)
        for t in u.taps():
            if t.field in written and (t.dx or t.dy):
                raise LoweringError(
                    f"op writing {u.field!r} reads {t.field!r} at offset "
                    f"(dx={t.dx}, dy={t.dy}) after it was updated earlier in "
                    "the same loop body; cross-tile read-after-write cannot "
                    "be fused")
        updates.append(u)
        if u.field not in written:
            written.append(u.field)
    halo = 0
    for u in updates:
        for t in u.taps():
            halo = max(halo, abs(t.dx), abs(t.dy))
    return LoweredGroup(updates=tuple(updates), halo=halo)


def transpose_taps(group: LoweredGroup, answer: str) -> LoweredGroup:
    """Adjoint of a lowered linear operator: transpose the tap set.

    For a linear operator body in canonical form — every term one tap of
    the unknown ``answer`` at offset ``o_x``, optionally times a coefficient
    tap at ``o_c`` — the transposed stencil follows from re-indexing the
    bilinear form ``<y, A x>``: the unknown tap moves to ``-o_x`` and the
    coefficient tap to ``o_c - o_x``::

        c * C[q + o_c] * x[q + o_x]   →   c * C[p + o_c - o_x] * x[p - o_x]

    (X/Y offsets are periodic — the roll semantics every backend
    implements — and the Moat/z-window row masking is the *same* for the
    adjoint: the identity rows of ``A`` transpose to identity rows plus a
    boundary-column correction the adjoint solver applies outside the
    Krylov loop, see :mod:`repro.solver.adjoint`.)

    The result is re-canonicalized exactly like :func:`lower_update`
    (taps sorted, like terms merged, terms sorted), so a symmetric tap set
    maps to a ``LoweredGroup`` that compares **equal** to the input — and
    therefore hits the *same* kernel-cache entry in
    :func:`repro.compiler.codegen.compile_group`.  Transposing twice is the
    identity on canonical groups.

    Raises :class:`LoweringError` for bodies that are not linear in
    ``answer`` (constant addend, affine-shift terms, products of unknown
    taps) — those have no well-defined operator transpose.
    """
    updates = []
    for u in group.updates:
        if u.field != answer:
            raise LoweringError(
                f"transpose_taps: update writes {u.field!r}, not the "
                f"unknown {answer!r}")
        if u.const != 0.0:
            raise LoweringError(
                f"transpose_taps: operator has a constant addend "
                f"({u.const}); A(x) must be linear in the unknown")
        poly: dict = {}
        for coeff, taps in u.terms:
            unknown = [t for t in taps if t.field == answer]
            if len(unknown) != 1:
                raise LoweringError(
                    "transpose_taps: term is not linear in the unknown "
                    f"({len(unknown)} taps of {answer!r})")
            x = unknown[0]
            rest = list(taps)
            rest.remove(x)
            new = [Tap(answer, -x.dz, -x.dx, -x.dy)] + [
                Tap(t.field, t.dz - x.dz, t.dx - x.dx, t.dy - x.dy)
                for t in rest
            ]
            key = tuple(sorted(new))
            poly[key] = poly.get(key, 0.0) + coeff
        terms = tuple(sorted(
            (coeff, taps) for taps, coeff in poly.items() if coeff != 0.0))
        updates.append(AffineUpdate(field=u.field, z0=u.z0, zlen=u.zlen,
                                    const=0.0, terms=terms))
    halo = 0
    for u in updates:
        for t in u.taps():
            halo = max(halo, abs(t.dx), abs(t.dy))
    return LoweredGroup(updates=tuple(updates), halo=halo)

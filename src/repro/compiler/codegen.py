"""Codegen pass + kernel cache for the WFA program compiler.

``compile_group`` turns one loop body's lowered :class:`LoweredGroup` into a
``step(env) -> env`` function around exactly one fused ``pl.pallas_call``
(built by :func:`repro.kernels.fused.build_fused_call`).  Kernels are
memoized by *program signature* — the lowered tap form plus field
shapes/dtypes and block/interpret settings — so re-making an identical
program (the WFA's repeated ``make_WSE`` workflow) reuses the compiled
kernel; :data:`stats` exposes build/hit/fallback counters for tests and
benchmarks.

Two integration points:

* :func:`compile_group` — single device.  Inputs are wrap-padded with
  ``jnp.pad`` so out-of-domain taps reproduce the interpreter's ``jnp.roll``
  semantics bit-for-bit (wrap-around only ever lands in Moat cells for
  depth-1 stencils; for wider stencils the backends still agree because both
  wrap).
* :func:`compile_group_sharded` — inside ``shard_map``.  The brick is
  halo-padded with ``core.halo.halo_pad`` (ICI ppermute) and the kernel's
  Moat mask is driven by the brick's mesh coordinates.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compiler.ir import LoweredGroup, LoweringError, lower_group

log = logging.getLogger("repro.compiler")


@dataclasses.dataclass
class CompilerStats:
    """Counters for the fused-kernel pipeline (reset with ``reset_stats``)."""

    groups_fused: int = 0      # loop bodies routed to a fused kernel
    kernels_built: int = 0     # distinct pallas_call sites constructed
    cache_hits: int = 0        # loop bodies served from the kernel cache
    fallbacks: int = 0         # loop bodies routed to the interpreter
    fallback_reasons: Tuple[str, ...] = ()

    def note_fallback(self, reason: str) -> None:
        self.fallbacks += 1
        self.fallback_reasons = self.fallback_reasons + (reason,)


stats = CompilerStats()

_KERNEL_CACHE: Dict[tuple, object] = {}


def reset_stats() -> None:
    # mutate in place so `from repro.compiler import stats` stays live
    stats.groups_fused = 0
    stats.kernels_built = 0
    stats.cache_hits = 0
    stats.fallbacks = 0
    stats.fallback_reasons = ()


def clear_cache() -> None:
    _KERNEL_CACHE.clear()


def try_compile(compile_fn, loop):
    """Shared fallback policy for both pallas backends (single + sharded).

    Runs ``compile_fn()``; on :class:`LoweringError` counts the fallback,
    logs the reason, and returns ``None`` so the caller substitutes its
    interpreter step.  Keeping the policy here stops the two call sites from
    diverging in accounting or log wording.
    """
    try:
        return compile_fn()
    except LoweringError as e:
        stats.note_fallback(str(e))
        log.warning(
            "pallas lowering failed for loop %r: %s — falling back to the "
            "interpreter for this body", getattr(loop, "name", None), e)
        return None


def _field_specs(group: LoweredGroup, shapes: Dict[str, tuple],
                 dtypes: Dict[str, object]):
    """Ordered name -> (nz, dtype); validates a common (X, Y) extent."""
    names = list(group.fields_written())
    for n in group.fields_read():
        if n not in names:
            names.append(n)
    base_xy = shapes[names[0]][:2]
    for n in names:
        if shapes[n][:2] != base_xy:
            raise LoweringError(
                f"fields {names[0]!r} {shapes[names[0]]} and {n!r} "
                f"{shapes[n]} disagree in (X, Y); cannot fuse")
    specs = {n: (shapes[n][2], dtypes[n]) for n in names}
    return specs, base_xy


def _get_kernel(group: LoweredGroup, specs, bx, by, nx, ny, block, interpret,
                time_tile, wrap, margin=0, batch=1, region=None):
    from repro.kernels.fused import build_fused_call
    sig = (group, tuple((n, s[0], jnp.dtype(s[1]).name) for n, s in
                        specs.items()), bx, by, nx, ny, tuple(block),
           bool(interpret), int(time_tile), bool(wrap), int(margin),
           int(batch), region)
    hit = _KERNEL_CACHE.get(sig)
    if hit is not None:
        stats.cache_hits += 1
        return hit
    # one cache entry per (signature, batch, region): the builder itself is
    # batch-independent (the per-member kernel is vmapped over the leading
    # axis at the step layer), but keying on B means one warm entry serves
    # the whole fleet of that ensemble width — the bench gate "one compile
    # per plan signature" stays truthful for batched plans.  ``region`` tags
    # the overlap scheduler's windowed interior launch (None = whole brick).
    kernel = build_fused_call(group.updates, specs, group.halo, bx, by,
                              nx, ny, block=block, interpret=interpret,
                              time_tile=time_tile, wrap=wrap, margin=margin,
                              region=region)
    stats.kernels_built += 1
    _KERNEL_CACHE[sig] = kernel
    return kernel


def compile_transfer(kind: str, fine_shape, coarse_shape, dtype,
                     interpret: bool = False):
    """Build (and cache) one inter-grid transfer kernel for a level pair.

    ``kind`` is ``"restrict"`` (full-weighting, fine → coarse) or
    ``"prolong"`` (trilinear, coarse → fine); the canonical form is
    :class:`repro.compiler.ir.TransferStencil`, which validates the shape
    pair, and the kernels live in :mod:`repro.kernels.transfer`.  Cached in
    the same signature-keyed kernel cache as the fused stencil kernels —
    one entry per (kind, level-pair shapes, dtype).
    """
    from repro.compiler.ir import TransferStencil
    from repro.kernels import transfer as ktransfer

    ts = TransferStencil(kind, tuple(fine_shape), tuple(coarse_shape))
    sig = ("transfer", ts, jnp.dtype(dtype).name, bool(interpret))
    hit = _KERNEL_CACHE.get(sig)
    if hit is not None:
        stats.cache_hits += 1
        return hit
    if kind == "restrict":
        kernel = ktransfer.build_restrict_call(
            ts.fine_shape, ts.coarse_shape, dtype, interpret=interpret)
    else:
        kernel = ktransfer.build_prolong_call(
            ts.coarse_shape, ts.fine_shape, dtype, interpret=interpret)
    stats.kernels_built += 1
    _KERNEL_CACHE[sig] = kernel
    return kernel


def _build_overlap_step(group, specs, bx, by, nx, ny, block, interpret,
                        time_tile, wrap, margin, batch, split,
                        coords_fn, slabs_fn):
    """One interior/boundary-split step for the exchange/compute overlap.

    The schedule both pallas backends share (single device substitutes wrap
    slabs for the ppermute exchange):

    1. **exchange in flight** — the depth-``k·h`` margin slabs are extracted
       (``slabs_fn``) into their own buffers, the *double-buffered margins*:
       the transfer never aliases the resident buffers the interior launch
       is writing in place, so ``input_output_aliases`` stays valid.
    2. **interior launch** — the region at distance ``≥ k·h`` from every
       brick edge steps ``k`` sub-steps off a window contained in the brick:
       no margin reads, so nothing orders it after the exchange and the
       scheduler is free to run both concurrently.
    3. **boundary launches** — once the slabs land, each shell region's
       padded window is assembled from the **pre-step** buffers + landed
       slabs (:func:`repro.engine.layout.strip_window` — bitwise the window
       a refreshed monolithic launch would read) and stepped by its own
       small kernel; outputs splice into the written buffers.

    Every launch reuses the monolithic kernel machinery (same per-cell tap
    arithmetic, same Moat masking from global coordinates), which is why
    the split output is bitwise-equal to the fused monolithic kernel.
    """
    from repro.engine.layout import land_region, strip_window

    ph = time_tile * group.halo
    in_names = list(specs)
    interior, written = _get_kernel(group, specs, bx, by, nx, ny, block,
                                    interpret, time_tile, wrap,
                                    margin=margin, batch=batch,
                                    region=split.interior)
    shells = [
        _get_kernel(group, specs, r.rx, r.ry, nx, ny, block, interpret,
                    time_tile, wrap, margin=0, batch=batch)[0]
        for r in split.shells
    ]

    def _launch(kern, coords, ins):
        if batch > 1:
            return jax.vmap(lambda *a: kern(coords, *a))(*ins)
        return kern(coords, *ins)

    def step(env):
        env = dict(env)
        coords = coords_fn()
        slabs = {n: slabs_fn(env[n]) for n in in_names}
        ins = [env[n] for n in in_names]
        # pin the fusion boundary at the kernel inputs and the in-flight
        # slab buffers — the same barrier rule the monolithic paths use to
        # keep FMA contraction identical across margin producers
        flat = [s for n in in_names for s in slabs[n].values()]
        pinned = jax.lax.optimization_barrier(tuple(ins) + tuple(flat))
        ins = list(pinned[:len(in_names)])
        rest = iter(pinned[len(in_names):])
        slabs = {n: {key: next(rest) for key in slabs[n]} for n in in_names}
        ic = coords + jnp.array([[split.interior.x0, split.interior.y0]],
                                jnp.int32)
        outs = _launch(interior, ic, ins)
        new = dict(zip(in_names, ins))
        new.update(zip(written, outs))
        for r, kern in zip(split.shells, shells):
            wins = [strip_window(pre, slabs[n], margin, ph, r, bx, by)
                    for n, pre in zip(in_names, ins)]
            wins = list(jax.lax.optimization_barrier(tuple(wins)))
            sc = coords + jnp.array([[r.x0, r.y0]], jnp.int32)
            souts = _launch(kern, sc, wins)
            for name, so in zip(written, souts):
                new[name] = land_region(new[name], so, margin, r)
        env.update(new)
        return env

    return step


def compile_group(ops, shapes: Dict[str, tuple], dtypes: Dict[str, object],
                  block=(8, 128), interpret: bool = False, *,
                  time_tile: int = 1, group: LoweredGroup = None,
                  resident: int = 0, batch: int = 1, overlap: bool = False):
    """Lower + codegen one loop body for single-device execution.

    Returns ``step(env) -> env`` fusing all of ``ops`` into one pallas_call;
    with ``time_tile=k`` each call advances *k* steps off one wrap pad of
    depth ``k·h`` (validated by :func:`repro.compiler.ir.tile_group`).  Pass
    ``group=`` to reuse a lowering the planner already derived.  Raises
    :class:`LoweringError` when the body cannot be fused (the caller falls
    back to the interpreter and logs the reason).

    ``resident=K`` switches to the halo-resident protocol (the engine's
    :class:`~repro.engine.layout.HaloLayout`): ``env`` holds ``(nx + 2K,
    ny + 2K, nz)`` buffers, the step refreshes only the depth-``k·h`` wrap
    margin in place (:func:`repro.engine.layout.wrap_refresh` — four edge
    slabs, no full-array repack) and the kernel writes back into the same
    buffers via ``input_output_aliases``.  Bitwise identical to the
    repacking step at every precision: the kernel sees the same window
    values ``jnp.pad(mode="wrap")`` would have built.

    ``batch=B`` compiles an *ensemble* step: every env buffer carries a
    leading ``(B, ...)`` axis, the margin refresh / wrap pad and the
    barrier operate on the stacked arrays directly (they are rank-agnostic
    over leading axes), and only the fused ``pallas_call`` is ``jax.vmap``-
    wrapped over the members — so one launch advances all B scenarios and
    each member's arithmetic is bitwise identical to its ``batch=1`` run.
    The step is **not** built by vmapping the whole batch=1 step: the
    barrier that pins the resident/legacy bitwise guarantee has no batching
    rule, so batching is threaded below it instead.

    ``overlap=True`` (resident mode only) splits the launch into an interior
    kernel + four boundary shell kernels so the margin refresh overlaps the
    interior compute (see :func:`_build_overlap_step`); bodies whose brick
    is too small for a nonempty interior (or halo-free bodies) silently keep
    the monolithic launch.
    """
    from repro.compiler.ir import split_regions, tile_group

    if group is None:
        group = lower_group(ops)
    specs, (nx, ny) = _field_specs(group, shapes, dtypes)
    # same brick bound the planner clamps against; direct callers get the
    # validation too (a wrap pad deeper than the grid would be ill-formed)
    tiled = tile_group(group, time_tile, brick_xy=(nx, ny))
    ph = tiled.halo            # k·h margin, paid once per tile
    if resident and resident < ph:
        raise LoweringError(
            f"resident margin {resident} < tiled halo {ph}")
    if overlap and resident:
        split = split_regions(group, time_tile, (nx, ny))
        if split is not None:
            from repro.engine.layout import wrap_slabs

            coords0 = jnp.zeros((1, 2), jnp.int32)
            step = _build_overlap_step(
                group, specs, nx, ny, nx, ny, block, interpret, time_tile,
                True, resident, batch, split,
                coords_fn=lambda: coords0,
                slabs_fn=lambda buf: wrap_slabs(buf, resident, ph))
            stats.groups_fused += 1
            return step
    fused, written = _get_kernel(group, specs, nx, ny, nx, ny, block,
                                 interpret, time_tile, wrap=True,
                                 margin=resident, batch=batch)
    in_names = list(specs)
    coords = jnp.zeros((1, 2), jnp.int32)
    call = (jax.vmap(lambda *a: fused(coords, *a)) if batch > 1
            else (lambda *a: fused(coords, *a)))
    stats.groups_fused += 1

    if resident:
        from repro.engine.layout import wrap_refresh

        def step(env):
            env = dict(env)
            ins = [wrap_refresh(env[n], resident, ph) for n in in_names]
            # pin the fusion boundary at the kernel inputs: XLA otherwise
            # fuses the margin producer (refresh here, pad on the legacy
            # path) into the kernel's first ops, and the differing contexts
            # can flip FMA contraction — a ~1-ulp resident/legacy divergence.
            # Both paths barrier, so both compile the kernel identically and
            # the bitwise-equality guarantee holds at every precision.
            ins = list(jax.lax.optimization_barrier(tuple(ins)))
            outs = call(*ins)
            for name, inp in zip(in_names, ins):
                env[name] = inp  # refreshed margins (non-written fields)
            for name, out in zip(written, outs):
                env[name] = out
            return env

        return step

    def step(env):
        env = dict(env)
        padded = []
        for n in in_names:
            v = env[n]
            if ph:
                widths = ((0, 0),) * (v.ndim - 3) + (
                    (ph, ph), (ph, ph), (0, 0))
                v = jnp.pad(v, widths, mode="wrap")
            padded.append(v)
        padded = list(jax.lax.optimization_barrier(tuple(padded)))
        outs = call(*padded)
        for name, out in zip(written, outs):
            env[name] = out
        return env

    return step


def compile_group_sharded(ops, shapes: Dict[str, tuple],
                          dtypes: Dict[str, object], *, mesh_xy, axis_names,
                          block=(8, 128), interpret: bool = False,
                          time_tile: int = 1, group: LoweredGroup = None,
                          resident: int = 0, batch: int = 1,
                          overlap: bool = False):
    """Lower + codegen one loop body for use *inside* ``shard_map``.

    ``shapes`` are the global field shapes; the returned ``step`` operates on
    the per-device brick env (halo-pads it with ppermute — depth ``k·h``
    when ``time_tile=k``, ONE exchange per k steps — then runs the same
    fused kernel with mesh-derived coordinates).

    ``resident=K`` switches to the halo-resident protocol: the brick env
    holds ``(bx + 2K, by + 2K, nz)`` buffers, the exchange moves only the
    four depth-``k·h`` margin slabs (:func:`repro.core.halo.halo_refresh` —
    same ppermute traffic, no concatenated repack) and the kernel writes in
    place via ``input_output_aliases``.  Bitwise identical to the repacking
    step at every precision.

    ``overlap=True`` (resident mode only) splits each launch into an
    interior kernel — concurrent with the margin slabs' ``ppermute``
    exchange, which it does not depend on — plus four boundary shell
    kernels fed by the landed slabs (:func:`_build_overlap_step`).  Bricks
    too small for a nonempty interior keep the monolithic launch.
    """
    from repro.compiler.ir import split_regions, tile_group
    from repro.core.halo import exchange_slabs, halo_pad, halo_refresh

    if group is None:
        group = lower_group(ops)
    specs, (nx, ny) = _field_specs(group, shapes, dtypes)
    mx, my = mesh_xy
    ax_x, ax_y = axis_names
    if nx % mx or ny % my:
        raise LoweringError(
            f"global extent ({nx},{ny}) not divisible by mesh ({mx},{my})")
    bx, by = nx // mx, ny // my
    tiled = tile_group(group, time_tile, brick_xy=(bx, by))
    ph = tiled.halo
    if resident and resident < ph:
        raise LoweringError(
            f"resident margin {resident} < tiled halo {ph}")

    def _coords():
        cx = jax.lax.axis_index(ax_x) * bx
        cy = jax.lax.axis_index(ax_y) * by
        return jnp.stack([cx, cy]).astype(jnp.int32).reshape(1, 2)

    if overlap and resident:
        split = split_regions(group, time_tile, (bx, by))
        if split is not None:
            step = _build_overlap_step(
                group, specs, bx, by, nx, ny, block, interpret, time_tile,
                False, resident, batch, split,
                coords_fn=_coords,
                slabs_fn=lambda buf: exchange_slabs(
                    buf, resident, ph, ax_x, ax_y, mx, my))
            stats.groups_fused += 1
            return step
    fused, written = _get_kernel(group, specs, bx, by, nx, ny, block,
                                 interpret, time_tile, wrap=False,
                                 margin=resident, batch=batch)
    in_names = list(specs)
    stats.groups_fused += 1

    def _call(coords, ins):
        # batched bricks: the exchange/barrier above already ran on the
        # stacked (B, ...) arrays; vmap only the per-member fused kernel
        # (coords are member-invariant, closed over)
        if batch > 1:
            return jax.vmap(lambda *a: fused(coords, *a))(*ins)
        return fused(coords, *ins)

    if resident:

        def step(env):
            env = dict(env)
            coords = _coords()
            ins = [halo_refresh(env[n], resident, ph, ax_x, ax_y, mx, my)
                   for n in in_names]
            ins = list(jax.lax.optimization_barrier(tuple(ins)))
            outs = _call(coords, ins)
            for name, inp in zip(in_names, ins):
                env[name] = inp
            for name, out in zip(written, outs):
                env[name] = out
            return env

        return step

    def step(env):
        env = dict(env)
        coords = _coords()
        padded = [env[n] if ph == 0 else
                  halo_pad(env[n], ph, ax_x, ax_y, mx, my)
                  for n in in_names]
        padded = list(jax.lax.optimization_barrier(tuple(padded)))
        outs = _call(coords, padded)
        for name, out in zip(written, outs):
            env[name] = out
        return env

    return step

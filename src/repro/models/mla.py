"""Multi-head Latent Attention (DeepSeek-V2 §2.1; also MiniCPM3).

Queries and KV project through low-rank latents; the decode cache stores only
the compressed latent ``c_kv`` (kv_lora_rank) plus the shared single-head
rotary key — 576 floats/token for deepseek-v2 instead of 32k for full MHA.

Two decode paths:

* naive (baseline): re-expand K/V from every cached latent each step — the
  faithful formulation, O(S·r·H·(dn+dv)) FLOPs per token;
* absorbed (``cfg.mla_absorbed``): fold ``W_uk`` into the query and ``W_uv``
  into the output projection so attention runs directly in latent space —
  O(S·r) per head-step.  A beyond-paper serving optimization; see
  EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, chunked_attention
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init
from repro.parallel import pshard


def mla_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, rq, dtype),
        "q_norm": rmsnorm_init(rq, dtype),
        "wq_b": dense_init(ks[1], rq, h * (dn + dr), dtype),
        "wkv_a": dense_init(ks[2], d, rkv + dr, dtype),
        "kv_norm": rmsnorm_init(rkv, dtype),
        "wkv_b": dense_init(ks[3], rkv, h * (dn + dv), dtype),
        "wo": dense_init(ks[4], h * dv, d, dtype),
    }


def _latents(params, x, cfg, pos):
    """x: (B,S,D) → q (B,S,H,dn+dr), c_kv (B,S,rkv), k_rope (B,S,1,dr)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(params["q_norm"], x @ params["wq_a"])
    q = (cq @ params["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_a = x @ params["wkv_a"]
    c_kv = rmsnorm(params["kv_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_rope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], pos,
                        cfg.rope_theta)
    return q, c_kv, k_rope


def _expand_kv(params, c_kv, cfg):
    """c_kv (..., rkv) → k_nope (..., H, dn), v (..., H, dv)."""
    h, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    kv = (c_kv @ params["wkv_b"]).reshape(*c_kv.shape[:-1], h, dn + dv)
    return kv[..., :dn], kv[..., dn:]


def mla_apply(params, x, cfg, pos):
    """Full-sequence MLA (training / prefill)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q, c_kv, k_rope = _latents(params, x, cfg, pos)
    k_nope, v = _expand_kv(params, c_kv, cfg)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    q = pshard(q.reshape(b, s, h, 1, dn + dr), "batch", "seq", "heads",
               None, None)
    k = pshard(k, "batch", "seq", "heads", None)
    out = chunked_attention(q, k, v, pos, pos, window=None,
                            scale=(dn + dr) ** -0.5)
    out = out.reshape(b, s, h * dv)
    return out @ params["wo"]


class MLACache(NamedTuple):
    c_kv: jax.Array      # (B, S_max, rkv)
    k_rope: jax.Array    # (B, S_max, dr)


def mla_decode(params, x, cache: MLACache, cfg, pos):
    """One-token decode over the compressed cache."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos_arr = jnp.asarray(pos, jnp.int32)[None]
    q, c_new, kr_new = _latents(params, x, cfg, pos_arr)

    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new[:, :, 0].astype(cache.k_rope.dtype), (0, pos, 0))
    c_kv = pshard(c_kv, "cache_batch", "cache_seq", None)
    k_rope = pshard(k_rope, "cache_batch", "cache_seq", None)

    s_max = c_kv.shape[1]
    scale = (dn + dr) ** -0.5
    q_nope, q_rope = q[:, 0, :, :dn], q[:, 0, :, dn:]   # (B,H,dn),(B,H,dr)
    idx = jnp.arange(s_max)
    mask = (idx <= pos)[None, None, :]

    if cfg.mla_absorbed:
        # fold W_uk into q: scores in latent space, context stays latent.
        wkv_b = params["wkv_b"].reshape(cfg.kv_lora_rank, h, dn + dv)
        w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, w_uk)          # (B,H,rkv)
        s_ = (jnp.einsum("bhr,bsr->bhs", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
        p = jax.nn.softmax(jnp.where(mask, s_, NEG_INF), axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)      # (B,H,rkv)
        out = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), w_uv)
    else:
        k_nope, v = _expand_kv(params, c_kv, cfg)                 # (B,S,H,·)
        s_ = (jnp.einsum("bhd,bshd->bhs", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
        p = jax.nn.softmax(jnp.where(mask, s_, NEG_INF), axis=-1)
        out = jnp.einsum("bhs,bshv->bhv", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32).astype(x.dtype)

    out = out.reshape(b, 1, h * dv)
    return out @ params["wo"], MLACache(c_kv, k_rope)

"""Mixture-of-Experts: top-k router + capacity-bounded sorted dispatch.

Covers mixtral (8 experts, top-2) and deepseek-v2 (2 shared + 160 routed,
top-6).  Dispatch is the sort-based formulation: per data-parallel group,
token→expert assignments are ranked inside each expert with an argsort +
searchsorted pass, written into an (E, C, D) buffer (unique slots; dropped
tokens add zeros), processed with one grouped einsum per projection and
combined back with the gate weights.

Sharding: the (G, E, C, D) dispatch buffer is group-sharded on entry and
expert-sharded (`experts` logical axis) for the einsums — under GSPMD that
boundary lowers to the canonical MoE all-to-all.  mixtral (E=8 < mesh model
axis) instead keeps experts replicated and shards each expert's d_ff
(`expert_mlp` → 'model'), selected per-config via sharding overrides.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _ACTS, dense_init, mlp_apply, mlp_init
from repro.parallel import pshard


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, m.n_experts, dtype, scale=0.02),
        "w_gate": _experts_init(ks[1], m.n_experts, d, m.d_expert, dtype),
        "w_up": _experts_init(ks[2], m.n_experts, d, m.d_expert, dtype),
        "w_down": _experts_init(ks[3], m.n_experts, m.d_expert, d, dtype),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], d, m.n_shared * m.d_expert, dtype,
                               gated=True)
    return p


def _experts_init(key, e, d_in, d_out, dtype):
    import math
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def _route(logits, k: int, norm_topk: bool):
    """logits (T, E) → (weights (T,k), experts (T,k), probs (T,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    if norm_topk:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, probs


def _dispatch_group(x, topw, topi, n_experts: int, capacity: int):
    """One DP group.  x (T, D); topw/topi (T, k) → (buf (E,C,D), meta)."""
    t, d = x.shape
    k = topi.shape[-1]
    n = t * k
    eid = topi.reshape(n)
    wgt = topw.reshape(n)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(eid, stable=True)
    s_eid, s_tok, s_wgt = eid[order], tok[order], wgt[order]
    first = jnp.searchsorted(s_eid, s_eid, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < capacity
    slot = s_eid * capacity + jnp.minimum(rank, capacity - 1)

    vals = x[s_tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((n_experts * capacity, d), x.dtype).at[slot].add(vals)
    return buf.reshape(n_experts, capacity, d), (s_tok, s_wgt, slot, keep)


def _combine_group(y_buf, meta, t: int, d: int):
    s_tok, s_wgt, slot, keep = meta
    y = y_buf.reshape(-1, y_buf.shape[-1])[slot]
    y = y * (s_wgt * keep).astype(y.dtype)[:, None]
    return jnp.zeros((t, d), y.dtype).at[s_tok].add(y)


def moe_apply(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B,S,D), aux load-balance loss (scalar))."""
    m = cfg.moe
    b, s, d = x.shape
    logits = x @ params["router"]
    topw, topi, probs = _route(logits.reshape(b * s, m.n_experts), m.top_k,
                               m.norm_topk)

    # route per-sequence group: keeps gather/scatter local under DP sharding
    capacity = int(s * m.top_k / m.n_experts * m.capacity_factor) + 1
    capacity = -(-capacity // 8) * 8                   # pad to sublane

    def group(xg, wg, ig):
        buf, meta = _dispatch_group(xg, wg, ig, m.n_experts, capacity)
        return buf, meta

    bufs, metas = jax.vmap(group)(
        x, topw.reshape(b, s, m.top_k), topi.reshape(b, s, m.top_k))

    bufs = pshard(bufs, "batch", "experts", None, "embed")
    act = _ACTS[m.act]
    h = act(jnp.einsum("becd,edf->becf", bufs, params["w_gate"])) \
        * jnp.einsum("becd,edf->becf", bufs, params["w_up"])
    y_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y_buf = pshard(y_buf, "batch", "experts", None, "embed")

    out = jax.vmap(lambda yb, meta: _combine_group(yb, meta, s, d))(
        y_buf, metas)
    out = out.astype(x.dtype)

    if m.n_shared:
        out = out + mlp_apply(params["shared"], x, act=m.act)

    # Switch-style load-balance aux loss
    pe = probs.mean(axis=0)                                     # (E,)
    onehot = jax.nn.one_hot(topi[:, 0], m.n_experts, dtype=jnp.float32)
    fe = onehot.mean(axis=0)
    aux = m.n_experts * jnp.sum(pe * fe)
    return out, aux

"""Shared layer primitives: norms, rotary embeddings, MLPs, embeddings.

Parameters are plain dict pytrees; every layer is an (init, apply) pair of
functions.  ``init`` takes an ``jax.random`` key and returns the param dict;
``apply`` is functional.  Compute dtype and param dtype come from the config
(bf16/bf16 for production rooflines, fp32 for CPU smoke tests).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (Qwen3 / Chameleon): x is (..., head_dim)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(rot_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                            / rot_dim))


def apply_rope(x, pos, theta: float = 1e4, fraction: float = 1.0):
    """Rotate the first ``fraction`` of head_dim; interleaved-pair convention.

    x: (..., S, H, D) — the head axis is required (use H=1 for single-head
    rope streams such as MLA's shared k_rope).  pos: (..., S) int32.
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    if rot == 0:
        return x
    rot -= rot % 2
    freqs = rope_freqs(rot, theta)                       # (rot/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    angles = angles[..., None, :]                        # broadcast over H
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, d: int, d_ff: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(params, x, act: str = "silu"):
    f = _ACTS[act]
    up = x @ params["up"]
    if "gate" in params:
        up = f(x @ params["gate"]) * up
    else:
        up = f(up)
    return up @ params["down"]

"""Block definitions + per-kind (init, apply, decode, cache) dispatch.

Every block kind is pre-norm residual.  ``mamba_shared`` is the zamba2
shared-attention step: a Mamba2 block followed by the globally-shared
attention+MLP block applied to ``concat(x, x_embed)`` (params live once at
model level and are passed in by closure).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_apply, mlp_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def block_init(key, kind: str, cfg, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if kind in ("attn", "attn_moe"):
        p = {"ln1": rmsnorm_init(d, dtype), "ln2": rmsnorm_init(d, dtype),
             "attn": attn.attn_init(ks[0], cfg, dtype)}
        if kind == "attn_moe":
            p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, dtype,
                                gated=cfg.gated_mlp)
        return p
    if kind in ("mla", "mla_moe"):
        p = {"ln1": rmsnorm_init(d, dtype), "ln2": rmsnorm_init(d, dtype),
             "attn": mla_mod.mla_init(ks[0], cfg, dtype)}
        if kind == "mla_moe":
            p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, dtype,
                                gated=cfg.gated_mlp)
        return p
    if kind == "rwkv":
        return {"ln1": rmsnorm_init(d, dtype), "ln2": rmsnorm_init(d, dtype),
                "tm": rwkv_mod.rwkv_init(ks[0], cfg, dtype),
                "cm": rwkv_mod.rwkv_ffn_init(ks[1], cfg, dtype)}
    if kind in ("mamba", "mamba_shared"):
        return {"ln1": rmsnorm_init(d, dtype),
                "ssm": ssm_mod.ssm_init(ks[0], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind}")


def shared_block_init(key, cfg, dtype):
    """zamba2 shared attention+MLP over concat(x, x_embed) (width 2D)."""
    import dataclasses
    d2 = 2 * cfg.d_model
    acfg = dataclasses.replace(
        cfg, d_model=d2, n_heads=cfg.shared_n_heads,
        n_kv_heads=cfg.shared_n_heads, head_dim=d2 // cfg.shared_n_heads,
        qk_norm=False, sliding_window=None, rope_fraction=1.0)
    ks = jax.random.split(key, 4)
    return {
        "ln1": rmsnorm_init(d2, dtype), "ln2": rmsnorm_init(d2, dtype),
        "attn": attn.attn_init(ks[0], acfg, dtype),
        "mlp": mlp_init(ks[1], d2, cfg.shared_d_ff, dtype, gated=True),
        "out": (jax.random.normal(ks[2], (d2, cfg.d_model), jnp.float32)
                / jnp.sqrt(d2)).astype(dtype),
    }, acfg


# ---------------------------------------------------------------------------
# apply (training / prefill)
# ---------------------------------------------------------------------------

def block_apply(kind: str, params, x, cfg, pos, shared=None, x_embed=None):
    """Returns (x, aux) where aux is the MoE load-balance loss (or 0)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_moe", "mla", "mla_moe"):
        h = rmsnorm(params["ln1"], x)
        if kind.startswith("mla"):
            h = mla_mod.mla_apply(params["attn"], h, cfg, pos)
        else:
            h = attn.attn_apply(params["attn"], h, cfg, pos)
        x = x + h
        h = rmsnorm(params["ln2"], x)
        if kind.endswith("moe"):
            h, aux = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, act=cfg.mlp_act)
        return x + h, aux
    if kind == "rwkv":
        h, _ = rwkv_mod.rwkv_time_mix(params["tm"],
                                      rmsnorm(params["ln1"], x), cfg)
        x = x + h
        h, _ = rwkv_mod.rwkv_channel_mix(params["cm"],
                                         rmsnorm(params["ln2"], x))
        return x + h, aux
    if kind in ("mamba", "mamba_shared"):
        x = x + ssm_mod.ssm_apply(params["ssm"],
                                  rmsnorm(params["ln1"], x), cfg)
        if kind == "mamba_shared":
            sp, acfg = shared
            xc = jnp.concatenate([x, x_embed], axis=-1)
            h = rmsnorm(sp["ln1"], xc)
            h = attn.attn_apply(sp["attn"], h, acfg, pos)
            xc = xc + h
            h = mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], xc), act="silu")
            x = x + (xc + h) @ sp["out"]
        return x, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache init + decode
# ---------------------------------------------------------------------------

def cache_init(kind: str, cfg, batch: int, s_max: int, dtype):
    """Single-layer cache pytree (stacked by the caller's scan)."""
    if kind in ("attn", "attn_moe"):
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        return attn.KVCache(
            jnp.zeros((batch, s_max, kv, hd), dtype),
            jnp.zeros((batch, s_max, kv, hd), dtype))
    if kind in ("mla", "mla_moe"):
        return mla_mod.MLACache(
            jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
            jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype))
    if kind == "rwkv":
        d = cfg.d_model
        hk = d // cfg.n_heads
        return rwkv_mod.RWKVState(
            jnp.zeros((batch, d), dtype), jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, cfg.n_heads, hk, hk), jnp.float32))
    if kind in ("mamba", "mamba_shared"):
        s = cfg.ssm
        conv_dim = s.d_inner + 2 * s.d_state
        st = ssm_mod.SSMState(
            jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
            jnp.zeros((batch, s.n_heads, s.d_state, s.headdim), jnp.float32))
        if kind == "mamba_shared":
            d2 = 2 * cfg.d_model
            hd2 = d2 // cfg.shared_n_heads
            return {"ssm": st, "shared_kv": attn.KVCache(
                jnp.zeros((batch, s_max, cfg.shared_n_heads, hd2), dtype),
                jnp.zeros((batch, s_max, cfg.shared_n_heads, hd2), dtype))}
        return st
    raise ValueError(kind)


def block_decode(kind: str, params, x, cache, cfg, pos, shared=None,
                 x_embed=None):
    """One-token step.  x: (B, 1, D) → (x, new_cache)."""
    if kind in ("attn", "attn_moe", "mla", "mla_moe"):
        h = rmsnorm(params["ln1"], x)
        if kind.startswith("mla"):
            h, cache = mla_mod.mla_decode(params["attn"], h, cache, cfg, pos)
        else:
            h, cache = attn.attn_decode(params["attn"], h, cache, cfg, pos)
        x = x + h
        h = rmsnorm(params["ln2"], x)
        if kind.endswith("moe"):
            h, _ = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, act=cfg.mlp_act)
        return x + h, cache
    if kind == "rwkv":
        h, cache = rwkv_mod.rwkv_time_mix_decode(
            params["tm"], rmsnorm(params["ln1"], x), cache, cfg)
        x = x + h
        h, cache = rwkv_mod.rwkv_channel_mix_decode(
            params["cm"], rmsnorm(params["ln2"], x), cache)
        return x + h, cache
    if kind in ("mamba", "mamba_shared"):
        st = cache["ssm"] if kind == "mamba_shared" else cache
        h, st = ssm_mod.ssm_decode(params["ssm"],
                                   rmsnorm(params["ln1"], x), st, cfg, pos)
        x = x + h
        if kind == "mamba_shared":
            sp, acfg = shared
            xc = jnp.concatenate([x, x_embed], axis=-1)
            h = rmsnorm(sp["ln1"], xc)
            h, kv = attn.attn_decode(sp["attn"], h, cache["shared_kv"],
                                     acfg, pos)
            xc = xc + h
            h = mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], xc), act="silu")
            x = x + (xc + h) @ sp["out"]
            return x, {"ssm": st, "shared_kv": kv}
        return x, st
    raise ValueError(kind)

"""RWKV6 "Finch" — attention-free time mixing with data-dependent decay.

Implements the v6 time-mix (DDLerp token-shift, LoRA-conditioned per-channel
decay ``w_t = exp(−exp(w0 + tanh(x·A)·B))``, bonus ``u``) and channel-mix.
The WKV recurrence

    S_t = diag(w_t)·S_{t−1} + k_t v_tᵀ ;   y_t = r_tᵀ·(S_{t−1} + diag(u)·k_t v_tᵀ)

is evaluated in chunks (GLA-style): within a chunk it is a decay-weighted
lower-triangular attention; across chunks a scan carries the (H, K, V) state.
This is the structural cousin of the paper's time-marching field update —
state advances locally, no reductions (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm_init
from repro.parallel import pshard


def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    lora = cfg.rwkv_lora
    ks = jax.random.split(key, 12)
    return {
        # DDLerp token-shift: 5 streams (r, k, v, w, g)
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "ts_a": dense_init(ks[1], d, 5 * lora, dtype, scale=0.01),
        "ts_b": (jax.random.normal(ks[2], (5, lora, d), jnp.float32)
                 * 0.01).astype(dtype),
        # decay LoRA
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_a": dense_init(ks[3], d, lora * 2, dtype, scale=0.01),
        "w_b": (jax.random.normal(ks[4], (lora * 2, d), jnp.float32)
                * 0.01).astype(dtype),
        "u": jnp.zeros((d,), jnp.float32),
        "wr": dense_init(ks[5], d, d, dtype),
        "wk": dense_init(ks[6], d, d, dtype),
        "wv": dense_init(ks[7], d, d, dtype),
        "wg": dense_init(ks[8], d, d, dtype),
        "wo": dense_init(ks[9], d, d, dtype),
        "ln_x": rmsnorm_init(d, dtype),      # per-head group norm surrogate
    }


def rwkv_ffn_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def _ddlerp(params, x, xx):
    """Data-dependent interpolation between x and shifted xx → 5 streams."""
    base = xx - x                                        # (B,S,D)
    mix = x + base * params["mu"][:, None, None, :]      # (5,B,S,D)
    lora = jnp.tanh(x @ params["ts_a"])                  # (B,S,5·L)
    lora = lora.reshape(*x.shape[:-1], 5, -1)            # (B,S,5,L)
    dyn = jnp.einsum("bsfl,fld->fbsd", lora, params["ts_b"])
    return mix + dyn * base[None]


def _decay(params, xw):
    """Per-channel log-decay (≤0): log w = −exp(w0 + tanh(x·A)·B)."""
    lo = jnp.tanh(xw @ params["w_a"]) @ params["w_b"]
    return -jnp.exp(params["w0"] + lo.astype(jnp.float32))


def wkv_chunked(r, k, v, logw, u, n_heads: int, chunk: int = 64):
    """Chunked WKV6.  r,k,v (B,S,D); logw (B,S,D) ≤ 0; u (D,).

    Heads split D into (H, K) with K = D // H; V = K.
    Returns (B, S, D) and needs no state input (train path starts at zero).
    """
    b, s, d = r.shape
    hk = d // n_heads
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c

    def hshape(x):
        return x.reshape(b, nc, c, n_heads, hk)

    rr, kk, vv = hshape(r.astype(jnp.float32)), hshape(k.astype(jnp.float32)), hshape(v.astype(jnp.float32))
    lw = hshape(logw)
    uu = u.reshape(n_heads, hk)

    cl = jnp.cumsum(lw, axis=2)                          # (B,nc,c,H,K)
    # A[i,j] = (r_i ⊙ exp(cl_{i-1}))·(k_j ⊙ exp(−cl_j)) for j < i
    r_dec = rr * jnp.exp(cl - lw)                        # exp(cl_{i-1})
    k_dec = kk * jnp.exp(-cl)
    scores = jnp.einsum("bzihk,bzjhk->bzhij", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)         # strictly lower
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bzihk,bzihk->bzhi", rr * uu[None, None, None], kk)
    y_intra = (jnp.einsum("bzhij,bzjhv->bzihv", scores, vv)
               + diag[..., None].swapaxes(2, 3) * vv)

    # chunk-state: S_z = Σ_j diag(exp(cl_c − cl_j)) k_j ⊗ v_j
    tail = jnp.exp(cl[:, :, -1:, :, :] - cl)             # (B,nc,c,H,K)
    s_chunk = jnp.einsum("bzjhk,bzjhv->bzhkv", kk * tail, vv)
    g_chunk = jnp.exp(cl[:, :, -1])                      # (B,nc,H,K)

    def carry(S, inp):
        s_z, g = inp                                     # (B,H,K,V), (B,H,K)
        return S * g[..., None] + s_z, S

    S0 = jnp.zeros((b, n_heads, hk, hk), jnp.float32)
    _, S_prev = jax.lax.scan(carry, S0, (s_chunk.swapaxes(0, 1),
                                         g_chunk.swapaxes(0, 1)))
    S_prev = S_prev.swapaxes(0, 1)                       # (B,nc,H,K,V)
    y_inter = jnp.einsum("bzihk,bzhkv->bzihv", r_dec, S_prev)
    y = y_intra + y_inter
    return y.reshape(b, s, d)


class RWKVState(NamedTuple):
    tm_shift: jax.Array   # (B, D) last token (time-mix)
    cm_shift: jax.Array   # (B, D) last token (channel-mix)
    wkv: jax.Array        # (B, H, K, V) fp32


def rwkv_time_mix(params, x, cfg, shift_state=None):
    """x (B,S,D) → (B,S,D); shift_state (B,D) carries the previous token."""
    b, s, d = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((b, d), x.dtype)
    xx = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(params, x, xx)
    # head-sharded projections: constrain so the WKV chunk math stays local
    # per head (no activation all-gathers — §Perf rwkv iteration 1)
    r = pshard(xr @ params["wr"], "batch", "seq", "heads")
    k = pshard(xk @ params["wk"], "batch", "seq", "heads")
    v = pshard(xv @ params["wv"], "batch", "seq", "heads")
    g = jax.nn.silu(pshard(xg @ params["wg"], "batch", "seq", "heads"))
    logw = pshard(_decay(params, xw), "batch", "seq", "heads")
    y = wkv_chunked(r, k, v, logw, params["u"], cfg.n_heads, cfg.rwkv_chunk)
    # per-head group norm ≈ rmsnorm over head dim
    hk = d // cfg.n_heads
    yh = y.reshape(b, s, cfg.n_heads, hk)
    var = jnp.mean(yh * yh, axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + 1e-6)
    y = (yh.reshape(b, s, d) * params["ln_x"]["scale"].astype(jnp.float32))
    return (y.astype(x.dtype) * g) @ params["wo"], x[:, -1, :]


def rwkv_channel_mix(params, x, shift_state=None):
    b, s, d = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((b, d), x.dtype)
    xx = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (xx - x) * params["mu_k"]
    xr = x + (xx - x) * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    if x.shape[1] > 1:
        # train/prefill: constrain so GSPMD contracts locally + all-reduces
        # (0.5 GB) instead of all-gathering k (3.7 GB).  At decode (S=1) the
        # same constraint flips GSPMD into gathering the 235 MB weight —
        # measured regression — so it is sequence-length gated.
        k = pshard(k, "batch", "seq", "mlp")
        down = pshard(k @ params["wv"], "batch", "seq", "embed")
    else:
        down = k @ params["wv"]
    return jax.nn.sigmoid(xr @ params["wr"]) * down, x[:, -1, :]


def rwkv_time_mix_decode(params, x, state: RWKVState, cfg):
    """One token.  x (B, 1, D)."""
    b, _, d = x.shape
    xx = state.tm_shift[:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(params, x, xx)
    r = (xr @ params["wr"]).astype(jnp.float32)
    k = (xk @ params["wk"]).astype(jnp.float32)
    v = (xv @ params["wv"]).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"])
    w = jnp.exp(_decay(params, xw))                      # (B,1,D)
    hk = d // cfg.n_heads
    rh = r.reshape(b, cfg.n_heads, hk)
    kh = k.reshape(b, cfg.n_heads, hk)
    vh = v.reshape(b, cfg.n_heads, hk)
    wh = w.reshape(b, cfg.n_heads, hk)
    uh = params["u"].reshape(cfg.n_heads, hk)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, state.wkv + uh[None, ..., None] * kv)
    S = state.wkv * wh[..., None] + kv
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6)
    y = (y.reshape(b, 1, d) * params["ln_x"]["scale"].astype(jnp.float32))
    out = (y.astype(x.dtype) * g) @ params["wo"]
    return out, RWKVState(x[:, -1, :], state.cm_shift, S)


def rwkv_channel_mix_decode(params, x, state: RWKVState):
    y, last = rwkv_channel_mix(params, x, state.cm_shift)
    return y, RWKVState(state.tm_shift, last, state.wkv)

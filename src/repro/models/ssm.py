"""Mamba2 (SSD) block — the zamba2 backbone.

Chunked state-space-duality formulation: within a chunk the recurrence is an
attention-like masked einsum; across chunks a scan carries the (H, P, N)
state.  Decode carries (conv_state, ssm_state) and advances in O(1).

Shapes: d_inner = expand·d_model, H = d_inner / headdim heads, state N,
single B/C group (n_groups=1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def ssm_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner
    h = s.n_heads
    conv_dim = di + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * s.d_state + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(proj, cfg):
    s = cfg.ssm
    di, n, h = s.d_inner, s.d_state, s.n_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d; xbc (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a_log, B, C, chunk: int = 128):
    """SSD scan.  x (B,S,H,P), dt (B,S,H) (post-softplus), B/C (B,S,N).

    Returns y (B,S,H,P).  a = exp(dt·A) with A = −exp(a_log).
    """
    bsz, seq, h, p = x.shape
    n = B.shape[-1]
    c = min(chunk, seq)
    while seq % c:
        c -= 1
    nc = seq // c

    A = -jnp.exp(a_log)                                  # (H,)
    la = (dt * A).reshape(bsz, nc, c, h)                 # log decay / step
    xd = (x * dt[..., None]).reshape(bsz, nc, c, h, p)   # dt-weighted input
    Bc = B.reshape(bsz, nc, c, n)
    Cc = C.reshape(bsz, nc, c, n)

    cl = jnp.cumsum(la, axis=2)                          # (B,nc,c,H)
    # intra-chunk: y[i] += Σ_{j≤i} (C_i·B_j)·exp(cl_i−cl_j)·xd_j
    scores = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)       # (B,nc,c,c)
    decay = jnp.exp(cl[:, :, :, None, :] - cl[:, :, None, :, :])  # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    m = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bzij,bzijh,bzjhp->bzihp", scores, m, xd)

    # chunk state: S_z = Σ_j exp(cl_c − cl_j)·B_j ⊗ xd_j   (B,nc,H,N,P)
    tail = jnp.exp(cl[:, :, -1:, :] - cl)                # (B,nc,c,H)
    s_chunk = jnp.einsum("bzjh,bzjn,bzjhp->bzhnp", tail, Bc, xd)
    chunk_decay = jnp.exp(cl[:, :, -1, :])               # (B,nc,H)

    def carry_fn(S, inp):
        s_z, g = inp                                     # (B,H,N,P), (B,H)
        S_new = S * g[..., None, None] + s_z
        return S_new, S

    S0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, S_prev = jax.lax.scan(
        carry_fn, S0,
        (s_chunk.swapaxes(0, 1).astype(jnp.float32),
         chunk_decay.swapaxes(0, 1)))
    S_prev = S_prev.swapaxes(0, 1)                       # (B,nc,H,N,P)

    # inter-chunk: y[i] += exp(cl_i)·C_i·S_prev
    y_inter = jnp.einsum("bzih,bzin,bzhnp->bzihp",
                         jnp.exp(cl), Cc, S_prev.astype(x.dtype))
    y = (y_intra + y_inter).reshape(bsz, seq, h, p)
    return y


class SSMState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_dim)
    ssm: jax.Array    # (B, H, N, P) fp32


def ssm_apply(params, x, cfg):
    """Training / prefill path.  x: (B, S, D) → (B, S, D)."""
    s = cfg.ssm
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :s.d_inner]
    B = xbc[..., s.d_inner:s.d_inner + s.d_state]
    C = xbc[..., s.d_inner + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    bsz, seq, _ = x.shape
    xh = xs.reshape(bsz, seq, s.n_heads, s.headdim)
    y = ssd_chunked(xh, dt, params["a_log"], B, C, chunk=s.chunk)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(bsz, seq, s.d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"]


def ssm_decode(params, x, state: SSMState, cfg, pos):
    """One-token decode.  x: (B, 1, D)."""
    s = cfg.ssm
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(proj[:, 0], cfg)            # (B, ·)
    conv_hist = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)
    w = params["conv_w"]
    xbc_c = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_hist, w) + params["conv_b"])
    new_conv = conv_hist[:, 1:, :]

    xs = xbc_c[..., :s.d_inner]
    B = xbc_c[..., s.d_inner:s.d_inner + s.d_state]
    C = xbc_c[..., s.d_inner + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt * A)                                  # (B,H)
    xh = xs.reshape(-1, s.n_heads, s.headdim)
    xd = xh * dt[..., None]
    S = (state.ssm * a[..., None, None]
         + jnp.einsum("bn,bhp->bhnp", B, xd.astype(jnp.float32)))
    y = jnp.einsum("bn,bhnp->bhp", C, S.astype(x.dtype))
    y = y + params["d_skip"][None, :, None].astype(x.dtype) * xh
    y = y.reshape(-1, 1, s.d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z[:, None, :]))
    return y @ params["out_proj"], SSMState(new_conv, S)

"""repro.models — LM substrate for the assigned architecture pool.

Pure-JAX, dict-pytree parameters, scan-over-layers.  Entry points live in
:mod:`repro.models.model`: ``init_params``, ``forward``, ``loss_fn``,
``init_cache``, ``prefill``, ``decode_step``.
"""

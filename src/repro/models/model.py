"""Model-level API: init, forward/loss, prefill, decode — scan-over-layers.

Parameters::

    {"embed": (V, D) | (K, V, D),
     "segments": [per-segment stacked block params (leading dim = count)],
     "shared": zamba2 shared block (unstacked) | absent,
     "final_ln": rmsnorm,
     "lm_head": (D, V) | (K, D, V) | absent (tied)}

Each segment is scanned (`jax.lax.scan`) so HLO size and compile time are
O(#segments), not O(#layers) — this is what makes 60-layer/160-expert
dry-runs on a 512-fake-device CPU host tractable, and is the production
choice anyway.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import embed_init, rmsnorm, rmsnorm_init
from repro.parallel import pshard


def _dtype(name: str):
    return jnp.dtype(name)


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = _dtype(cfg.param_dtype)
    k_embed, k_seg, k_shared, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        params["embed"] = jnp.stack([
            embed_init(k, cfg.vocab_size, cfg.d_model, dtype)
            for k in jax.random.split(k_embed, cfg.n_codebooks)])
    else:
        params["embed"] = embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                     dtype)

    segs = []
    seg_keys = jax.random.split(k_seg, len(cfg.segments))
    for (kind, count), sk in zip(cfg.segments, seg_keys):
        layers = [tfm.block_init(k, kind, cfg, dtype)
                  for k in jax.random.split(sk, count)]
        segs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
    params["segments"] = segs

    if any(kind == "mamba_shared" for kind, _ in cfg.segments):
        params["shared"], _ = tfm.shared_block_init(k_shared, cfg, dtype)

    params["final_ln"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            params["lm_head"] = jnp.stack([
                embed_init(k, cfg.d_model, cfg.vocab_size, dtype)
                for k in jax.random.split(k_head, cfg.n_codebooks)])
        else:
            params["lm_head"] = embed_init(k_head, cfg.d_model,
                                           cfg.vocab_size, dtype)
    return params


def _shared_ctx(params, cfg):
    if "shared" not in params:
        return None
    _, acfg = tfm.shared_block_init(jax.random.PRNGKey(0), cfg, "float32")
    return (params["shared"], acfg)


def _embed(params, tokens, cfg):
    if cfg.n_codebooks > 1:                      # (B, S, K) EnCodec frames
        x = params["embed"][0][tokens[..., 0]]
        for k in range(1, cfg.n_codebooks):
            x = x + params["embed"][k][tokens[..., k]]
        return x
    return params["embed"][tokens]


def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward(params, tokens, cfg, *, last_only: bool = False):
    """Causal forward.  tokens (B, S[, K]) → logits (B, S|1, V[, K])."""
    cdt = _dtype(cfg.compute_dtype)
    x = _embed(params, tokens, cfg).astype(cdt)
    x = pshard(x, "batch", "seq", "embed")
    x_embed = x
    seq = x.shape[1]
    pos = jnp.arange(seq, dtype=jnp.int32)
    shared = _shared_ctx(params, cfg)
    if shared is not None:
        shared = (jax.tree.map(lambda a: a.astype(cdt), shared[0]), shared[1])

    aux_total = jnp.zeros((), jnp.float32)
    for (kind, count), seg in zip(cfg.segments, params["segments"]):
        def body(x, layer, kind=kind):
            layer = jax.tree.map(lambda a: a.astype(cdt), layer)
            x, aux = tfm.block_apply(kind, layer, x, cfg, pos,
                                     shared=shared, x_embed=x_embed)
            return x, aux
        if cfg.scan_layers:
            x, auxs = jax.lax.scan(_remat(body, cfg), x, seg)
            aux_total = aux_total + auxs.sum()
        else:                         # flat calibration mode
            for i in range(count):
                layer = jax.tree.map(lambda a: a[i], seg)
                x, aux = _remat(body, cfg)(x, layer)
                aux_total = aux_total + aux

    x = rmsnorm(params["final_ln"], x)
    if last_only:
        x = x[:, -1:, :]
    logits = _lm_head(params, x, cfg)
    return logits, aux_total


def _lm_head(params, x, cfg):
    cdt = x.dtype
    if cfg.n_codebooks > 1:
        return jnp.einsum("bsd,kdv->bskv", x,
                          params["lm_head"].astype(cdt))
    if cfg.tie_embeddings:
        return x @ params["embed"].astype(cdt).T
    return x @ params["lm_head"].astype(cdt)


def loss_fn(params, batch, cfg):
    """batch: {tokens (B,S[,K]), labels (B,S[,K])} → (loss, metrics)."""
    logits, aux = forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:
        logp = jax.nn.log_softmax(logits, axis=-1)       # (B,S,K,V)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)
        ce = -ll.mean()
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)
        ce = -ll.mean()
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, s_max: int):
    cdt = _dtype(cfg.compute_dtype)
    caches = []
    for kind, count in cfg.segments:
        one = tfm.cache_init(kind, cfg, batch, s_max, cdt)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (count,) + a.shape), one))
    return caches


def decode_step(params, cache, tokens, pos, cfg):
    """One token for the whole batch.  tokens (B, 1[, K]); pos scalar."""
    cdt = _dtype(cfg.compute_dtype)
    x = _embed(params, tokens, cfg).astype(cdt)
    x_embed = x
    shared = _shared_ctx(params, cfg)
    if shared is not None:
        shared = (jax.tree.map(lambda a: a.astype(cdt), shared[0]), shared[1])

    new_cache = []
    for (kind, count), seg, cch in zip(cfg.segments, params["segments"],
                                       cache):
        def body(x, layer_cache, kind=kind):
            layer, lc = layer_cache
            layer = jax.tree.map(lambda a: a.astype(cdt), layer)
            x, lc = tfm.block_decode(kind, layer, x, lc, cfg, pos,
                                     shared=shared, x_embed=x_embed)
            return x, lc
        if cfg.scan_layers:
            x, cch2 = jax.lax.scan(body, x, (seg, cch))
        else:                         # flat calibration mode
            outs = []
            for i in range(count):
                layer = jax.tree.map(lambda a: a[i], seg)
                lc = jax.tree.map(lambda a: a[i], cch)
                x, lc2 = body(x, (layer, lc))
                outs.append(lc2)
            cch2 = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache.append(cch2)

    x = rmsnorm(params["final_ln"], x)
    logits = _lm_head(params, x, cfg)
    return logits, new_cache


def prefill(params, tokens, cfg, s_max: int):
    """Run the prompt, return (last-token logits, filled cache).

    Layer-by-layer (unscanned) python loop over segments with scanned
    layers; attention/MLA caches are written at positions [0, S); recurrent
    states carry their end-of-prompt value.
    """
    cdt = _dtype(cfg.compute_dtype)
    b, s = tokens.shape[0], tokens.shape[1]
    x = _embed(params, tokens, cfg).astype(cdt)
    x_embed = x
    pos = jnp.arange(s, dtype=jnp.int32)
    shared = _shared_ctx(params, cfg)
    if shared is not None:
        shared = (jax.tree.map(lambda a: a.astype(cdt), shared[0]), shared[1])

    caches = []
    for (kind, count), seg in zip(cfg.segments, params["segments"]):
        def body(x, layer, kind=kind):
            layer = jax.tree.map(lambda a: a.astype(cdt), layer)
            x, lc = _block_prefill(kind, layer, x, cfg, pos, s_max,
                                   shared=shared, x_embed=x_embed)
            return x, lc
        x, lcs = jax.lax.scan(body, x, seg)
        caches.append(lcs)

    x = rmsnorm(params["final_ln"], x[:, -1:, :])
    return _lm_head(params, x, cfg), caches


def _block_prefill(kind, params, x, cfg, pos, s_max, shared=None,
                   x_embed=None):
    """block_apply + cache capture (see transformer.block_decode)."""
    from repro.models import attention as attn_mod
    from repro.models import mla as mla_mod
    from repro.models import rwkv as rwkv_mod
    from repro.models import ssm as ssm_mod
    from repro.models.layers import mlp_apply

    b, s, d = x.shape
    cdt = x.dtype

    def pad_cache(arr):
        pad = [(0, 0)] * arr.ndim
        pad[1] = (0, s_max - s)
        return jnp.pad(arr, pad)

    if kind in ("attn", "attn_moe"):
        h = rmsnorm(params["ln1"], x)
        q, k, v = attn_mod._project_qkv(params["attn"], h, cfg, pos)
        out = attn_mod.chunked_attention(q, k, v, pos, pos,
                                         window=cfg.sliding_window)
        out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + out @ params["attn"]["wo"]
        h = rmsnorm(params["ln2"], x)
        if kind.endswith("moe"):
            from repro.models import moe as moe_mod
            h, _ = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, act=cfg.mlp_act)
        cache = attn_mod.KVCache(pad_cache(k), pad_cache(v))
        return x + h, cache
    if kind in ("mla", "mla_moe"):
        h = rmsnorm(params["ln1"], x)
        q, c_kv, k_rope = mla_mod._latents(params["attn"], h, cfg, pos)
        k_nope, v = mla_mod._expand_kv(params["attn"], c_kv, cfg)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (b, s, cfg.n_heads, cfg.qk_rope_dim))], axis=-1)
        out = attn_mod.chunked_attention(
            q.reshape(b, s, cfg.n_heads, 1, -1), k, v, pos, pos, window=None,
            scale=(cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
        out = out.reshape(b, s, cfg.n_heads * cfg.v_head_dim)
        x = x + out @ params["attn"]["wo"]
        h = rmsnorm(params["ln2"], x)
        if kind.endswith("moe"):
            from repro.models import moe as moe_mod
            h, _ = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, act=cfg.mlp_act)
        cache = mla_mod.MLACache(pad_cache(c_kv), pad_cache(k_rope[:, :, 0]))
        return x + h, cache
    if kind == "rwkv":
        h = rmsnorm(params["ln1"], x)
        hh, tm_last = rwkv_mod.rwkv_time_mix(params["tm"], h, cfg)
        # recompute final wkv state for the cache
        S = _rwkv_final_state(params["tm"], h, cfg)
        x = x + hh
        h2 = rmsnorm(params["ln2"], x)
        hh, cm_last = rwkv_mod.rwkv_channel_mix(params["cm"], h2)
        cache = rwkv_mod.RWKVState(h[:, -1, :], h2[:, -1, :], S)
        return x + hh, cache
    if kind in ("mamba", "mamba_shared"):
        h = rmsnorm(params["ln1"], x)
        y, st = _ssm_prefill(params["ssm"], h, cfg)
        x = x + y
        if kind == "mamba_shared":
            sp, acfg = shared
            xc = jnp.concatenate([x, x_embed], axis=-1)
            hc = rmsnorm(sp["ln1"], xc)
            q, k, v = attn_mod._project_qkv(sp["attn"], hc, acfg, pos)
            out = attn_mod.chunked_attention(q, k, v, pos, pos, window=None)
            out = out.reshape(b, s, acfg.n_heads * acfg.head_dim)
            xc = xc + out @ sp["attn"]["wo"]
            hc = mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], xc), act="silu")
            x = x + (xc + hc) @ sp["out"]
            return x, {"ssm": st,
                       "shared_kv": attn_mod.KVCache(pad_cache(k),
                                                     pad_cache(v))}
        return x, st
    raise ValueError(kind)


def _rwkv_final_state(params, h, cfg):
    """End-of-prompt WKV state via a cheap rescan (B,H,K,V)."""
    from repro.models import rwkv as rwkv_mod
    b, s, d = h.shape
    xx = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = rwkv_mod._ddlerp(params, h, xx)
    k = (xk @ params["wk"]).astype(jnp.float32)
    v = (xv @ params["wv"]).astype(jnp.float32)
    logw = rwkv_mod._decay(params, xw)
    hk = d // cfg.n_heads
    kk = k.reshape(b, s, cfg.n_heads, hk)
    vv = v.reshape(b, s, cfg.n_heads, hk)
    lw = logw.reshape(b, s, cfg.n_heads, hk)
    cl = jnp.cumsum(lw, axis=1)
    tail = jnp.exp(cl[:, -1:, :, :] - cl)
    return jnp.einsum("bshk,bshv->bhkv", kk * tail, vv)


def _ssm_prefill(params, h, cfg):
    """ssm_apply + end state (conv tail + final SSD state)."""
    from repro.models import ssm as ssm_mod
    s = cfg.ssm
    proj = h @ params["in_proj"]
    z, xbc, dt = ssm_mod._split_proj(proj, cfg)
    xbc_c = ssm_mod._causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc_c[..., :s.d_inner]
    B = xbc_c[..., s.d_inner:s.d_inner + s.d_state]
    C = xbc_c[..., s.d_inner + s.d_state:]
    dtf = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    bsz, seq, _ = h.shape
    xh = xs.reshape(bsz, seq, s.n_heads, s.headdim)
    y = ssm_mod.ssd_chunked(xh, dtf, params["a_log"], B, C, chunk=s.chunk)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(bsz, seq, s.d_inner).astype(h.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    # final state: rerun decay accumulation over the whole sequence
    A = -jnp.exp(params["a_log"])
    la = dtf * A
    cl = jnp.cumsum(la, axis=1)                                 # (B,S,H)
    tail = jnp.exp(cl[:, -1:, :] - cl)
    xd = xh * dtf[..., None]
    S = jnp.einsum("bsh,bsn,bshp->bhnp", tail, B,
                   xd.astype(jnp.float32))
    conv_tail = xbc[:, -(s.d_conv - 1):, :]
    conv_tail = jnp.where(
        jnp.arange(s.d_conv - 1)[None, :, None] >= (s.d_conv - 1) - seq,
        conv_tail, 0.0) if seq < s.d_conv - 1 else conv_tail
    return out, ssm_mod.SSMState(conv_tail, S)

"""GQA/MHA attention: chunked (flash-style) training path + cached decode.

Features required by the assigned pool: grouped KV heads (GQA), per-head
qk-norm (qwen3 / chameleon), partial RoPE (glm4), sliding-window masks
(mixtral), full MHA (musicgen).  The training path streams KV in chunks with
an online softmax so 32k-token prefill never materialises an S×S score
matrix — the memory term of the roofline stays linear in S.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, head_rmsnorm
from repro.parallel import pshard

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, kv * hd, dtype),
         "wv": dense_init(ks[2], d, kv * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, x, cfg, pos):
    """x: (B, S, D) → q (B,S,KV,G,hd), k/v (B,S,KV,hd)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    q = (x @ params["wq"]).reshape(b, s, kv, g, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    if cfg.rope_fraction > 0:
        q = apply_rope(q.reshape(b, s, h, hd), pos, cfg.rope_theta,
                       cfg.rope_fraction).reshape(b, s, kv, g, hd)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def chunked_attention(q, k, v, q_pos, k_pos, *, window: Optional[int],
                      chunk_q: int = 512, chunk_k: int = 1024,
                      scale: Optional[float] = None):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, KV, G, hd);  k, v: (B, Sk, KV, hd);
    q_pos: (Sq,), k_pos: (Sk,) global positions (causal mask uses them).
    Returns (B, Sq, KV, G, hd).
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    hdv = v.shape[-1]                      # v head dim may differ (MLA)
    scale = scale if scale is not None else hd ** -0.5
    cq = min(chunk_q, sq)
    while sq % cq:
        cq -= 1
    ck = min(chunk_k, sk)
    while sk % ck:
        ck -= 1
    nq, nk = sq // cq, sk // ck

    qc = q.reshape(b, nq, cq, kvh, g, hd)
    kc = k.reshape(b, nk, ck, kvh, hd)
    vc = v.reshape(b, nk, ck, kvh, hdv)
    qp = q_pos.reshape(nq, cq)
    kp = k_pos.reshape(nk, ck)

    def per_q_chunk(args):
        qi, qpi = args                       # (B, cq, KV, G, hd), (cq,)
        qi = qi * scale

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, kpj = inp                # (B, ck, KV, hd), (ck,)
            s_ = jnp.einsum("bqkgd,bckd->bqkgc", qi, kj,
                            preferred_element_type=jnp.float32)
            mask = qpi[:, None] >= kpj[None, :]          # causal
            if window is not None:
                mask &= (qpi[:, None] - kpj[None, :]) < window
            s_ = jnp.where(mask[None, :, None, None, :], s_, NEG_INF)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, cq, kvh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cq, kvh, g), jnp.float32)
        a0 = jnp.zeros((b, cq, kvh, g, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kp))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(per_q_chunk, (qc.swapaxes(0, 1), qp))
    out = out.swapaxes(0, 1).reshape(b, sq, kvh, g, hdv)
    return out.astype(q.dtype)


def attn_apply(params, x, cfg, pos):
    """Full-sequence causal attention (training / prefill). x: (B, S, D)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(params, x, cfg, pos)
    q = pshard(q, "batch", "seq", "kv_heads", None, None)
    k = pshard(k, "batch", "seq", "kv_heads", None)
    out = chunked_attention(q, k, v, pos, pos, window=cfg.sliding_window)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"]


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, KV, hd)
    v: jax.Array


def attn_decode(params, x, cache: KVCache, cfg, pos):
    """One-token decode. x: (B, 1, D); pos: scalar int32 current position."""
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kvh
    pos_arr = jnp.asarray(pos, jnp.int32)[None]
    q, k_new, v_new = _project_qkv(params, x, cfg, pos_arr)

    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos, 0, 0))
    k = pshard(k, "cache_batch", "cache_seq", "cache_heads", None)
    v = pshard(v, "cache_batch", "cache_seq", "cache_heads", None)

    s_max = k.shape[1]
    scale = hd ** -0.5
    s_ = jnp.einsum("bkgd,bskd->bkgs", q[:, 0] * scale, k,
                    preferred_element_type=jnp.float32)
    idx = jnp.arange(s_max)
    mask = idx <= pos
    if cfg.sliding_window is not None:
        mask &= idx > pos - cfg.sliding_window
    s_ = jnp.where(mask[None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    return out @ params["wo"], KVCache(k, v)

"""The simulation service: async request serving over the unified engine.

``SimulationService`` is the always-on front end the ROADMAP's serving item
describes: a bounded admission queue feeding a pool of worker threads whose
plans (and therefore fused-kernel cache entries) are **pre-warmed** from a
persisted signature manifest, so steady-state requests never pay compile
latency — the serving-tier analogue of the WFA's amortized ``make_WSE``
workflow.

Request lifecycle (see ``docs/service.md`` for the narrated version)::

    submit ──admission──▶ queue ──signature group──▶ worker
                                                       │ plan cache (warm)
                                                       ▼
                            chunked resident stepping / Krylov solve
                              │ checkpoint every ckpt_every steps
                              │ fault ⇒ restore last snapshot, retry
                              ▼
                            ticket resolves (result + RequestStats)

Fault tolerance is layered exactly as :mod:`repro.runtime.fault` frames it:
the engine's step hook is where injected (or real) faults surface; the
worker restores the newest resident-state snapshot and continues with
bounded retries and exponential backoff; a :class:`HeartbeatMonitor` per
worker flags straggling chunks; and a body whose pallas compile fails is
served through the *logged* interpreter degraded mode — flagged on every
ticket it serves, never silent.

Numerical faults are the one failure class that is **never retried**: a
:class:`~repro.engine.health.NumericalFault` (failed guarded solve, or a
non-finite field state caught by the per-chunk sentinel) is deterministic
— restore-and-continue would repoison — so the worker fails the ticket
fast with the taxonomy word and :class:`~repro.engine.health.
RecoveryTrace` on ``Ticket.stats``, keeping the retry budget for the
infrastructure faults it can actually fix.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.engine import health as ehealth
from repro.engine.hooks import fire_step_hook
from repro.engine.stats import service_stats as _engine_service_stats
from repro.engine.stats import stats as estats
from repro.runtime.fault import HeartbeatMonitor
from repro.service.requests import (
    DeadlineExceeded,
    PlanSignature,
    RequestFailed,
    SolveRequest,
    StepRequest,
    Ticket,
)
from repro.service.scheduler import SignatureScheduler
from repro.service.workloads import (
    CompiledWorkload,
    build_workload,
    get_workload,
)

log = logging.getLogger("repro.service")

#: exceptions that retrying cannot fix (bad request, unknown workload)
_PERMANENT = (ValueError, KeyError, TypeError)


class SimulationService:
    """Async simulation serving over the compile-and-execute engine.

    ``workers`` threads serve signature-grouped requests from a bounded
    queue (``capacity``); ``manifest`` (a path or an iterable of
    :class:`PlanSignature`) pre-compiles the hot signatures at
    :meth:`start`; ``ckpt_root`` hosts per-request resident-state
    snapshots; ``default_chunk`` is the steps-per-launch granule requests
    are chunked into when they don't checkpoint.

    ``micro_batch=N`` (default 1 = off) turns the scheduler's signature
    groups into *ensemble launches*: up to N same-signature step requests
    (equal ``steps``, no checkpointing, no deadline) are coalesced into one
    batched plan — every kernel launch advances all of them at once, and
    each ticket gets its own member of the stacked result (its
    ``stats.batch`` records the coalesced width).  Any failure on the
    batched path falls back to serving the group individually.

    >>> svc = SimulationService(workers=1, capacity=8).start()
    >>> sig = PlanSignature("heat3d", (8, 8, 6))
    >>> t = svc.submit(StepRequest(sig, steps=4))
    >>> out = t.result(timeout=120)
    >>> out.shape, t.stats.retries
    ((8, 8, 6), 0)
    >>> svc.stop()
    """

    def __init__(
        self,
        workers: int = 2,
        capacity: int = 256,
        group_max: int = 16,
        manifest: Union[str, Iterable[PlanSignature], None] = None,
        ckpt_root: Optional[str] = None,
        default_chunk: int = 8,
        max_retries: int = 3,
        backoff_base: float = 0.02,
        backoff_cap: float = 1.0,
        straggler_threshold: float = 4.0,
        mesh=None,
        micro_batch: int = 1,
    ):
        if workers < 1:
            raise ValueError(f"workers must be >= 1; got {workers}")
        if default_chunk < 1:
            raise ValueError(f"default_chunk must be >= 1; got {default_chunk}")
        if micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1; got {micro_batch}")
        if micro_batch > 1 and mesh is not None:
            raise ValueError("micro-batching is single-device; drop mesh=")
        self.micro_batch = micro_batch
        self.default_chunk = default_chunk
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.straggler_threshold = straggler_threshold
        self.ckpt_root = ckpt_root
        self.mesh = mesh
        self.scheduler = SignatureScheduler(capacity=capacity, group_max=group_max)
        self._nworkers = workers
        self._threads: List[threading.Thread] = []
        self._plans: Dict[str, CompiledWorkload] = {}
        self._plans_lock = threading.Lock()
        self._slock = threading.Lock()  # guards the shared engine counters
        self._manifest_sigs = self._load_manifest(manifest)
        self._seen: Dict[str, PlanSignature] = {
            s.key(): s for s in self._manifest_sigs
        }
        self._started = False
        self._t_start: Optional[float] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "SimulationService":
        """Warm the manifest signatures, then open the worker pool."""
        if self._started:
            return self
        self.warm(self._manifest_sigs)
        for wid in range(self._nworkers):
            th = threading.Thread(
                target=self._worker_loop, args=(wid,),
                name=f"sim-worker-{wid}", daemon=True,
            )
            th.start()
            self._threads.append(th)
        self._started = True
        self._t_start = time.monotonic()
        return self

    def stop(self, wait: bool = True) -> None:
        """Close admission and (optionally) drain + join the workers."""
        self.scheduler.close()
        if wait:
            for th in self._threads:
                th.join()
        self._threads = []
        self._started = False

    def __enter__(self) -> "SimulationService":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -- manifest ------------------------------------------------------------
    @staticmethod
    def _load_manifest(manifest) -> List[PlanSignature]:
        if manifest is None:
            return []
        if isinstance(manifest, (str, os.PathLike)):
            if not os.path.exists(manifest):
                return []
            with open(manifest) as f:
                doc = json.load(f)
            return [PlanSignature.from_json(d) for d in doc["signatures"]]
        return list(manifest)

    def save_manifest(self, path: str) -> None:
        """Persist every signature this service has seen (submitted or
        warmed), so the next instance pre-compiles the same hot set.

        Schema 2 adds the per-signature ``batch`` field; schema-1 manifests
        (no ``schema`` key, no ``batch``) still load — absent batch reads
        as 1, the classic single-scenario signature.
        """
        doc = {"schema": 2, "signatures": [s.to_json() for s in self._seen.values()]}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)

    # -- plan cache ----------------------------------------------------------
    def warm(self, signatures: Sequence[PlanSignature]) -> None:
        """Pre-compile ``signatures``: build plan + kernels, then trace and
        run one default-chunk advance (or one solve) so even the XLA
        executable is hot before the first request lands."""
        for sig in signatures:
            cw = self._get_workload(sig, ticket=None)
            if cw.spec.kind == "step":
                m = self.default_chunk
                env = cw.advance(m)(cw.initial_env(None))
                jax.block_until_ready(list(env.values()))
            else:
                x0 = cw.spec.default_init(sig.shape, np.dtype(sig.dtype))
                if sig.batch > 1:
                    x0 = np.broadcast_to(x0, (sig.batch,) + x0.shape).copy()
                x = cw.solver("cg", 1e-6, 200)(x0)[0]
                jax.block_until_ready(x)
            log.info("warmed %s in %.3fs", sig.key(), cw.build_s)

    def _get_workload(self, sig: PlanSignature, ticket: Optional[Ticket]):
        with self._plans_lock:
            cw = self._plans.get(sig.key())
            if cw is not None:
                with self._slock:
                    estats.plan_cache_hits += 1
                if ticket is not None:
                    ticket.stats.plan_cache_hit = True
                return cw
            cw = build_workload(sig, mesh=self.mesh)
            self._plans[sig.key()] = cw
        if cw.degraded:
            log.warning(
                "signature %s serves DEGRADED via the interpreter: %s",
                sig.key(), cw.degraded_reason,
            )
        if ticket is not None:
            ticket.stats.compile_s = cw.build_s
        return cw

    # -- submission ----------------------------------------------------------
    def submit(self, request: Union[StepRequest, SolveRequest]) -> Ticket:
        """Admit a request; returns its :class:`Ticket` or raises
        :class:`~repro.service.requests.ServiceOverloaded` when the bounded
        queue is full (admission control — shed load at the door)."""
        get_workload(request.signature.workload)  # unknown name fails here
        if not self._started:
            raise RuntimeError("service not started; call start() first")
        ticket = Ticket(request)
        try:
            self.scheduler.submit(ticket)
        except Exception:
            with self._slock:
                estats.requests_rejected += 1
            raise  # ServiceOverloaded: the bounded queue is full
        with self._slock:
            estats.requests_admitted += 1
        self._seen.setdefault(request.signature.key(), request.signature)
        return ticket

    # -- workers -------------------------------------------------------------
    def _worker_loop(self, wid: int) -> None:
        # one monitor per signature: chunk durations are only comparable
        # within a compiled workload, and the monitor's start/end pairing
        # is single-threaded, so monitors live with the worker
        monitors: Dict[str, HeartbeatMonitor] = {}

        def monitor_for(sig: PlanSignature) -> HeartbeatMonitor:
            key = sig.key()
            if key not in monitors:
                monitors[key] = HeartbeatMonitor(
                    threshold=self.straggler_threshold,
                    on_straggler=lambda step, ratio: self._note_straggler(
                        wid, step, ratio
                    ),
                )
            return monitors[key]

        while True:
            group = self.scheduler.get_group(timeout=0.25)
            if not group:
                if self.scheduler._closed and not len(self.scheduler):
                    return
                self._collect_expired()
                continue
            for batch in self._coalesce(group):
                if len(batch) == 1:
                    self._serve(
                        batch[0], wid, monitor_for(batch[0].request.signature)
                    )
                else:
                    self._serve_batched(batch, wid, monitor_for)
            self._collect_expired()

    def _coalesce(self, group: List[Ticket]) -> List[List[Ticket]]:
        """Split one signature group into serve units: singletons, plus —
        when ``micro_batch > 1`` — ensemble batches of step requests that
        can share a launch (equal ``steps``, no checkpoint/resume, no
        deadline, single-member signature)."""
        if self.micro_batch <= 1 or len(group) < 2:
            return [[t] for t in group]

        def eligible(t: Ticket) -> bool:
            r = t.request
            return (
                isinstance(r, StepRequest)
                and r.ckpt_every == 0
                and not r.resume
                and r.deadline_s is None
                and r.signature.batch == 1
            )

        units: List[List[Ticket]] = []
        buckets: Dict[int, List[Ticket]] = {}
        for t in group:
            if eligible(t):
                buckets.setdefault(t.request.steps, []).append(t)
            else:
                units.append([t])
        for ts in buckets.values():
            while ts:
                unit, ts = ts[: self.micro_batch], ts[self.micro_batch:]
                units.append(unit)
        return units

    def _serve_batched(self, tickets: List[Ticket], wid: int, monitor_for):
        """Serve a coalesced unit as one batched launch sequence.

        The member requests share a plan built for
        ``replace(signature, batch=B)`` — same program, same kernels, one
        leading member axis — and each ticket resolves with its member of
        the stacked result.  Any failure falls back to the individual
        serve path (which has its own retry loop), so coalescing can only
        add throughput, never new failure modes.
        """
        B = len(tickets)
        reqs = [t.request for t in tickets]
        now = time.monotonic()
        for t in tickets:
            t.stats.worker = wid
            t.stats.started_s = now
            t.stats.queue_wait_s = now - t.stats.submitted_s
            t.stats.batch = B
        try:
            bsig = dataclasses.replace(reqs[0].signature, batch=B)
            cw = self._get_workload(bsig, tickets[0])
            for t in tickets[1:]:
                t.stats.plan_cache_hit = tickets[0].stats.plan_cache_hit
            self._seen.setdefault(bsig.key(), bsig)
            monitor = monitor_for(bsig)
            init = np.stack(
                [
                    np.asarray(r.init, dtype=bsig.dtype)
                    if r.init is not None
                    else cw.spec.default_init(bsig.shape, np.dtype(bsig.dtype))
                    for r in reqs
                ]
            )
            env = cw.initial_env(init)
            steps = reqs[0].steps
            seg = cw.segment
            k = seg.time_tile if seg.kind == "fused" else 1
            chunk = self.default_chunk
            if k > 1:
                chunk = max(k, (chunk // k) * k)
            step = chunks = launches = exchanges = 0
            while step < steps:
                m = min(chunk, steps - step)
                monitor.start_step(step)
                fire_step_hook(step, tag=reqs[0].request_id)
                env = cw.advance(m)(env)
                jax.block_until_ready(list(env.values()))
                monitor.end_step()
                step += m
                chunks += 1
                dl, dx = cw.chunk_accounting(m)
                launches += dl
                exchanges += dx
            out = cw.finalize(env)  # (B, X, Y, Z)
        except Exception as e:
            log.warning(
                "micro-batch of %d %s requests failed (%r); "
                "serving individually",
                B, reqs[0].signature.key(), e,
            )
            for t in tickets:
                t.stats.batch = 1
                self._serve(t, wid, monitor_for(t.request.signature))
            return
        fin = time.monotonic()
        repacks = 2 if cw.layout.pad > 0 else 0
        with self._slock:
            estats.queue_wait_s += sum(t.stats.queue_wait_s for t in tickets)
            estats.requests_completed += B
            estats.steps_run += steps * B
            estats.launches += launches
            estats.exchanges += exchanges
            estats.ensemble_runs += 1
            estats.ensemble_members += B
            if repacks:
                estats.repacks += repacks
                estats.resident_runs += 1
            if cw.degraded:
                estats.requests_degraded += B
        for i, t in enumerate(tickets):
            st = t.stats
            st.finished_s = fin
            st.exec_s = fin - st.started_s
            st.steps = steps
            st.chunks = chunks
            st.launches = launches
            st.exchanges = exchanges
            st.repacks = repacks
            if cw.degraded:
                st.degraded = True
                st.degraded_reason = cw.degraded_reason
            t._resolve(np.asarray(out[i]))

    def _collect_expired(self) -> None:
        with self._slock:
            n = len(self.scheduler.expired)
            if n:
                estats.requests_expired += n
                self.scheduler.expired.clear()

    def _note_straggler(self, wid: int, step: int, ratio: float) -> None:
        with self._slock:
            estats.service_stragglers += 1
        log.warning(
            "worker %d straggling at step %d (%.1fx trailing median)",
            wid, step, ratio,
        )

    def _serve(self, ticket: Ticket, wid: int, monitor: HeartbeatMonitor):
        req = ticket.request
        st = ticket.stats
        st.worker = wid
        st.started_s = time.monotonic()
        st.queue_wait_s = st.started_s - st.submitted_s
        with self._slock:
            estats.queue_wait_s += st.queue_wait_s
        if (
            req.deadline_s is not None
            and st.queue_wait_s > req.deadline_s
        ):
            st.finished_s = time.monotonic()
            with self._slock:
                estats.requests_expired += 1
            ticket._fail(
                DeadlineExceeded(
                    f"request {req.request_id} expired after "
                    f"{st.queue_wait_s:.3f}s in queue"
                )
            )
            return
        try:
            cw = self._get_workload(req.signature, ticket)
        except _PERMANENT as e:
            self._finish_fail(ticket, e)
            return
        if cw.degraded:
            st.degraded = True
            st.degraded_reason = cw.degraded_reason
        st.batch = max(st.batch, req.signature.batch)
        attempt = 0
        while True:
            try:
                if isinstance(req, StepRequest):
                    value = self._run_step(cw, req, ticket, monitor)
                else:
                    value = self._run_solve(cw, req, ticket)
                break
            except _PERMANENT as e:
                self._finish_fail(ticket, e)
                return
            except ehealth.NumericalFault as e:
                # deterministic numerical failure: a re-run would repoison,
                # so fail FAST — no retry, no backoff (unlike the injected
                # infrastructure faults below, which restore-and-continue)
                st.outcome = e.outcome or "NAN_RESIDUAL"
                if e.trace is not None:
                    st.recovery = e.trace.summary()
                with self._slock:
                    estats.numerical_faults += 1
                self._finish_fail(ticket, e)
                return
            except Exception as e:  # transient: restore-and-continue
                attempt += 1
                st.retries += 1
                with self._slock:
                    estats.request_retries += 1
                if attempt > self.max_retries:
                    self._finish_fail(
                        ticket,
                        RequestFailed(
                            f"request {req.request_id} failed after "
                            f"{self.max_retries} retries: {e!r}"
                        ),
                    )
                    return
                backoff = min(
                    self.backoff_cap, self.backoff_base * (2 ** (attempt - 1))
                )
                log.warning(
                    "request %s attempt %d failed (%r); retrying in %.3fs",
                    req.request_id, attempt, e, backoff,
                )
                time.sleep(backoff)
        st.finished_s = time.monotonic()
        st.exec_s = st.finished_s - st.started_s
        with self._slock:
            estats.requests_completed += 1
            if st.degraded:
                estats.requests_degraded += 1
        ticket._resolve(value)

    def _finish_fail(self, ticket: Ticket, error: BaseException) -> None:
        ticket.stats.finished_s = time.monotonic()
        with self._slock:
            estats.requests_failed += 1
        log.error("request %s failed: %s", ticket.request.request_id, error)
        ticket._fail(error)

    # -- step requests -------------------------------------------------------
    def _ckpt_manager(self, req: StepRequest) -> Optional[CheckpointManager]:
        if req.ckpt_every <= 0:
            return None
        root = self.ckpt_root or os.path.join(".", "service_ckpt")
        return CheckpointManager(
            os.path.join(root, req.ckpt_key or req.request_id), keep=2
        )

    def _restore_env(self, cw: CompiledWorkload, mgr: CheckpointManager):
        """Rebuild the chunk-loop state from the newest snapshot: the
        standing padded buffers (single device) or the sharded global
        arrays (mesh), plus the step counter they were taken at."""
        sig = cw.signature
        pad = 0 if cw.mesh is not None else cw.layout.pad
        dtype = np.dtype(sig.dtype)
        target = {}
        for n, f in cw.program.fields.items():
            nx, ny, nz = f.shape
            shape = (nx + 2 * pad, ny + 2 * pad, nz)
            if cw.mesh is not None:
                target[n] = jax.ShapeDtypeStruct(
                    shape, dtype, sharding=cw.sharding()
                )
            else:
                target[n] = jax.ShapeDtypeStruct(shape, dtype)
        env, step, extra = mgr.restore(target)
        if extra.get("signature") != sig.key():
            raise ValueError(
                f"checkpoint belongs to {extra.get('signature')!r}, "
                f"not {sig.key()!r}"
            )
        return env, int(extra["step"])

    def _run_step(
        self,
        cw: CompiledWorkload,
        req: StepRequest,
        ticket: Ticket,
        monitor: HeartbeatMonitor,
    ) -> np.ndarray:
        st = ticket.stats
        mgr = self._ckpt_manager(req)
        step = 0
        env = None
        if mgr is not None and (req.resume or st.retries > 0):
            if mgr.latest_step() is not None:
                env, step = self._restore_env(cw, mgr)
                st.restores += 1
                with self._slock:
                    estats.service_restores += 1
                log.info(
                    "request %s restored at step %d", req.request_id, step
                )
        if env is None:
            env = cw.initial_env(req.init)
        chunk = req.ckpt_every if req.ckpt_every > 0 else self.default_chunk
        # Temporal blocking is tile-boundary sensitive (a k-step fused
        # launch differs from k untiled launches by ~1 ulp), so chunk
        # boundaries — and therefore checkpoints — are snapped to
        # multiples of the tile factor; the launch sequence then matches
        # an uninterrupted run exactly and resume stays bitwise.
        seg = cw.segment
        k = seg.time_tile if seg.kind == "fused" else 1
        if k > 1:
            chunk = max(k, (chunk // k) * k)
        while step < req.steps:
            m = min(chunk, req.steps - step)
            # the injectable failure boundary: after the previous chunk's
            # checkpoint, before this chunk advances any state — inside the
            # heartbeat window so injected slowdowns read as slow chunks
            monitor.start_step(step)
            fire_step_hook(step, tag=req.request_id)
            env = cw.advance(m)(env)
            jax.block_until_ready(list(env.values()))
            monitor.end_step()
            step += m
            # the explicit-path sentinel at the service's natural chunk
            # granule: one isfinite reduction per field per chunk (the
            # chunk runners donate, so the recovery state is the newest
            # checkpoint, not a held env)
            ok = bool(jax.device_get(ehealth.probe_ok_compiled(dict(env))))
            with self._slock:
                estats.health_probes += 1
            if not ok:
                raise ehealth.NumericalFault(
                    f"request {req.request_id}: non-finite field state "
                    f"at step {step}",
                    outcome="NAN_RESIDUAL",
                    step=step,
                )
            st.chunks += 1
            st.steps += m
            launches, exchanges = cw.chunk_accounting(m)
            st.launches += launches
            st.exchanges += exchanges
            if cw.mesh is not None:
                st.repacks += 2  # enter/exit per chunk inside shard_map
            with self._slock:
                estats.steps_run += m
                estats.launches += launches
                estats.exchanges += exchanges
            if mgr is not None:
                mgr.save(
                    step,
                    env,
                    extra={
                        "signature": cw.signature.key(),
                        "step": step,
                        "pad": 0 if cw.mesh is not None else cw.layout.pad,
                    },
                )
                st.checkpoints += 1
                with self._slock:
                    estats.service_checkpoints += 1
        if cw.mesh is None and cw.layout.pad > 0:
            st.repacks += 2  # one enter + one exit per resident request
            with self._slock:
                estats.repacks += 2
                estats.resident_runs += 1
        return cw.finalize(env)

    # -- solve requests ------------------------------------------------------
    def _run_solve(
        self, cw: CompiledWorkload, req: SolveRequest, ticket: Ticket
    ) -> np.ndarray:
        """One guarded Krylov solve, classified and (boundedly) recovered.

        The solver's health word drives the service's in-queue ladder: a
        failed cg/pipecg solve escalates once to BiCGSTAB (warm kernels,
        no recompile — the service skips the fp64 rung the offline path
        runs, keeping worker latency bounded); a still-failed solve raises
        :class:`~repro.engine.health.NumericalFault` with the full
        :class:`~repro.engine.health.RecoveryTrace`, which ``_serve``
        fails fast and never retries.
        """
        from repro.solver import health as shealth

        fire_step_hook(0, tag=req.request_id)
        x0 = (
            np.asarray(req.init, dtype=req.signature.dtype)
            if req.init is not None
            else cw.spec.default_init(
                req.signature.shape, np.dtype(req.signature.dtype)
            )
        )
        B = req.signature.batch
        if B > 1 and x0.ndim == 3:
            x0 = np.broadcast_to(x0, (B,) + x0.shape).copy()

        trace = ehealth.RecoveryTrace()

        def attempt(method, reason):
            x, (iters, res, outcomes) = cw.solver(
                method, req.tol, req.maxiter
            )(x0)
            jax.block_until_ready(x)
            iters = int(np.sum(np.asarray(iters)))
            outs = np.asarray(jax.device_get(outcomes))
            trace.record(
                method,
                req.signature.dtype,
                shealth.outcome_name(shealth.worst(outs)),
                iters,
                float(np.max(np.asarray(res))),
                reason,
            )
            return x, iters, outs

        x, iters, outs = attempt(req.method, "initial")
        if shealth.any_failure(outs) and req.method in ("cg", "pipecg"):
            worst = shealth.outcome_name(shealth.worst(outs))
            log.warning(
                "request %s: %s solve %s; escalating to bicgstab",
                req.request_id, req.method, worst,
            )
            with self._slock:
                estats.recovery_attempts += 1
            x, iters, outs = attempt("bicgstab", f"escalate after {worst}")
        ticket.stats.outcome = shealth.outcome_name(shealth.worst(outs))
        ticket.stats.recovery = trace.summary()
        ticket.stats.iterations = iters
        ticket.stats.steps = 1
        if shealth.any_failure(outs):
            raise ehealth.NumericalFault(
                f"request {req.request_id}: solve failed "
                f"({ticket.stats.outcome}) after {len(trace.attempts)} "
                "attempt(s)",
                outcome=ticket.stats.outcome,
                trace=trace,
            )
        return np.asarray(jax.device_get(x))

    # -- observability -------------------------------------------------------
    def service_stats(self) -> dict:
        """The service-level summary (see
        :func:`repro.engine.stats.service_stats`) plus this instance's live
        state: worker count, queue depth, plan-cache size, uptime."""
        out = _engine_service_stats()
        out["service"] = {
            "workers": self._nworkers,
            "queue_depth": len(self.scheduler),
            "plan_cache": sorted(self._plans),
            "uptime_s": (
                time.monotonic() - self._t_start if self._t_start else 0.0
            ),
        }
        return out

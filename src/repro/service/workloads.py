"""Workload registry + compiled plans for the simulation service.

A *workload* is a named recipe for recording a WFA program at a requested
``(shape, dtype)`` — the service's analogue of a model architecture in an
inference server.  A :class:`PlanSignature` names one workload at one
specialization, and :func:`build_workload` turns it into a
:class:`CompiledWorkload`: the recorded program, its
:func:`repro.engine.plan` schedule, the halo-resident layout, and a cache
of jitted *chunk runners* ``advance(env, m)`` that step resident buffers
``m`` logical steps per call.

Chunked stepping is what makes serving checkpointable: the service holds
the standing padded buffers between chunks (single device) and snapshots
them at chunk boundaries, so a fault between chunks resumes from the last
snapshot instead of step 0.  Chunking is bitwise-invariant — margins are
transient (refreshed to the full read depth before every launch), so
``advance(·, k)`` then ``advance(·, n−k)`` equals ``advance(·, n)`` exactly,
at every precision (the checkpoint tests pin this at fp64) — with one
caveat for temporal blocking: a ``k``-step fused launch is ~1 ulp away
from ``k`` untiled launches, so on tiled plans the invariance holds when
every chunk boundary lands on a multiple of the tile factor (the service
snaps its chunk granule accordingly; see ``SimulationService._run_step``).

Registered workloads (three distinct stencil families, so a mixed request
stream exercises distinct plan signatures):

* ``heat3d``   — the paper's explicit FTCS heat body (7-point, affine);
* ``advdiff``  — advection–diffusion with off-axis diagonal taps;
* ``jacobi3d`` — weighted-Jacobi Poisson sweeps against a fixed RHS field
  (two fields: only the sweep field is written);
* ``btcs_heat`` — the implicit BTCS system (``Operator``/``Rhs``), served
  through :func:`repro.solver.api.make_solver` (``SolveRequest`` only).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.field import Field
from repro.core.program import ForLoop, scoped_program
from repro.engine.plan import plan as build_plan
from repro.engine.executor import fresh_buffer
from repro.service.requests import PlanSignature

Shape = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One registered workload: how to record it and how to initialize it."""

    name: str
    kind: str  # "step" | "solve"
    record: Callable  # (shape, dtype, n_steps) -> (program, answer_name)
    default_init: Callable[[Shape, object], np.ndarray]
    description: str = ""


WORKLOADS: Dict[str, WorkloadSpec] = {}


def register_workload(spec: WorkloadSpec) -> WorkloadSpec:
    WORKLOADS[spec.name] = spec
    return spec


def get_workload(name: str) -> WorkloadSpec:
    if name not in WORKLOADS:
        raise KeyError(
            f"unknown workload {name!r}; registered: {sorted(WORKLOADS)}"
        )
    return WORKLOADS[name]


# ---------------------------------------------------------------------------
# registered workloads
# ---------------------------------------------------------------------------


def _hot_plate(shape: Shape, dtype) -> np.ndarray:
    T = np.full(shape, 500.0, dtype)
    T[1:-1, 1:-1, 0] = 300.0
    T[1:-1, 1:-1, -1] = 400.0
    return T


def _smooth_noise(shape: Shape, dtype) -> np.ndarray:
    rng = np.random.default_rng(7)
    return rng.uniform(0.0, 1.0, size=shape).astype(dtype)


def _record_heat3d(shape: Shape, dtype, n_steps: int):
    c = 0.1
    center = 1.0 - 6.0 * c
    with scoped_program() as program:
        T = Field("T", init_data=_hot_plate(shape, dtype), dtype=dtype)
        with ForLoop("service_heat", n_steps):
            T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
                T[2:, 0, 0]
                + T[:-2, 0, 0]
                + T[1:-1, 1, 0]
                + T[1:-1, -1, 0]
                + T[1:-1, 0, 1]
                + T[1:-1, 0, -1]
            )
    return program, "T"


def _record_advdiff(shape: Shape, dtype, n_steps: int):
    with scoped_program() as program:
        T = Field("T", init_data=_smooth_noise(shape, dtype), dtype=dtype)
        with ForLoop("service_advdiff", n_steps):
            T[1:-1, 0, 0] = (
                T[1:-1, 0, 0]
                + 0.05
                * (
                    T[2:, 0, 0]
                    + T[:-2, 0, 0]
                    + T[1:-1, 1, 0]
                    + T[1:-1, -1, 0]
                    + T[1:-1, 0, 1]
                    + T[1:-1, 0, -1]
                    - 6.0 * T[1:-1, 0, 0]
                )
                - 0.1 * (T[1:-1, 0, 0] - T[1:-1, -1, 0])
                - 0.07 * (T[1:-1, 0, 0] - T[1:-1, 0, -1])
                + 0.02 * (T[1:-1, 1, 1] + T[1:-1, -1, -1] - 2.0 * T[1:-1, 0, 0])
            )
    return program, "T"


def _record_jacobi3d(shape: Shape, dtype, n_steps: int):
    w = 6.0 / 7.0  # weighted-Jacobi damping (the multigrid smoother's omega)
    with scoped_program() as program:
        U = Field("U", init_data=np.zeros(shape, dtype), dtype=dtype)
        F = Field("F", init_data=_smooth_noise(shape, dtype), dtype=dtype)
        with ForLoop("service_jacobi", n_steps):
            U[1:-1, 0, 0] = (1.0 - w) * U[1:-1, 0, 0] + (w / 6.0) * (
                U[2:, 0, 0]
                + U[:-2, 0, 0]
                + U[1:-1, 1, 0]
                + U[1:-1, -1, 0]
                + U[1:-1, 0, 1]
                + U[1:-1, 0, -1]
                - F[1:-1, 0, 0]
            )
    return program, "U"


def _record_btcs_heat(shape: Shape, dtype, n_steps: int):
    from repro.solver import Operator, Rhs

    wpsi, psi = 0.05, 0.625
    with scoped_program() as program:
        T = Field("T", init_data=_hot_plate(shape, dtype), dtype=dtype)
        with Operator():
            T[1:-1, 0, 0] = T[1:-1, 0, 0] - wpsi * (
                T[2:, 0, 0]
                + T[:-2, 0, 0]
                + T[1:-1, 1, 0]
                + T[1:-1, -1, 0]
                + T[1:-1, 0, 1]
                + T[1:-1, 0, -1]
            )
        with Rhs():
            T[1:-1, 0, 0] = psi * T[1:-1, 0, 0]
    return program, "T"


register_workload(
    WorkloadSpec(
        "heat3d", "step", _record_heat3d, _hot_plate,
        "explicit FTCS heat (paper Fig. 3 body)",
    )
)
register_workload(
    WorkloadSpec(
        "advdiff", "step", _record_advdiff, _smooth_noise,
        "advection-diffusion with off-axis taps",
    )
)
register_workload(
    WorkloadSpec(
        "jacobi3d", "step", _record_jacobi3d,
        lambda shape, dtype: np.zeros(shape, dtype),
        "weighted-Jacobi Poisson sweeps against a fixed RHS field",
    )
)
register_workload(
    WorkloadSpec(
        "btcs_heat", "solve", _record_btcs_heat, _hot_plate,
        "implicit BTCS heat system (Operator/Rhs, Krylov solve)",
    )
)


# ---------------------------------------------------------------------------
# compiled workloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledWorkload:
    """One signature's compiled execution state, shared by every request.

    ``plan``/``layout`` come straight from the engine planner; ``advance``
    runners are built lazily per chunk length and memoized, so steady-state
    chunk sizes are traced exactly once per signature.  ``degraded`` is set
    when the pallas backend fell back to the interpreter (forced compile
    failure, non-lowerable body) — requests served through it are counted
    and flagged, never silent.
    """

    signature: PlanSignature
    spec: WorkloadSpec
    program: object
    answer: str
    plan: Optional[object] = None  # ExecutionPlan (step workloads)
    mesh: Optional[object] = None
    build_s: float = 0.0
    degraded: bool = False
    degraded_reason: str = ""
    _advance: Dict[int, Callable] = dataclasses.field(default_factory=dict)
    _solvers: Dict[tuple, Callable] = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)

    # -- step workloads ------------------------------------------------------
    @property
    def layout(self):
        return self.plan.layout

    @property
    def segment(self):
        return self.plan.segments[0]

    def field_names(self):
        return list(self.program.fields)

    def initial_env(self, init: Optional[np.ndarray]) -> dict:
        """Fresh device env (resident form on a single device).

        Batched signatures stack every field to ``(B, X, Y, Z)``; ``init``
        may then be one state shared by all members or a per-member stack.
        """
        B = self.signature.batch
        env = {
            n: np.asarray(f.init_data) for n, f in self.program.fields.items()
        }
        if init is not None:
            init = np.asarray(init, dtype=self.signature.dtype)
            if init.ndim == 4 and init.shape[0] != B:
                raise ValueError(
                    f"init stacks {init.shape[0]} members; signature "
                    f"batch is {B}"
                )
            env[self.answer] = init
        if B > 1:
            env = {
                n: (
                    v
                    if v.ndim == 4
                    else np.broadcast_to(v, (B,) + v.shape).copy()
                )
                for n, v in env.items()
            }
        env = {n: fresh_buffer(v) for n, v in env.items()}
        if self.mesh is None:
            env = self.layout.enter(env)
        else:
            sharding = self.sharding()
            env = {n: jax.device_put(v, sharding) for n, v in env.items()}
        return env

    def finalize(self, env: dict) -> np.ndarray:
        """Answer field back on the host (interior slice on a single device)."""
        if self.mesh is None:
            env = self.layout.exit(env)
        return np.asarray(jax.device_get(env[self.answer]))

    def advance(self, m: int) -> Callable:
        """The jitted chunk runner for ``m`` logical steps (memoized).

        Single device: steps the *resident padded* env in place (entry
        donated — zero allocation in steady state).  Mesh: steps the global
        unpadded env under ``shard_map`` (enter/exit per chunk, per brick).
        """
        with self._lock:
            hit = self._advance.get(m)
            if hit is not None:
                return hit
            fn = (
                self._advance_single(m)
                if self.mesh is None
                else self._advance_sharded(m)
            )
            self._advance[m] = fn
            return fn

    def _trace_chunk(self, env: dict, m: int) -> dict:
        seg = self.segment
        k = seg.time_tile if seg.kind == "fused" else 1
        if k > 1:
            env = jax.lax.fori_loop(0, m // k, lambda i, e: seg.step(e), env)
            if m % k:
                # the planner compiled step_rem because the workload's
                # nominal trip count is k+1 (see build_workload)
                env = jax.lax.fori_loop(
                    0, m % k, lambda i, e: seg.step_rem(e), env
                )
            return env
        return jax.lax.fori_loop(0, m, lambda i, e: seg.step(e), env)

    def _advance_single(self, m: int) -> Callable:
        def run(env):
            return self._trace_chunk(env, m)

        return jax.jit(run, donate_argnums=0)

    def _advance_sharded(self, m: int) -> Callable:
        from jax.sharding import PartitionSpec as P

        from repro.core.jaxcompat import shard_map

        mesh = self.mesh
        _, _, ax_x, ax_y = self.plan.mesh_ctx
        spec = P(ax_x, ax_y, None)
        specs = {n: spec for n in self.program.fields}
        layout = self.layout

        def local(env):
            return layout.exit(self._trace_chunk(layout.enter(env), m))

        return jax.jit(
            shard_map(
                local, mesh=mesh, in_specs=(specs,), out_specs=specs,
                check=False,
            ),
            donate_argnums=0,
        )

    def sharding(self):
        from jax.sharding import PartitionSpec as P

        _, _, ax_x, ax_y = self.plan.mesh_ctx
        return jax.sharding.NamedSharding(self.mesh, P(ax_x, ax_y, None))

    def chunk_accounting(self, m: int) -> Tuple[int, int]:
        """Static (launches, exchanges) one ``m``-step chunk pays."""
        seg = self.segment
        if seg.kind != "fused":
            launches = m
            exchanges = m * len(seg.ops) if self.mesh is not None else 0
            return launches, exchanges
        k = seg.time_tile
        launches = (m // k) + (m % k) if k > 1 else m
        return launches, launches if seg.halo > 0 else 0

    # -- solve workloads -----------------------------------------------------
    def solver(self, method: str, tol: float, maxiter: int) -> Callable:
        """Memoized jitted solver ``x0 -> (x, (iters, res, outcomes))`` per request
        parameters (the operator kernel itself is shared via the global
        kernel cache, so new parameter combinations reuse it)."""
        key = (method, float(tol), int(maxiter))
        with self._lock:
            hit = self._solvers.get(key)
            if hit is not None:
                return hit
            from repro.solver.api import make_solver

            fn = make_solver(
                self.program,
                self.answer,
                method=method,
                backend=self.signature.backend,
                tol=tol,
                maxiter=maxiter,
                batch=self.signature.batch,
            )
            self._solvers[key] = fn
            return fn


def build_workload(
    signature: PlanSignature, mesh=None
) -> CompiledWorkload:
    """Record + plan one signature (the service's plan-cache miss path).

    Step workloads are recorded with a nominal trip count of
    ``time_tile + 1`` so the planner compiles both the tiled step and the
    untiled remainder step — the chunk runners can then advance *any* step
    count, not just multiples of the tile factor.  Raises ``ValueError``
    for solve workloads on a mesh (served single-device for now) and for
    multi-loop programs (chunked checkpointing needs one loop body).
    """
    from repro.compiler import stats as kstats
    from repro.engine.options import RunOptions
    from repro.engine.stats import stats as estats

    spec = get_workload(signature.workload)
    if signature.batch > 1 and mesh is not None:
        raise ValueError(
            "batched signatures are served single-device; submit "
            f"{signature.key()!r} without a mesh"
        )
    t0 = time.perf_counter()
    nominal = signature.time_tile + 1 if signature.time_tile > 1 else 2
    program, answer = spec.record(
        signature.shape, np.dtype(signature.dtype), nominal
    )
    cw = CompiledWorkload(
        signature=signature, spec=spec, program=program, answer=answer,
        mesh=mesh,
    )
    fallbacks_before = kstats.fallbacks
    if spec.kind == "step":
        cw.plan = build_plan(
            program,
            options=RunOptions(
                backend=signature.backend,
                mesh=mesh,
                time_tile=signature.time_tile,
                batch=signature.batch,
            ),
        )
        if len(cw.plan.segments) != 1:
            raise ValueError(
                f"workload {spec.name!r} records {len(cw.plan.segments)} "
                "loop bodies; the service's chunked stepping needs exactly 1"
            )
        seg = cw.plan.segments[0]
        if signature.backend == "pallas" and seg.kind != "fused":
            cw.degraded = True
            cw.degraded_reason = (
                kstats.fallback_reasons[-1]
                if kstats.fallbacks > fallbacks_before
                else "body not fused"
            )
    else:
        if mesh is not None:
            raise ValueError(
                f"solve workload {spec.name!r} is served single-device; "
                "submit without a mesh"
            )
        # build the default solver now so warm-up pays the operator compile
        cw.solver("cg", 1e-6, 200)
        if kstats.fallbacks > fallbacks_before:
            cw.degraded = True
            cw.degraded_reason = kstats.fallback_reasons[-1]
    cw.build_s = time.perf_counter() - t0
    estats.plan_builds += 1
    return cw

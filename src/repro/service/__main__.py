"""``python -m repro.service`` — the serving smoke demo and CI gate.

``--smoke`` runs the full acceptance scenario end to end:

1. warm a 3-signature manifest (heat3d / advdiff / jacobi3d), then serve a
   mixed stream of ≥64 concurrent step + solve requests and **gate** on:
   every request completed, zero kernel compiles after warm-up (every
   request a plan-cache hit), zero retries, zero unexpected interpreter
   fallbacks;
2. inject a step fault into one checkpointed request and gate on it
   completing *with* a restore (restore-and-continue, not restart);
3. force a pallas compile failure for a fresh signature and gate on it
   being served through the logged interpreter degraded mode;
4. submit a *poisoned* solve request (NaN initial state) and gate on it
   failing **fast** with ``NumericalFault`` — zero retries, the health
   taxonomy word and a populated recovery trace on the ticket — while the
   injected infrastructure fault of phase 2 still retried.

Exit status is 0 only if every gate holds, so CI can call this directly.
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

import numpy as np


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="simulation service smoke demo / CI gate",
    )
    p.add_argument("--smoke", action="store_true",
                   help="run the gated end-to-end scenario (CI entry point)")
    p.add_argument("--requests", type=int, default=64,
                   help="concurrent requests in the mixed stream (default 64)")
    p.add_argument("--workers", type=int, default=4,
                   help="service worker threads (default 4)")
    p.add_argument("--steps", type=int, default=24,
                   help="logical steps per step request (default 24)")
    p.add_argument("--shape", type=int, nargs=3, default=(24, 24, 6),
                   metavar=("NX", "NY", "NZ"),
                   help="base field shape (default 24 24 6)")
    p.add_argument("--no-fault", action="store_true",
                   help="skip the fault-injection and degraded-mode phases")
    p.add_argument("--json", action="store_true",
                   help="emit the final service stats as JSON on stdout")
    p.add_argument("--ckpt-root", default=None,
                   help="checkpoint directory (default: a temp dir)")
    return p


def _gate(checks: dict) -> bool:
    ok = True
    for name, passed in checks.items():
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}")
        ok = ok and passed
    return ok


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s",
    )
    if not args.smoke:
        _build_parser().print_help()
        return 0

    import tempfile

    from repro.compiler import stats as kstats
    from repro.engine import reset_stats
    from repro.runtime.fault import FaultInjector
    from repro.service import (
        PlanSignature,
        SimulationService,
        SolveRequest,
        StepRequest,
    )

    reset_stats()
    nx, ny, nz = args.shape
    ckpt_root = args.ckpt_root or tempfile.mkdtemp(prefix="repro-service-")
    manifest = [
        PlanSignature("heat3d", (nx, ny, nz)),
        PlanSignature("advdiff", (nx - 4, ny - 4, nz)),
        PlanSignature("jacobi3d", (nx - 8, ny - 8, nz), time_tile=2),
    ]
    solve_sig = PlanSignature("btcs_heat", (12, 12, 4))

    svc = SimulationService(
        workers=args.workers,
        capacity=max(4 * args.requests, 256),
        manifest=manifest + [solve_sig],
        ckpt_root=ckpt_root,
        default_chunk=max(1, args.steps // 3),
    )
    print(f"== warm-up: {len(manifest) + 1} manifest signatures ==")
    svc.start()

    # ---- phase 1: mixed no-fault stream ------------------------------------
    built_before = kstats.kernels_built
    print(f"== phase 1: {args.requests} concurrent mixed requests ==")
    tickets = []
    for i in range(args.requests):
        if i % 8 == 7:
            tickets.append(svc.submit(SolveRequest(solve_sig, maxiter=60)))
        else:
            sig = manifest[i % len(manifest)]
            tickets.append(
                svc.submit(
                    StepRequest(sig, steps=args.steps, priority=i % 3)
                )
            )
    results = []
    for t in tickets:
        try:
            results.append(t.result(timeout=600))
        except Exception as e:  # gate below reports it; keep draining
            print(f"  request {t.request.request_id} failed: {e!r}")
            results.append(None)
    finite = all(
        r is not None and np.all(np.isfinite(np.asarray(r))) for r in results
    )
    phase1 = {
        "all requests completed": all(t.done() and t.error() is None
                                      for t in tickets),
        "results finite": finite,
        f"distinct signatures >= 3 "
        f"({len({t.stats.signature for t in tickets})})":
            len({t.stats.signature for t in tickets}) >= 3,
        "zero kernel compiles after warm-up":
            kstats.kernels_built == built_before,
        "every request hit the plan cache":
            all(t.stats.plan_cache_hit for t in tickets),
        "zero retries on the no-fault stream":
            sum(t.stats.retries for t in tickets) == 0,
        "zero degraded requests":
            sum(t.stats.degraded for t in tickets) == 0,
        "zero unexpected interpreter fallbacks": kstats.fallbacks == 0,
    }
    ok = _gate(phase1)

    if not args.no_fault:
        # ---- phase 2: fault-injected request completes via restore --------
        print("== phase 2: injected step fault -> restore-and-continue ==")
        fail_step = 2 * max(1, args.steps // 4)
        with FaultInjector(fail_at=[fail_step]):
            t = svc.submit(
                StepRequest(
                    manifest[0], steps=args.steps,
                    ckpt_every=max(1, args.steps // 4),
                )
            )
            faulted = t.result(timeout=600)
        phase2 = {
            "fault-injected request completed":
                np.all(np.isfinite(np.asarray(faulted))),
            f"retried ({t.stats.retries}) and restored "
            f"({t.stats.restores}) mid-flight":
                t.stats.retries >= 1 and t.stats.restores >= 1,
            f"checkpoints written ({t.stats.checkpoints})":
                t.stats.checkpoints >= 2,
        }
        ok = _gate(phase2) and ok

        # ---- phase 3: forced compile failure -> logged degraded mode ------
        print("== phase 3: forced compile failure -> degraded mode ==")
        degraded_sig = PlanSignature("heat3d", (nx + 2, ny + 2, nz))
        with FaultInjector(fail_compile=["service_heat"]):
            t = svc.submit(StepRequest(degraded_sig, steps=8))
            deg = t.result(timeout=600)
        phase3 = {
            "degraded request completed":
                np.all(np.isfinite(np.asarray(deg))),
            "served via interpreter degraded mode": t.stats.degraded,
            f"fallback logged ({t.stats.degraded_reason[:40]!r})":
                bool(t.stats.degraded_reason),
        }
        ok = _gate(phase3) and ok

        # ---- phase 4: poisoned request -> fail-fast NumericalFault --------
        print("== phase 4: poisoned solve -> fail-fast NumericalFault ==")
        from repro.engine.health import NumericalFault

        poison = np.full(solve_sig.shape, np.nan, solve_sig.dtype)
        t = svc.submit(SolveRequest(solve_sig, maxiter=60, init=poison))
        fault = None
        try:
            t.result(timeout=600)
        except Exception as e:
            fault = e
        phase4 = {
            "poisoned solve raised NumericalFault":
                isinstance(fault, NumericalFault),
            f"failed fast: zero retries ({t.stats.retries})":
                t.stats.retries == 0,
            f"taxonomy on ticket ({t.stats.outcome!r})":
                t.stats.outcome == "NAN_RESIDUAL",
            f"recovery trace populated ({len(t.stats.recovery)} attempts)":
                len(t.stats.recovery) >= 1,
        }
        ok = _gate(phase4) and ok

    stats = svc.service_stats()
    svc.save_manifest(f"{ckpt_root}/manifest.json")
    svc.stop()
    if args.json:
        print(json.dumps(stats, indent=1, default=str))
    else:
        req = stats["requests"]
        print(
            f"== served {req['completed']} requests "
            f"(mean queue wait {req['mean_queue_wait_s'] * 1e3:.1f} ms, "
            f"plan cache hits {stats['plans']['cache_hits']}, "
            f"kernel cache hits {stats['kernels']['cache_hits']}) =="
        )
    print("SMOKE PASS" if ok else "SMOKE FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Request model for the simulation service.

A request names *what* to run through a :class:`PlanSignature` — the
service's unit of cacheability.  Two requests with equal signatures share
one compiled :class:`~repro.service.workloads.CompiledWorkload` (and
therefore one kernel-cache lineage), which is what makes warm-pool serving
work: the scheduler groups queued requests by signature and a worker that
has the plan hot serves the whole group without a single compile.

``StepRequest`` runs an explicit time-stepping workload for ``steps``
logical steps (optionally checkpointing resident state every
``ckpt_every`` steps so a killed worker resumes mid-flight);
``SolveRequest`` runs a recorded implicit system to convergence.  Both
carry ``priority`` (higher dispatches first) and ``deadline_s`` (seconds
from submit; requests still queued past it are expired, not run).

Results travel through a :class:`Ticket` — a thread-safe future the
submitting thread blocks on — carrying the per-request
:class:`RequestStats` record either way (observability survives failure).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Optional, Tuple

import numpy as np


class ServiceOverloaded(RuntimeError):
    """Admission control rejected the request: the bounded queue is full."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed while it was still queued."""


class RequestFailed(RuntimeError):
    """The request exhausted its retry budget without completing."""


_ids = itertools.count()


def _next_id(prefix: str) -> str:
    return f"{prefix}-{next(_ids):06d}"


@dataclasses.dataclass(frozen=True)
class PlanSignature:
    """The cache key of one compiled workload.

    ``workload`` names a registered program builder (see
    :mod:`repro.service.workloads`); ``shape``/``dtype`` fix the field
    extents the kernels are specialized to; ``time_tile`` and ``backend``
    select the execution strategy; ``batch`` is the ensemble width the plan
    steps per launch (1 = classic single-scenario serving — its ``key()``
    spelling is unchanged, so pre-batch warm manifests stay valid).
    Everything the compiled plan depends on is in here — equal signatures
    are interchangeable at serve time.
    """

    workload: str
    shape: Tuple[int, int, int]
    dtype: str = "float32"
    time_tile: int = 1
    backend: str = "pallas"
    batch: int = 1

    def __post_init__(self):
        if len(self.shape) != 3:
            raise ValueError(f"shape must be (X, Y, Z); got {self.shape!r}")
        object.__setattr__(self, "shape", tuple(int(n) for n in self.shape))
        np.dtype(self.dtype)  # validates early, at request-build time
        if self.time_tile < 1:
            raise ValueError(f"time_tile must be >= 1; got {self.time_tile}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1; got {self.batch}")

    def key(self) -> str:
        nx, ny, nz = self.shape
        base = (
            f"{self.workload}:{nx}x{ny}x{nz}:{self.dtype}"
            f":k{self.time_tile}:{self.backend}"
        )
        # batch=1 keeps the historical spelling so schema-1 manifests and
        # old dashboards keep matching
        return base if self.batch == 1 else f"{base}:b{self.batch}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "PlanSignature":
        return cls(
            workload=d["workload"],
            shape=tuple(d["shape"]),
            dtype=d.get("dtype", "float32"),
            time_tile=int(d.get("time_tile", 1)),
            backend=d.get("backend", "pallas"),
            batch=int(d.get("batch", 1)),  # absent in schema-1 manifests
        )


@dataclasses.dataclass
class RequestStats:
    """Per-request observability record (attached to the ticket either way).

    ``queue_wait_s`` is submit → dispatch; ``plan_cache_hit`` says whether
    the worker found the signature's plan warm (after warm-up it always
    should); ``launches``/``exchanges`` are the kernel-level counts this
    request's chunks actually paid; ``retries``/``restores`` count the
    restore-and-continue path; ``degraded`` marks the interpreter fallback;
    ``batch`` > 1 marks a request served as one member of a coalesced
    ensemble launch (micro-batching).

    ``outcome`` is the numerical-health taxonomy word of the request's
    solve (``CONVERGED``/``NAN_RESIDUAL``/…, see :mod:`repro.solver.health`;
    empty for step requests that tripped no sentinel) and ``recovery`` the
    per-attempt summary of any escalation the worker ran — both populated
    whether the request completed or failed with a ``NumericalFault``
    (which the service never retries).
    """

    request_id: str = ""
    signature: str = ""
    batch: int = 1
    worker: Optional[int] = None
    submitted_s: float = 0.0
    started_s: float = 0.0
    finished_s: float = 0.0
    queue_wait_s: float = 0.0
    exec_s: float = 0.0
    plan_cache_hit: bool = False
    compile_s: float = 0.0  # plan build time when this request paid it
    steps: int = 0
    chunks: int = 0
    launches: int = 0
    exchanges: int = 0
    repacks: int = 0
    iterations: int = 0  # solve requests: inner Krylov iterations
    retries: int = 0
    checkpoints: int = 0
    restores: int = 0
    degraded: bool = False
    degraded_reason: str = ""
    outcome: str = ""  # health taxonomy word of the solve ("" = n/a)
    recovery: Tuple[str, ...] = ()  # per-attempt escalation summary

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finished_s - self.submitted_s)


def _check_init(init, signature: PlanSignature) -> None:
    """``init`` may be one state at ``signature.shape``, or — for batched
    signatures — a per-member ``(batch,) + shape`` stack."""
    if init is None:
        return
    got = tuple(init.shape)
    ok = [signature.shape]
    if signature.batch > 1:
        ok.append((signature.batch,) + signature.shape)
    if got not in ok:
        raise ValueError(
            f"init shape {got} != signature shape "
            f"{' or '.join(str(s) for s in ok)}"
        )


@dataclasses.dataclass
class StepRequest:
    """Run a registered explicit workload for ``steps`` logical steps.

    ``init`` overrides the workload's default initial condition (must match
    ``signature.shape``/``dtype``).  ``ckpt_every > 0`` snapshots resident
    state every that many steps under ``ckpt_key`` (defaults to the request
    id) — and ``resume=True`` starts from the newest such snapshot instead
    of step 0, which is how a killed worker's solve is carried forward by a
    fresh service instance.
    """

    signature: PlanSignature
    steps: int
    init: Optional[np.ndarray] = None
    priority: int = 0
    deadline_s: Optional[float] = None
    ckpt_every: int = 0
    ckpt_key: Optional[str] = None
    resume: bool = False
    request_id: str = dataclasses.field(default_factory=lambda: _next_id("step"))

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1; got {self.steps}")
        if self.ckpt_every < 0:
            raise ValueError(f"ckpt_every must be >= 0; got {self.ckpt_every}")
        if self.resume and not self.ckpt_key:
            raise ValueError("resume=True requires an explicit ckpt_key")
        if self.ckpt_every > 0 and self.signature.batch > 1:
            raise ValueError(
                "checkpointing batched signatures is not supported; "
                "submit members individually to checkpoint them"
            )
        _check_init(self.init, self.signature)


@dataclasses.dataclass
class SolveRequest:
    """Solve a registered implicit workload to convergence."""

    signature: PlanSignature
    method: str = "cg"
    tol: float = 1e-6
    maxiter: int = 200
    init: Optional[np.ndarray] = None
    priority: int = 0
    deadline_s: Optional[float] = None
    request_id: str = dataclasses.field(default_factory=lambda: _next_id("solve"))

    def __post_init__(self):
        if self.maxiter < 1:
            raise ValueError(f"maxiter must be >= 1; got {self.maxiter}")
        _check_init(self.init, self.signature)


class Ticket:
    """A thread-safe future for one submitted request.

    ``result(timeout)`` blocks for the final field data (re-raising the
    request's failure); ``stats`` is the :class:`RequestStats` record and
    is populated whether the request completed, failed or expired.
    """

    def __init__(self, request):
        self.request = request
        self.stats = RequestStats(
            request_id=request.request_id, signature=request.signature.key()
        )
        self._done = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    # -- producer side (service worker) -------------------------------------
    def _resolve(self, value) -> None:
        self._value = value
        self._done.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done.set()

    # -- consumer side -------------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id} still pending "
                f"after {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._value

    def error(self) -> Optional[BaseException]:
        return self._error if self._done.is_set() else None

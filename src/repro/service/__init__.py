"""Simulation-as-a-service: async serving over the unified engine.

Quickstart (the full story is in ``docs/service.md``)::

    from repro.service import (
        PlanSignature, SimulationService, StepRequest,
    )

    svc = SimulationService(workers=2).start()
    sig = PlanSignature("heat3d", (32, 32, 8))
    ticket = svc.submit(StepRequest(sig, steps=50))
    field = ticket.result(timeout=60)   # and ticket.stats for observability
    svc.stop()

Run the end-to-end smoke (mixed signatures, fault injection, degraded
mode) with ``python -m repro.service --smoke``.
"""

from repro.engine.health import NumericalFault
from repro.engine.stats import service_stats
from repro.service.requests import (
    DeadlineExceeded,
    PlanSignature,
    RequestFailed,
    RequestStats,
    ServiceOverloaded,
    SolveRequest,
    StepRequest,
    Ticket,
)
from repro.service.scheduler import SignatureScheduler
from repro.service.service import SimulationService
from repro.service.workloads import (
    WORKLOADS,
    CompiledWorkload,
    WorkloadSpec,
    build_workload,
    get_workload,
    register_workload,
)

__all__ = [
    "CompiledWorkload",
    "DeadlineExceeded",
    "NumericalFault",
    "PlanSignature",
    "RequestFailed",
    "RequestStats",
    "ServiceOverloaded",
    "SignatureScheduler",
    "SimulationService",
    "SolveRequest",
    "StepRequest",
    "Ticket",
    "WORKLOADS",
    "WorkloadSpec",
    "build_workload",
    "get_workload",
    "register_workload",
    "service_stats",
]

"""Admission control + signature-grouping scheduler.

The queue is *bounded* (admission control: a full queue rejects at submit
time with :class:`~repro.service.requests.ServiceOverloaded` rather than
accepting work it cannot serve), *prioritized* (higher ``priority``
dispatches first; FIFO within a priority), and *signature-grouped*: when a
worker asks for work, the scheduler hands it **every** queued request that
shares the chosen head-of-line signature (up to ``group_max``).  A worker
therefore amortizes one warm plan across a whole group back-to-back — and
this grouping boundary is where ``SimulationService(micro_batch=N)``
coalesces the group into one batched ensemble launch: the same signature
re-planned with ``batch=B`` steps every member per kernel call (see
``SimulationService._serve_batched``).

Deadlines are enforced at dispatch: a request whose deadline passed while
queued is expired (its ticket fails with ``DeadlineExceeded``) instead of
occupying a worker.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import List, Optional

from repro.service.requests import (
    DeadlineExceeded,
    ServiceOverloaded,
    Ticket,
)


class SignatureScheduler:
    """Bounded priority queue that dispatches same-signature groups."""

    def __init__(self, capacity: int = 256, group_max: int = 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self.group_max = group_max
        self._heap: List[tuple] = []  # (-priority, seq, ticket)
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._closed = False
        self.expired: List[Ticket] = []  # tickets failed at dispatch

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)

    def submit(self, ticket: Ticket) -> None:
        """Admit ``ticket`` or raise :class:`ServiceOverloaded` (queue full)
        / ``RuntimeError`` (scheduler closed)."""
        req = ticket.request
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if len(self._heap) >= self.capacity:
                raise ServiceOverloaded(
                    f"queue full ({self.capacity} pending); request "
                    f"{req.request_id} rejected"
                )
            ticket.stats.submitted_s = time.monotonic()
            heapq.heappush(
                self._heap, (-req.priority, next(self._seq), ticket)
            )
            self._ready.notify()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._ready.notify_all()

    def _pop_expired(self, now: float) -> None:
        """Fail (and drop) every queued ticket whose deadline has passed."""
        keep = []
        for item in self._heap:
            t = item[2]
            dl = t.request.deadline_s
            if dl is not None and now - t.stats.submitted_s > dl:
                t.stats.finished_s = now
                t._fail(
                    DeadlineExceeded(
                        f"request {t.request.request_id} expired after "
                        f"{now - t.stats.submitted_s:.3f}s in queue "
                        f"(deadline {dl}s)"
                    )
                )
                self.expired.append(t)
            else:
                keep.append(item)
        if len(keep) != len(self._heap):
            heapq.heapify(keep)
            self._heap[:] = keep

    def get_group(self, timeout: Optional[float] = None) -> List[Ticket]:
        """Block for work; return all queued requests sharing the
        head-of-line signature (priority order, ≤ ``group_max``).

        Returns ``[]`` on timeout or when the scheduler is closed and
        drained — workers use that as their exit signal.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while True:
                self._pop_expired(time.monotonic())
                if self._heap:
                    break
                if self._closed:
                    return []
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    return []
                self._ready.wait(remaining)
            head = heapq.heappop(self._heap)[2]
            sig = head.request.signature
            group, keep = [head], []
            # drain in priority order so the group preserves dispatch order
            while self._heap and len(group) < self.group_max:
                item = heapq.heappop(self._heap)
                if item[2].request.signature == sig:
                    group.append(item[2])
                else:
                    keep.append(item)
            for item in keep:
                heapq.heappush(self._heap, item)
            return group

"""``wfa.Ensemble`` — thousands of scenarios behind one kernel launch.

Wafer-scale throughput makes the *ensemble* the natural unit of work:
uncertainty quantification, parameter sweeps and data assimilation all run
the same field program over many initial states or coefficient sets.  This
module packages that as a first-class value: one recorded :class:`Program`
plus per-member ``(B, X, Y, Z)`` *overrides* for the fields that differ
between members.  ``wfa.make`` and ``wfa.solve`` accept an ``Ensemble``
transparently — the engine plans the program once with
``RunOptions(batch=B)``, every field buffer carries the leading member
axis, and each kernel launch (or masked Krylov iteration) advances all B
scenarios at once (see :mod:`repro.engine.plan` and
:mod:`repro.solver.krylov`).

Two ways to build one:

* **parameter sweep** — record once, override the varying fields::

      wse, T, C = record_varcoef_btcs(T0, C0, w)
      ens = Ensemble(wse.program, T, overrides={C.name: stacked_coeffs})

* **stacked programs** — record each member separately (e.g. different
  initial states from a data-assimilation filter) and stack them;
  :meth:`Ensemble.from_programs` validates the recordings are structurally
  identical (same ops, loops, shapes — they must be, to share one compiled
  kernel) and derives the overrides from whichever init data differs:

>>> import numpy as np
>>> from repro.core import Field, ForLoop, WFAInterface
>>> def member(v):  # the `with` exit releases the recording, so members
...     with WFAInterface() as wse:  # can be recorded back to back
...         T = Field("T", init_data=np.full((6, 6, 4), v, np.float32))
...         with ForLoop("t", 2):
...             T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
...     return wse, T
>>> ens = Ensemble.from_programs([member(1.0), member(2.0), member(4.0)])
>>> ens.batch
3
>>> out = ens.make(options="numpy")
>>> out.shape
(3, 6, 6, 4)
>>> [float(out[b, 2, 2, 1]) for b in range(3)]
[0.25, 0.5, 1.0]
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.program import Program, release_program


def _loop_sig(loop) -> Tuple:
    if loop is None:
        return None
    return (loop.name, loop.n, getattr(loop, "role", None))


def _canonical(program: Program) -> Tuple:
    """Structure of a recording, with every per-member *value* stripped out.

    Two programs with equal canonical forms lower to the same IR and hence
    share one compiled kernel (init data is the only thing allowed to
    differ) — the precondition for stacking them into one batched plan.
    """
    fields = tuple(
        (n, tuple(f.shape), np.dtype(f.dtype).name)
        for n, f in sorted(program.fields.items())
    )
    ops = tuple(
        (
            op.field_name,
            _loop_sig(op.loop),
            (op.target_z.start, op.target_z.stop, op.target_z.step),
            op.expr,  # frozen-dataclass tree: structural equality
        )
        for op in program.ops
    )
    return fields, ops


@dataclasses.dataclass(frozen=True)
class Ensemble:
    """One program, B members: per-member field stacks over a shared recording.

    ``overrides`` maps field names to ``(B, X, Y, Z)`` stacks; every field
    *not* overridden broadcasts its init data to all members.  ``answer``
    may be a Field or its name.  The ensemble is inert data — execution
    happens through :meth:`make` / :meth:`solve` (or the module-level
    ``wfa.make`` / ``wfa.solve``, which dispatch here on isinstance).
    """

    program: Program
    answer: object
    overrides: Dict[str, np.ndarray]
    batch: int = 0  # 0 = infer from the overrides' leading axis

    def __post_init__(self):
        release_program(self.program)  # recording is over; members are data
        name = getattr(self.answer, "name", self.answer)
        if name not in self.program.fields:
            raise ValueError(f"answer field {name!r} is not in this program")
        object.__setattr__(self, "answer", name)
        if not self.overrides and not self.batch:
            raise ValueError(
                "pass batch= explicitly when no field is overridden "
                "(an all-identical ensemble has no leading axis to infer B from)"
            )
        b = self.batch
        for n, v in self.overrides.items():
            if n not in self.program.fields:
                raise ValueError(f"override {n!r} is not a field of this program")
            v = np.asarray(v)
            want = self.program.fields[n].shape
            if v.ndim != 4 or v.shape[1:] != tuple(want):
                raise ValueError(
                    f"override {n!r} must be a (B, {want[0]}, {want[1]}, "
                    f"{want[2]}) stack; got {v.shape}"
                )
            if b and v.shape[0] != b:
                raise ValueError(
                    f"override {n!r} has {v.shape[0]} members; expected {b}"
                )
            b = b or v.shape[0]
        object.__setattr__(self, "batch", int(b))
        object.__setattr__(self, "overrides", dict(self.overrides))

    @classmethod
    def from_programs(cls, members, answer=None) -> "Ensemble":
        """Stack separately recorded members into one batched ensemble.

        ``members`` is a sequence of ``(wse, answer_field)`` pairs (what the
        recorder presets return; a bare ``WFAInterface``/``Program`` works
        when ``answer=`` names the unknown).  All recordings must be
        structurally identical — same fields, loops and update expressions —
        since one compiled kernel serves every member; only init data may
        differ, and each differing field becomes a stacked override.
        """
        progs, names = [], []
        for m in members:
            if isinstance(m, tuple):
                obj, ans = m
                names.append(getattr(ans, "name", ans))
            else:
                obj = m
                names.append(getattr(answer, "name", answer))
            progs.append(obj if isinstance(obj, Program) else obj.program)
        if not progs:
            raise ValueError("from_programs needs at least one member")
        if len(set(names)) != 1 or names[0] is None:
            raise ValueError(
                f"members disagree on the answer field: {sorted(set(map(str, names)))}"
            )
        ref = _canonical(progs[0])
        for i, p in enumerate(progs[1:], start=1):
            if _canonical(p) != ref:
                raise ValueError(
                    f"member {i} records a structurally different program "
                    "(ops/loops/field shapes must match to share one "
                    "batched kernel); only init data may vary"
                )
        overrides = {}
        for n, f in progs[0].fields.items():
            datas = [np.asarray(p.fields[n].init_data) for p in progs]
            if any(not np.array_equal(d, datas[0]) for d in datas[1:]):
                overrides[n] = np.stack(datas)
        return cls(
            program=progs[0],
            answer=names[0],
            overrides=overrides,
            batch=len(progs),
        )

    def stacked_env(self) -> Dict[str, np.ndarray]:
        """Every field as a ``(B, X, Y, Z)`` stack (overrides verbatim,
        the rest broadcast from init data)."""
        env = {}
        for n, f in self.program.fields.items():
            if n in self.overrides:
                env[n] = np.asarray(self.overrides[n])
            else:
                d = np.asarray(f.init_data)
                env[n] = np.broadcast_to(d, (self.batch,) + d.shape).copy()
        return env

    def _options(self, options):
        from repro.engine.options import RunOptions

        if options is None:
            options = RunOptions()
        elif isinstance(options, str):
            options = RunOptions(backend=options)
        if options.batch not in (1, self.batch):
            raise ValueError(
                f"options.batch={options.batch} conflicts with this "
                f"ensemble's {self.batch} members"
            )
        return options.replace(batch=self.batch)

    def make(self, options=None) -> np.ndarray:
        """Run the explicit program for all members in one batched plan;
        returns the answer as a ``(B, X, Y, Z)`` stack."""
        from repro.engine import run_program

        out = run_program(
            self.program, env=self.stacked_env(), options=self._options(options)
        )
        return np.asarray(out[self.answer])

    def solve(self, options=None, member_env=None, **kwargs):
        """Solve the recorded implicit system for all members in one masked
        Krylov loop (see :func:`repro.solver.solve`); per-member stacks for
        the guess/coefficients come from the overrides (``member_env=``
        entries take precedence)."""
        from repro.solver.api import solve as _solve

        env = dict(self.overrides)
        env.update(member_env or {})
        return _solve(
            self.program,
            self.answer,
            options=self._options(options),
            member_env=env,
            **kwargs,
        )


def _maybe_program(target) -> Optional[Program]:
    if isinstance(target, Program):
        return target
    prog = getattr(target, "program", None)
    return prog if isinstance(prog, Program) else None


def make(target, answer=None, options=None, **kwargs):
    """Module-level ``wfa.make``: Ensemble-aware explicit execution.

    ``make(ensemble)`` runs every member in one batched plan;
    ``make(wse_or_program, answer)`` is the classic single-scenario entry
    (equivalent to ``wse.make(answer, ...)``).
    """
    if isinstance(target, Ensemble):
        if answer is not None:
            raise ValueError("an Ensemble already carries its answer field")
        return target.make(options=options)
    prog = _maybe_program(target)
    if prog is None:
        raise TypeError(
            f"make() expects an Ensemble, WFAInterface or Program; "
            f"got {type(target).__name__}"
        )
    from repro.engine import run_program

    try:
        out = run_program(prog, options=options, **kwargs)
    finally:
        release_program(prog)
    name = getattr(answer, "name", answer)
    if name is None:
        raise ValueError("make(program, answer) needs the answer field")
    return np.asarray(out[name])


def solve(target, answer=None, **kwargs):
    """Module-level ``wfa.solve``: Ensemble-aware implicit solves.

    ``solve(ensemble, ...)`` runs one masked batched Krylov loop over all
    members; ``solve(wse_or_program, answer, ...)`` is the single-scenario
    entry of :func:`repro.solver.solve`.
    """
    if isinstance(target, Ensemble):
        if answer is not None:
            raise ValueError("an Ensemble already carries its answer field")
        return target.solve(**kwargs)
    prog = _maybe_program(target)
    if prog is None:
        raise TypeError(
            f"solve() expects an Ensemble, WFAInterface or Program; "
            f"got {type(target).__name__}"
        )
    from repro.solver.api import solve as _solve

    try:
        return _solve(prog, answer, **kwargs)
    finally:
        release_program(prog)

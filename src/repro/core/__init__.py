"""repro.core — the paper's contribution: the WFA field-equation API in JAX.

Public surface:

* :class:`~repro.core.field.Field` + :class:`~repro.core.program.WFAInterface`
  + :class:`~repro.core.program.ForLoop` — the NumPy-like frontend (Fig. 3);
* :mod:`~repro.core.explicit` — FTCS solver (Eq. 2), sharded + overlapped +
  wide-halo variants;
* :mod:`~repro.core.implicit` — BTCS/CG family (Eq. 3): classic, pipelined,
  Chebyshev;
* :mod:`~repro.core.perfmodel` — the paper's Eqs. 4-6/12-17 and the TPU
  three-term roofline.
"""
from repro.core.field import Field
from repro.core.program import ForLoop, WFAInterface

# paper-compatible aliases (Fig. 3 spells these WSE_*)
WSE_Array = Field
WSE_For_Loop = ForLoop
WSE_Interface = WFAInterface

__all__ = ["Field", "ForLoop", "WFAInterface",
           "WSE_Array", "WSE_For_Loop", "WSE_Interface"]

"""Implicit BTCS heat solver (paper Eq. 3) — legacy drivers over the solver
subsystem.

``A = I − ωψ·S`` with ``S`` the 6-neighbour sum and ``ψ = 1/(1+6ω)``; identity
rows on boundary cells.  CG runs on the interior subspace: search vectors are
zero on the Moat, so the masked operator is SPD there.

Since the solver subsystem landed (:mod:`repro.solver`) there is ONE
operator-compilation path: the BTCS operator is *recorded* through the WFA
frontend (:func:`repro.solver.presets.btcs_program`) and applied via the
shared program step — the same body ``wfa.solve`` lowers to a fused Pallas
kernel — and every iteration lives in :mod:`repro.solver.krylov`.  This
module keeps the historical driver surface:

* :func:`btcs_solve` — single-device time stepping (CG, pipelined CG,
  BiCGSTAB, Chebyshev, Jacobi);
* :func:`make_sharded_implicit` — brick-sharded drivers over a device mesh
  (kernel or interpreter operator application, fused ``psum`` reductions);
* :func:`make_operator` / :func:`make_brick_operator` — raw operator
  builders (the brick variant backs the roofline iteration harness).
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.explicit import interior_mask3d, neighbor_sum_padded
from repro.core.halo import halo_pad, local_moat_mask
from repro.core.jaxcompat import shard_map
from repro.solver import krylov
from repro.solver.api import make_sharded_solver, operator_fns
from repro.solver.presets import btcs_program, psi

__all__ = [
    "bicgstab_solve", "btcs_solve", "cg_solve", "chebyshev_bounds",
    "chebyshev_solve", "jacobi_solve", "make_brick_operator",
    "make_operator", "make_sharded_implicit", "make_sharded_iteration",
    "pipecg_solve", "psi",
]

# the Krylov/relaxation iterations, re-exported under their legacy names
# (one shared implementation — see repro.solver.krylov)
cg_solve = krylov.cg
pipecg_solve = krylov.pipecg
bicgstab_solve = krylov.bicgstab
chebyshev_solve = krylov.chebyshev
jacobi_solve = krylov.jacobi

#: legacy entry points that already warned this process (warn once each)
_DEPRECATION_WARNED = set()


def _warn_legacy(fn: str) -> None:
    if fn in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(fn)
    warnings.warn(
        f"repro.core.implicit.{fn} is deprecated; record the system through "
        "the WFA frontend (repro.solver presets) and call wfa.solve — "
        "repro.solver.solve / WFAInterface.solve — instead",
        DeprecationWarning,
        stacklevel=3,
    )


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

def _make_operator(w: float, shape):
    A, rhs = operator_fns(btcs_program(shape, w), "T", backend="jit")
    mask = interior_mask3d(shape)

    def dot(a, b):
        return jnp.sum(a * b, dtype=jnp.float32)

    return A, rhs, dot, mask


def make_operator(w: float, shape):
    """Single-device masked BTCS operator and rhs builder.

    .. deprecated:: use ``wfa.solve`` (or :func:`repro.solver.operator_fns`
       for raw applications) — this shim warns once and forwards.

    The operator body is recorded through the WFA frontend and applied with
    the shared program step (``repro.solver.api.operator_fns``), so this
    hand-callable path and the compiled ``wfa.solve`` path execute the same
    recorded stencil.
    """
    _warn_legacy("make_operator")
    return _make_operator(w, shape)


def make_brick_operator(w: float, brick_shape, ax_x, ax_y, mx, my,
                        use_kernel: bool = False):
    """Brick-local operator for use inside ``shard_map``.

    SpMV = halo exchange + padded stencil; dot = local dot + ``psum`` over
    both mesh axes (the reduction-to-center analogue, Fig. 2c).  Kept as the
    raw building block for the roofline iteration harness
    (:func:`make_sharded_iteration`); the time-stepping drivers go through
    ``repro.solver`` instead.
    """
    bx, by, nz = brick_shape
    wpsi = w * psi(w)
    if use_kernel:
        from repro.kernels import ops as kops

    def mask():
        m2 = local_moat_mask(bx, by, ax_x, ax_y, mx, my)
        zi = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nz), 2)
        return m2 & (zi > 0) & (zi < nz - 1)

    def A(v):
        P = halo_pad(v, 1, ax_x, ax_y, mx, my)
        if use_kernel:
            Av = kops.spmv_hex(P, 1.0, -wpsi)
        else:
            Av = v - wpsi * neighbor_sum_padded(P)
        return jnp.where(mask(), Av, v)

    def rhs(T):
        return jnp.where(mask(), psi(w) * T, T)

    def dot(a, b):
        d = jnp.sum(a * b, dtype=jnp.float32)
        # joint-axis psum: ONE all-reduce over the whole mesh instead of two
        # chained single-axis reductions — halves the diameter-latency term
        # (§Perf heat-implicit iteration 1)
        return jax.lax.psum(d, (ax_x, ax_y))

    return A, rhs, dot, mask


def chebyshev_bounds(w: float):
    """Analytic eigenvalue bounds of A = I − ωψS on the interior subspace.

    The neighbour-sum S on a Dirichlet grid has spectrum in (−6, 6), so
    λ(A) ⊂ [1−6ωψ, 1+6ωψ].  With the paper's ω = 0.1: [0.625, 1.375].
    (``repro.solver`` derives the same bracket mechanically from the lowered
    tap form — Gershgorin circles; see ``gershgorin_bounds``.)
    """
    wp = w * psi(w)
    return 1.0 - 6.0 * wp, 1.0 + 6.0 * wp


# ---------------------------------------------------------------------------
# time-stepping drivers
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("w", "steps", "method", "tol", "maxiter"))
def _btcs_solve_impl(T0, w: float, steps: int, method: str = "cg",
                     tol: float = 1e-6, maxiter: int = 500):
    A, rhs, dot, mask = _make_operator(w, T0.shape)

    def dot2(a, b, c, d):
        return dot(a, b), dot(c, d)

    def one(T, _):
        b = rhs(T)
        # the legacy aux contract stays (i, res); the outcome word is the
        # wfa.solve path's surface (SolveInfo.outcomes)
        if method == "cg":
            x, i, res, _ = krylov.cg(A, dot, b, T, tol=tol, maxiter=maxiter)
        elif method == "pipecg":
            x, i, res, _ = krylov.pipecg(A, dot2, b, T, tol=tol,
                                         maxiter=maxiter)
        elif method == "bicgstab":
            x, i, res, _ = krylov.bicgstab(A, dot, b, T, tol=tol,
                                           maxiter=maxiter)
        elif method == "chebyshev":
            lmin, lmax = chebyshev_bounds(w)
            x, i, res, _ = krylov.chebyshev(A, b, T, lmin, lmax,
                                            iters=maxiter)
        elif method == "jacobi":
            # unit diagonal + identity Moat rows: x + b − A(x) IS the Jacobi
            # sweep (b + ωψ·Sx interior, b on the Moat) — no mask needed
            x, i, res, _ = krylov.jacobi(lambda x: x + b - A(x), T,
                                         iters=maxiter)
        else:
            raise ValueError(method)
        return x, (i, res)

    T, aux = jax.lax.scan(one, T0, None, length=steps)
    return T, aux


def btcs_solve(T0, w: float, steps: int, method: str = "cg",
               tol: float = 1e-6, maxiter: int = 500):
    """Advance `steps` BTCS time steps on a single device.

    .. deprecated:: record the system (``repro.solver.record_btcs``) and
       call ``wfa.solve`` — same kernels, full method/preconditioner
       surface, ensemble batching.  This shim warns once and forwards.
    """
    _warn_legacy("btcs_solve")
    return _btcs_solve_impl(T0, w, steps, method=method, tol=tol,
                            maxiter=maxiter)


def make_sharded_implicit(mesh, shape, w: float, *, method: str = "cg",
                          tol: float = 1e-6, maxiter: int = 500,
                          use_kernel: bool = False, steps: int = 1):
    """Brick-sharded BTCS solver over ``mesh``; returns (step_fn, sharding).

    .. deprecated:: use ``wfa.solve(..., mesh=...)`` /
       :func:`repro.solver.make_sharded_solver` — this shim warns once and
       forwards.

    Routed through ``repro.solver.make_sharded_solver``: the recorded BTCS
    body compiles to one fused Pallas kernel per operator application when
    ``use_kernel`` (the PR-1 compiler path, inside shard_map) or runs on the
    shared roll interpreter otherwise; reductions are one fused ``psum``.
    """
    _warn_legacy("make_sharded_implicit")
    backend = "pallas" if use_kernel else "jit"
    step, sharding = make_sharded_solver(
        btcs_program(shape, w), "T", mesh, method=method, backend=backend,
        tol=tol, maxiter=maxiter, steps=steps)

    def step_fn(T):
        return step(T)[0]

    return step_fn, sharding


# ---------------------------------------------------------------------------
# roofline iteration harness (exact per-iteration accounting)
# ---------------------------------------------------------------------------

def make_sharded_iteration(mesh, shape, w: float, *, method: str = "cg",
                           use_kernel: bool = False):
    """One inner iteration as a standalone jitted step (for exact roofline
    accounting: no solver setup, no replacement branch).  State pytrees:

        cg:        (x, r, p, rr)
        pipecg:    (x, r, w, z, p, s, gamma, alpha)
        chebyshev: (x, r, d, rho)
    """
    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    mx, my = mesh.shape[ax_x], mesh.shape[ax_y]
    nx, ny, nz = shape
    bx, by = nx // mx, ny // my
    spec = jax.sharding.PartitionSpec(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    vec = lambda: jax.ShapeDtypeStruct(shape, jnp.float32, sharding=sharding)
    scal = lambda: jax.ShapeDtypeStruct((), jnp.float32)

    def local(state):
        A, rhs, dot, _ = make_brick_operator(
            w, (bx, by, nz), ax_x, ax_y, mx, my, use_kernel=use_kernel)

        def dot2(a, b, c, d):
            from repro.kernels import ops as kops
            # fused dual-dot kernel on Mosaic only: in interpret mode the
            # extra pallas launch per reduction costs more than it fuses
            if use_kernel and not kops._interpret():
                part = kops.dual_dot(a, b, c, d)
            else:
                part = jnp.stack([jnp.sum(a * b, dtype=jnp.float32),
                                  jnp.sum(c * d, dtype=jnp.float32)])
            part = jax.lax.psum(part, (ax_x, ax_y))
            return part[0], part[1]

        if method == "cg":
            x, r, p, rr = state
            if use_kernel:
                from repro.kernels import ops as kops
                P = halo_pad(p, 1, ax_x, ax_y, mx, my)
                Ap, pAp_l = kops.spmv_hex_dot(P, 1.0, -w * psi(w))
                Ap = jnp.where(_mask(bx, by, nz, ax_x, ax_y, mx, my), Ap, p)
                pAp = jax.lax.psum(pAp_l, (ax_x, ax_y))
            else:
                Ap = A(p)
                pAp = dot(p, Ap)
            alpha = rr / pAp
            x = x + alpha * p
            r = r - alpha * Ap
            rr_new = dot(r, r)
            beta = rr_new / rr
            p = r + beta * p
            return (x, r, p, rr_new)
        if method == "pipecg":
            x, r, w_, z, p, sv, gamma_prev, alpha_prev = state
            gamma, delta = dot2(r, r, w_, r)
            n = A(w_)
            beta = gamma / gamma_prev
            alpha = gamma / (delta - beta * gamma / alpha_prev)
            z = n + beta * z
            p = r + beta * p
            sv = w_ + beta * sv
            x = x + alpha * p
            r = r - alpha * sv
            w_ = w_ - alpha * z
            return (x, r, w_, z, p, sv, gamma, alpha)
        if method == "chebyshev":
            x, r, d, rho = state
            lmin, lmax = chebyshev_bounds(w)
            theta = 0.5 * (lmax + lmin)
            delta = 0.5 * (lmax - lmin)
            sigma1 = theta / delta
            r = r - A(d)
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = rho_new * rho * d + (2.0 * rho_new / delta) * r
            x = x + d
            return (x, r, d, rho_new)
        raise ValueError(method)

    n_vec = {"cg": 3, "pipecg": 6, "chebyshev": 3}[method]
    n_scal = {"cg": 1, "pipecg": 2, "chebyshev": 1}[method]
    state_sds = tuple([vec() for _ in range(n_vec)]
                      + [scal() for _ in range(n_scal)])
    vspec = spec
    sspec = jax.sharding.PartitionSpec()
    state_spec = tuple([vspec] * n_vec + [sspec] * n_scal)
    step = jax.jit(shard_map(local, mesh=mesh, in_specs=(state_spec,),
                                 out_specs=state_spec, check=False))
    return step, state_sds


def _mask(bx, by, nz, ax_x, ax_y, mx, my):
    m2 = local_moat_mask(bx, by, ax_x, ax_y, mx, my)
    zi = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nz), 2)
    return m2 & (zi > 0) & (zi < nz - 1)

"""Implicit BTCS heat solver (paper Eq. 3) — CG family, matrix-free.

``A = I − ωψ·S`` with ``S`` the 6-neighbour sum and ``ψ = 1/(1+6ω)``; identity
rows on boundary cells.  CG runs on the interior subspace: search vectors are
zero on the Moat, so the masked operator is SPD there.

Variants (all matrix-free, single-device or brick-sharded):

* :func:`cg_solve` — classic CG, two reduction points per iteration
  (paper-faithful; the second reduction is what Eq. 16's ``2(X+Y)`` term
  prices on the WSE and what ``psum`` latency prices on the TPU torus);
* :func:`pipecg_solve` — Ghysels–Vanroose pipelined CG: the two dots fuse
  into ONE reduction that overlaps with the next SpMV (the paper's
  "pipelined Krylov" future-work remark, implemented);
* :func:`chebyshev_solve` — reduction-free Chebyshev iteration using the
  analytic eigenvalue bounds of A (the paper's "reduction-free implicit
  methods" remark, implemented).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxcompat import shard_map
from repro.core.explicit import (interior_mask3d, neighbor_sum_padded,
                                 _fix_z_boundary)
from repro.core.halo import halo_pad, local_moat_mask


def psi(w: float) -> float:
    return 1.0 / (1.0 + 6.0 * w)


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

def make_operator(w: float, shape):
    """Single-device masked BTCS operator and rhs builder."""
    mask = interior_mask3d(shape)
    wpsi = w * psi(w)

    def nbsum(v):
        P = jnp.pad(v, ((1, 1), (1, 1), (0, 0)))
        return neighbor_sum_padded(P)

    def A(v):
        return jnp.where(mask, v - wpsi * nbsum(v), v)

    def rhs(T):
        # b = ψ·Tⁿ on interior; boundary rows carry γ (identity rows).
        return jnp.where(mask, psi(w) * T, T)

    def dot(a, b):
        return jnp.sum(a * b, dtype=jnp.float32)

    return A, rhs, dot, mask


def make_brick_operator(w: float, brick_shape, ax_x, ax_y, mx, my,
                        use_kernel: bool = False):
    """Brick-local operator for use inside ``shard_map``.

    SpMV = halo exchange + padded stencil; dot = local dot + ``psum`` over
    both mesh axes (the reduction-to-center analogue, Fig. 2c).
    """
    bx, by, nz = brick_shape
    wpsi = w * psi(w)
    if use_kernel:
        from repro.kernels import ops as kops

    def mask():
        m2 = local_moat_mask(bx, by, ax_x, ax_y, mx, my)
        zi = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nz), 2)
        return m2 & (zi > 0) & (zi < nz - 1)

    def A(v):
        P = halo_pad(v, 1, ax_x, ax_y, mx, my)
        if use_kernel:
            Av = kops.spmv_hex(P, 1.0, -wpsi)
        else:
            Av = v - wpsi * neighbor_sum_padded(P)
        return jnp.where(mask(), Av, v)

    def rhs(T):
        return jnp.where(mask(), psi(w) * T, T)

    def dot(a, b):
        d = jnp.sum(a * b, dtype=jnp.float32)
        # joint-axis psum: ONE all-reduce over the whole mesh instead of two
        # chained single-axis reductions — halves the diameter-latency term
        # (§Perf heat-implicit iteration 1)
        return jax.lax.psum(d, (ax_x, ax_y))

    return A, rhs, dot, mask


# ---------------------------------------------------------------------------
# solvers (operator- and dot-generic: same code runs on 1 chip or 512)
# ---------------------------------------------------------------------------

def cg_solve(A: Callable, dot: Callable, b, x0, *, tol: float = 1e-6,
             maxiter: int = 500):
    """Classic CG (Eq. 3 solve).  Two reductions per iteration: (p,Ap) and
    (r,r) — the paper's benchmarked bottleneck."""
    r = b - A(x0)
    p = r
    rr = dot(r, r)

    def cond(s):
        x, r, p, rr, i = s
        return (rr > tol * tol) & (i < maxiter)

    def body(s):
        x, r, p, rr, i = s
        Ap = A(p)
        pAp = dot(p, Ap)                      # reduction 1
        alpha = rr / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        rr_new = dot(r, r)                    # reduction 2 (overlaps x-update)
        beta = rr_new / rr
        p = r + beta * p
        return (x, r, p, rr_new, i + 1)

    x, r, p, rr, i = jax.lax.while_loop(cond, body, (x0, r, p, rr, 0))
    return x, i, jnp.sqrt(rr)


def pipecg_solve(A: Callable, dot2: Callable, b, x0, *, tol: float = 1e-6,
                 maxiter: int = 500):
    """Ghysels–Vanroose pipelined CG: ONE fused reduction per iteration,
    overlapped with the next SpMV.

    ``dot2(a, b, c, d)`` returns (a·b, c·d) in a single reduction — sharded
    backends implement it as one ``psum`` of a length-2 vector, halving the
    Eq. 16 latency term; XLA then schedules ``n = A w`` while it completes.
    """
    r = b - A(x0)
    w_ = A(r)
    zero = jnp.zeros_like(b)
    rr0 = dot2(r, r, r, r)[0]    # true entry residual (warm-start guard)
    replace_every = 25           # periodic residual replacement (fp32 drift)

    def body2(s):
        x, r, w_, z, p, sv, gamma_prev, alpha_prev, i, fresh = s
        gamma, delta = dot2(r, r, w_, r)       # fused reduction
        n = A(w_)                              # overlapped SpMV
        beta = jnp.where(fresh, 0.0, gamma / gamma_prev)
        denom = delta - beta * gamma / jnp.where(fresh, 1.0, alpha_prev)
        # fp32 pipelined recurrences can hit a vanishing denominator near
        # convergence; clamp to keep the iterate finite (cond exits next).
        denom = jnp.where(jnp.abs(denom) < 1e-30,
                          jnp.where(denom < 0, -1e-30, 1e-30), denom)
        alpha = gamma / denom
        z = n + beta * z
        p = r + beta * p
        sv = w_ + beta * sv
        x = x + alpha * p
        r = r - alpha * sv
        w_ = w_ - alpha * z
        # residual replacement: resync the recurred r/w with the true
        # residual every k iterations (Cools & Vanroose) — two extra SpMVs,
        # amortised 2/k, restores attainable accuracy at warm starts.
        do = (i + 1) % replace_every == 0
        r, w_ = jax.lax.cond(
            do, lambda x, r, w_: (b - A(x), A(b - A(x))),
            lambda x, r, w_: (r, w_), x, r, w_)
        return (x, r, w_, z, p, sv, gamma, alpha, i + 1, do)

    def cond2(s):
        gamma_prev, i = s[6], s[8]
        # gamma_prev is ‖r‖² of the previous iterate (true rr0 at entry)
        return (gamma_prev > tol * tol) & (i < maxiter)

    s0 = (x0, r, w_, zero, zero, zero, rr0,
          jnp.asarray(1.0, jnp.float32), jnp.asarray(0, jnp.int32),
          jnp.asarray(True))
    out = jax.lax.while_loop(cond2, body2, s0)
    x, i = out[0], out[8]
    rr = dot2(out[1], out[1], out[1], out[1])[0]
    return x, i, jnp.sqrt(rr)


def chebyshev_bounds(w: float) -> Tuple[float, float]:
    """Analytic eigenvalue bounds of A = I − ωψS on the interior subspace.

    The neighbour-sum S on a Dirichlet grid has spectrum in (−6, 6), so
    λ(A) ⊂ [1−6ωψ, 1+6ωψ].  With the paper's ω = 0.1: [0.625, 1.375].
    """
    wp = w * psi(w)
    return 1.0 - 6.0 * wp, 1.0 + 6.0 * wp


def jacobi_solve(step: Callable, x0, *, iters: int = 500):
    """Reduction-free Jacobi iteration for A = I − ωψS (unit diagonal):

        x ← where(interior, b + ωψ·S x, b)

    (``step`` is that update — built by the caller with its own nbsum/mask.)
    Spectral radius 6ωψ = 6ω/(1+6ω) < 1 for all ω > 0, so it always
    converges; zero collectives per iteration and only one neighbour
    exchange — the cheapest member of the paper's "reduction-free implicit
    methods" family (Chebyshev converges faster per iteration).
    """
    x = jax.lax.fori_loop(0, iters, lambda k, x: step(x), x0)
    return x, iters, jnp.zeros(())


def chebyshev_solve(A: Callable, b, x0, lmin: float, lmax: float, *,
                    iters: int = 500):
    """Reduction-free Chebyshev iteration — zero collectives per iteration."""
    theta = 0.5 * (lmax + lmin)
    delta = 0.5 * (lmax - lmin)
    sigma1 = theta / delta

    r = b - A(x0)
    d = r / theta
    x = x0 + d
    rho = 1.0 / sigma1

    def body(k, s):
        x, r, d, rho = s
        r = r - A(d)
        rho_new = 1.0 / (2.0 * sigma1 - rho)
        d = rho_new * rho * d + (2.0 * rho_new / delta) * r
        x = x + d
        return (x, r, d, rho_new)

    x, r, d, rho = jax.lax.fori_loop(0, iters, body, (x, r, d, rho))
    return x, iters, jnp.sqrt(jnp.sum(r * r, dtype=jnp.float32))


# ---------------------------------------------------------------------------
# time-stepping drivers
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("w", "steps", "method", "tol", "maxiter"))
def btcs_solve(T0, w: float, steps: int, method: str = "cg",
               tol: float = 1e-6, maxiter: int = 500):
    """Advance `steps` BTCS time steps on a single device."""
    A, rhs, dot, mask = make_operator(w, T0.shape)

    def dot2(a, b, c, d):
        return dot(a, b), dot(c, d)

    def one(T, _):
        b = rhs(T)
        if method == "cg":
            x, i, res = cg_solve(A, dot, b, T, tol=tol, maxiter=maxiter)
        elif method == "pipecg":
            x, i, res = pipecg_solve(A, dot2, b, T, tol=tol, maxiter=maxiter)
        elif method == "chebyshev":
            lmin, lmax = chebyshev_bounds(w)
            x, i, res = chebyshev_solve(A, b, T, lmin, lmax, iters=maxiter)
        elif method == "jacobi":
            wpsi = w * psi(w)

            def jstep(x):
                P = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
                return jnp.where(mask, b + wpsi * neighbor_sum_padded(P), b)

            x, i, res = jacobi_solve(jstep, T, iters=maxiter)
        else:
            raise ValueError(method)
        return x, (i, res)

    T, aux = jax.lax.scan(one, T0, None, length=steps)
    return T, aux


def make_sharded_iteration(mesh, shape, w: float, *, method: str = "cg",
                           use_kernel: bool = False):
    """One inner iteration as a standalone jitted step (for exact roofline
    accounting: no solver setup, no replacement branch).  State pytrees:

        cg:        (x, r, p, rr)
        pipecg:    (x, r, w, z, p, s, gamma, alpha)
        chebyshev: (x, r, d, rho)
    """
    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    mx, my = mesh.shape[ax_x], mesh.shape[ax_y]
    nx, ny, nz = shape
    bx, by = nx // mx, ny // my
    spec = jax.sharding.PartitionSpec(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    vec = lambda: jax.ShapeDtypeStruct(shape, jnp.float32, sharding=sharding)
    scal = lambda: jax.ShapeDtypeStruct((), jnp.float32)

    def local(state):
        A, rhs, dot, _ = make_brick_operator(
            w, (bx, by, nz), ax_x, ax_y, mx, my, use_kernel=use_kernel)

        def dot2(a, b, c, d):
            if use_kernel:
                from repro.kernels import ops as kops
                part = kops.dual_dot(a, b, c, d)
            else:
                part = jnp.stack([jnp.sum(a * b, dtype=jnp.float32),
                                  jnp.sum(c * d, dtype=jnp.float32)])
            part = jax.lax.psum(part, (ax_x, ax_y))
            return part[0], part[1]

        if method == "cg":
            x, r, p, rr = state
            if use_kernel:
                from repro.kernels import ops as kops
                from repro.core.halo import halo_pad
                P = halo_pad(p, 1, ax_x, ax_y, mx, my)
                Ap, pAp_l = kops.spmv_hex_dot(P, 1.0, -w * psi(w))
                Ap = jnp.where(_mask(bx, by, nz, ax_x, ax_y, mx, my), Ap, p)
                pAp = jax.lax.psum(pAp_l, (ax_x, ax_y))
            else:
                Ap = A(p)
                pAp = dot(p, Ap)
            alpha = rr / pAp
            x = x + alpha * p
            r = r - alpha * Ap
            rr_new = dot(r, r)
            beta = rr_new / rr
            p = r + beta * p
            return (x, r, p, rr_new)
        if method == "pipecg":
            x, r, w_, z, p, sv, gamma_prev, alpha_prev = state
            gamma, delta = dot2(r, r, w_, r)
            n = A(w_)
            beta = gamma / gamma_prev
            alpha = gamma / (delta - beta * gamma / alpha_prev)
            z = n + beta * z
            p = r + beta * p
            sv = w_ + beta * sv
            x = x + alpha * p
            r = r - alpha * sv
            w_ = w_ - alpha * z
            return (x, r, w_, z, p, sv, gamma, alpha)
        if method == "chebyshev":
            x, r, d, rho = state
            lmin, lmax = chebyshev_bounds(w)
            theta = 0.5 * (lmax + lmin)
            delta = 0.5 * (lmax - lmin)
            sigma1 = theta / delta
            r = r - A(d)
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = rho_new * rho * d + (2.0 * rho_new / delta) * r
            x = x + d
            return (x, r, d, rho_new)
        raise ValueError(method)

    n_vec = {"cg": 3, "pipecg": 6, "chebyshev": 3}[method]
    n_scal = {"cg": 1, "pipecg": 2, "chebyshev": 1}[method]
    state_sds = tuple([vec() for _ in range(n_vec)]
                      + [scal() for _ in range(n_scal)])
    vspec = spec
    sspec = jax.sharding.PartitionSpec()
    state_spec = tuple([vspec] * n_vec + [sspec] * n_scal)
    step = jax.jit(shard_map(local, mesh=mesh, in_specs=(state_spec,),
                                 out_specs=state_spec, check=False))
    return step, state_sds


def _mask(bx, by, nz, ax_x, ax_y, mx, my):
    m2 = local_moat_mask(bx, by, ax_x, ax_y, mx, my)
    zi = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nz), 2)
    return m2 & (zi > 0) & (zi < nz - 1)


def make_sharded_implicit(mesh, shape, w: float, *, method: str = "cg",
                          tol: float = 1e-6, maxiter: int = 500,
                          use_kernel: bool = False, steps: int = 1):
    """Brick-sharded BTCS solver over ``mesh``; returns (step_fn, sharding)."""
    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    mx, my = mesh.shape[ax_x], mesh.shape[ax_y]
    nx, ny, nz = shape
    bx, by = nx // mx, ny // my
    spec = jax.sharding.PartitionSpec(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)

    def local(T):
        A, rhs, dot, _ = make_brick_operator(
            w, (bx, by, nz), ax_x, ax_y, mx, my, use_kernel=use_kernel)

        if use_kernel:
            from repro.kernels import ops as kops

        def dot2(a, b, c, d):
            if use_kernel:
                part = kops.dual_dot(a, b, c, d)      # fused local pass
            else:
                part = jnp.stack([jnp.sum(a * b, dtype=jnp.float32),
                                  jnp.sum(c * d, dtype=jnp.float32)])
            part = jax.lax.psum(part, (ax_x, ax_y))   # ONE fused all-reduce
            return part[0], part[1]

        def one(T, _):
            b = rhs(T)
            if method == "cg":
                x, i, res = cg_solve(A, dot, b, T, tol=tol, maxiter=maxiter)
            elif method == "pipecg":
                x, i, res = pipecg_solve(A, dot2, b, T, tol=tol,
                                         maxiter=maxiter)
            elif method == "chebyshev":
                lmin, lmax = chebyshev_bounds(w)
                x, i, res = chebyshev_solve(A, b, T, lmin, lmax,
                                            iters=maxiter)
            elif method == "jacobi":
                wpsi = w * psi(w)
                A_, rhs_, dot_, mask_ = make_brick_operator(
                    w, (bx, by, nz), ax_x, ax_y, mx, my)

                def jstep(x):
                    P = halo_pad(x, 1, ax_x, ax_y, mx, my)
                    return jnp.where(mask_(),
                                     b + wpsi * neighbor_sum_padded(P), b)

                x, i, res = jacobi_solve(jstep, T, iters=maxiter)
            else:
                raise ValueError(method)
            return x, (i, res)

        T2, aux = jax.lax.scan(one, T, None, length=steps)
        return T2

    step = jax.jit(shard_map(local, mesh=mesh, in_specs=(spec,),
                                 out_specs=spec, check=False))
    return step, sharding

"""Stencil expression tracing — the WFA's NumPy-like frontend, in JAX.

The paper's ``WSE_Array`` indexing convention (Fig. 3):

    T[zslice, dx, dy]

* axis 0 is a *local* slice along the Z column owned by a tile,
* axes 1..2 are **relative tile offsets** in X / Y: -1 (W/S), 0 (C), +1 (E/N).

Indexing a :class:`~repro.core.field.Field` builds a lazy :class:`StencilExpr`
tree; assigning an expression to a field slice records an update in the active
:class:`~repro.core.program.Program`.  Expressions are evaluated either with
NumPy (the WFA's validation mode), with ``jax.numpy`` (single device), or
inside ``shard_map`` on halo-padded bricks (distributed mode).

Arrays are stored globally as ``(X, Y, Z)``; a term's value at cell
``(x, y, z)`` is ``field[x + dx, y + dy, z + dz]``.  Shifts are implemented
with ``roll`` — wrap-around only ever lands in domain-boundary cells, which
the boundary mask pins to their Dirichlet values, so roll is exact (see
core/boundary.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple, Union

import numpy as np

Scalar = Union[int, float]


def zslice_delta(zslice: slice, target: slice) -> int:
    """Relative Z offset of a term slice w.r.t. the update target slice.

    The WFA convention writes the target as ``T[1:-1, 0, 0]`` and neighbours
    as ``T[2:, 0, 0]`` (z+1) / ``T[:-2, 0, 0]`` (z-1).  Both slices must have
    equal length and be *normalized* — concrete, non-negative start/stop as
    produced by ``slice.indices`` in :meth:`Program.record_update`.  Raw
    subtraction of starts is wrong for negative-start spellings like
    ``T[-9:-1, 0, 0]``, which is why normalization happens at record time.
    """
    if (zslice.start is None or target.start is None
            or zslice.start < 0 or target.start < 0):
        raise ValueError("zslice_delta requires normalized slices "
                         "(record the update through a Program first)")
    return zslice.start - target.start


@dataclasses.dataclass(frozen=True)
class StencilExpr:
    """Base class for lazy stencil expression nodes."""

    def __add__(self, other):
        return BinOp("add", self, _lift(other))

    def __radd__(self, other):
        return BinOp("add", _lift(other), self)

    def __sub__(self, other):
        return BinOp("sub", self, _lift(other))

    def __rsub__(self, other):
        return BinOp("sub", _lift(other), self)

    def __mul__(self, other):
        return BinOp("mul", self, _lift(other))

    def __rmul__(self, other):
        return BinOp("mul", _lift(other), self)

    def __truediv__(self, other):
        return BinOp("div", self, _lift(other))

    def __neg__(self):
        return BinOp("mul", Const(-1.0), self)

    # -- analysis ---------------------------------------------------------
    def terms(self) -> Tuple["Term", ...]:
        out = []
        _collect_terms(self, out)
        return tuple(out)

    def max_offset(self) -> int:
        offs = [max(abs(t.dx), abs(t.dy)) for t in self.terms()]
        return max(offs) if offs else 0


@dataclasses.dataclass(frozen=True)
class Const(StencilExpr):
    value: float


@dataclasses.dataclass(frozen=True)
class Term(StencilExpr):
    """A field reference ``field[zslice, dx, dy]``."""

    field_name: str
    zslice: Tuple[Any, Any, Any]  # (start, stop, step) of the z slice
    dx: int
    dy: int

    def zslice_obj(self) -> slice:
        return slice(*self.zslice)


@dataclasses.dataclass(frozen=True)
class BinOp(StencilExpr):
    op: str
    lhs: StencilExpr
    rhs: StencilExpr


def _lift(v) -> StencilExpr:
    if isinstance(v, StencilExpr):
        return v
    if isinstance(v, (int, float, np.floating, np.integer)):
        return Const(float(v))
    raise TypeError(f"cannot use {type(v)} in a stencil expression")


def normalize_zslices(e: StencilExpr, nz_of: Dict[str, int]) -> StencilExpr:
    """Rewrite every :class:`Term` with a concrete ``(start, stop)`` z slice.

    ``nz_of`` maps field names to their Z extent.  Negative or open-ended
    slice spellings (``T[-9:-1]``, ``T[2:]``) are resolved via
    ``slice.indices`` so downstream passes (length validation, the compiler's
    :func:`zslice_delta`) can do plain integer arithmetic on starts.
    """
    if isinstance(e, Term):
        start, stop, _ = e.zslice_obj().indices(nz_of[e.field_name])
        return dataclasses.replace(e, zslice=(start, stop, None))
    if isinstance(e, BinOp):
        return dataclasses.replace(
            e,
            lhs=normalize_zslices(e.lhs, nz_of),
            rhs=normalize_zslices(e.rhs, nz_of),
        )
    return e


def _collect_terms(e: StencilExpr, out) -> None:
    if isinstance(e, Term):
        out.append(e)
    elif isinstance(e, BinOp):
        _collect_terms(e.lhs, out)
        _collect_terms(e.rhs, out)


_BINOPS: Dict[str, Callable] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
}


def evaluate(
    expr: StencilExpr,
    env: Dict[str, Any],
    target_z: slice,
    xp,
    roll: Callable[[Any, int, int], Any],
) -> Any:
    """Evaluate ``expr`` over the target z-slice.

    ``env`` maps field names to (X, Y, Z) arrays.  ``xp`` is the array module
    (numpy or jax.numpy); ``roll(a, shift, axis)`` shifts along X/Y.  The
    value of term ``(dx, dy)`` at cell x is ``a[x + dx]`` = ``roll(a, -dx)``.
    """
    if isinstance(expr, Const):
        return expr.value
    if isinstance(expr, Term):
        a = env[expr.field_name]
        if expr.dx:
            a = roll(a, -expr.dx, 0)
        if expr.dy:
            a = roll(a, -expr.dy, 1)
        # shift in z is expressed through the slice itself; the slice is
        # validated (equal length to target) when the update is recorded.
        return a[:, :, expr.zslice_obj()]
    if isinstance(expr, BinOp):
        lhs = evaluate(expr.lhs, env, target_z, xp, roll)
        rhs = evaluate(expr.rhs, env, target_z, xp, roll)
        return _BINOPS[expr.op](lhs, rhs)
    raise TypeError(f"unknown expr node {type(expr)}")


def neighbor_sum(a, xp, roll):
    """Sum of the six Cartesian neighbours — the paper's ``N(C)`` operator.

    z neighbours are local (the 1×1×Z decomposition keeps the column on one
    tile); x/y neighbours cross brick boundaries in distributed mode.
    Wrap-around cells are masked by the caller's boundary mask.
    """
    s = roll(a, 1, 0) + roll(a, -1, 0) + roll(a, 1, 1) + roll(a, -1, 1)
    zp = xp.concatenate([a[:, :, 1:], a[:, :, -1:]], axis=2)
    zm = xp.concatenate([a[:, :, :1], a[:, :, :-1]], axis=2)
    return s + zp + zm

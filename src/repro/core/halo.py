"""Distributed bricks + halo exchange — the WSE fabric on a TPU mesh.

The paper's 1×1×Z decomposition gives every tile a Z-column and exchanges
X/Y neighbour planes over single-cycle fabric hops.  The TPU analogue bricks
the (X, Y) plane over the (``data``, ``model``) mesh axes — each chip owns a
(bx, by, Z) brick — and exchanges depth-``h`` ghost zones with
``lax.ppermute`` along each axis: a nearest-neighbour ICI transfer, the
direct analogue of the WSE's W→C→E / N→C→S background threads.  Time-tiled
segments exchange depth ``k·h`` once per k steps (temporal blocking — the
engine's communication amortization).

This module owns the mesh-level primitives (``halo_pad``, the traced Moat
mask, the sharded roll-interpreter step); scheduling and backend dispatch
live in :mod:`repro.engine`.  ``run_sharded`` is the thin mesh entry point
into that engine, so the paper's Fig. 3 script runs unchanged on 1 device
or 512.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stencil as st
from repro.core.program import Program


def _ppermute_shift(x, axis_name: str, n: int, direction: int):
    """Receive neighbour data from ``direction`` (+1: from lower index)."""
    if direction > 0:
        perm = [(i, i + 1) for i in range(n - 1)]
    else:
        perm = [(i + 1, i) for i in range(n - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_pad(local, h: int, ax_x: str, ax_y: str, mx: int, my: int):
    """Pad a (bx, by, Z) brick with depth-``h`` halos in X and Y.

    Edge bricks receive zeros in the out-of-domain halo; those cells are
    never read by interior updates because domain-boundary cells are stored
    *inside* the edge bricks (the Moat), matching the paper's layout.
    Leading (batch) axes pass through: a ``(B, bx, by, Z)`` ensemble brick
    moves all B members' halo planes in the same ``ppermute``.
    """
    if h == 0:
        return local
    # X axis: receive the high plane of the -x neighbour, low plane of +x.
    lo_x = _ppermute_shift(local[..., -h:, :, :], ax_x, mx, +1)
    hi_x = _ppermute_shift(local[..., :h, :, :], ax_x, mx, -1)
    local = jnp.concatenate([lo_x, local, hi_x], axis=-3)
    lo_y = _ppermute_shift(local[..., -h:, :], ax_y, my, +1)
    hi_y = _ppermute_shift(local[..., :h, :], ax_y, my, -1)
    return jnp.concatenate([lo_y, local, hi_y], axis=-2)


def exchange_slabs(resident, margin: int, h: int, ax_x: str, ax_y: str,
                   mx: int, my: int):
    """Exchange the depth-``h`` margin slabs into *separate* buffers.

    The mesh counterpart of :func:`repro.engine.layout.wrap_slabs`: two
    ``ppermute`` edge transfers per axis, the Y transfers sourced from the
    x-extended rows (own edge columns flanked by the incoming X slabs'
    corner pieces), so corner cells arrive from the diagonal neighbour in
    two fabric hops — bitwise what :func:`halo_pad`'s concatenates build,
    zero fill on domain-edge bricks included.  The slabs stay in their own
    small arrays until :func:`repro.engine.layout.land_slabs` stores them:
    the returned dict is the *in-flight exchange* the overlap scheduler
    launches the interior kernel alongside, never aliasing the resident
    buffer that kernel writes.  Leading (batch) axes travel whole.
    """
    K = margin
    bx = resident.shape[-3] - 2 * K
    by = resident.shape[-2] - 2 * K
    # X axis: slabs of the interior's edge rows (full interior Y extent).
    lo_x = _ppermute_shift(resident[..., K + bx - h:K + bx, K:K + by, :],
                           ax_x, mx, +1)
    hi_x = _ppermute_shift(resident[..., K:K + h, K:K + by, :], ax_x, mx, -1)
    # Y axis: sources span the x-extended rows (corner pieces from the X
    # slabs just received), exactly like halo_pad's second concat.
    src_lo = jnp.concatenate([
        lo_x[..., :, by - h:by, :],
        resident[..., K:K + bx, K + by - h:K + by, :],
        hi_x[..., :, by - h:by, :],
    ], axis=-3)
    src_hi = jnp.concatenate([
        lo_x[..., :, 0:h, :],
        resident[..., K:K + bx, K:K + h, :],
        hi_x[..., :, 0:h, :],
    ], axis=-3)
    lo_y = _ppermute_shift(src_lo, ax_y, my, +1)
    hi_y = _ppermute_shift(src_hi, ax_y, my, -1)
    return {"lo_x": lo_x, "hi_x": hi_x, "lo_y": lo_y, "hi_y": hi_y}


def halo_refresh(resident, margin: int, h: int, ax_x: str, ax_y: str,
                 mx: int, my: int):
    """Refresh the depth-``h`` margin of a halo-*resident* brick in place.

    ``resident`` is a (bx + 2·margin, by + 2·margin, Z) buffer whose interior
    holds the brick (see :class:`repro.engine.layout.HaloLayout`).  Instead
    of rebuilding a padded copy per step (:func:`halo_pad`'s concatenate),
    only the four margin *slabs* move (:func:`exchange_slabs`), each written
    back with ``dynamic_update_slice`` — the narrow in-place update that
    keeps fields resident while halos travel.  The slab contents (including
    corners, and the zero fill on domain-edge bricks) are bitwise identical
    to what :func:`halo_pad` would have produced, so resident and repacking
    execution agree exactly.  Leading (batch) axes pass through — one slab
    transfer refreshes every ensemble member.
    """
    if h == 0:
        return resident
    from repro.engine.layout import land_slabs

    slabs = exchange_slabs(resident, margin, h, ax_x, ax_y, mx, my)
    return land_slabs(resident, slabs, margin, h)


def local_moat_mask(bx: int, by: int, ax_x: str, ax_y: str, mx: int, my: int):
    """(bx, by, 1) mask, False on global-domain-edge cells of this brick.

    Traced from ``axis_index`` so the same SPMD program serves all bricks —
    exactly how one Worker kernel image serves the whole WSE fabric.
    """
    cx = jax.lax.axis_index(ax_x)
    cy = jax.lax.axis_index(ax_y)
    gx = cx * bx + jax.lax.broadcasted_iota(jnp.int32, (bx, by, 1), 0)
    gy = cy * by + jax.lax.broadcasted_iota(jnp.int32, (bx, by, 1), 1)
    nx, ny = mx * bx, my * by
    return (gx > 0) & (gx < nx - 1) & (gy > 0) & (gy < ny - 1)


def evaluate_padded(expr: st.StencilExpr, env_padded: Dict[str, jnp.ndarray],
                    target_z: slice, h: int, bx: int, by: int):
    """Evaluate a stencil expression on depth-``h`` halo-padded bricks."""
    if isinstance(expr, st.Const):
        return expr.value
    if isinstance(expr, st.Term):
        a = env_padded[expr.field_name]
        x0 = h + expr.dx
        y0 = h + expr.dy
        return a[x0:x0 + bx, y0:y0 + by, expr.zslice_obj()]
    if isinstance(expr, st.BinOp):
        lhs = evaluate_padded(expr.lhs, env_padded, target_z, h, bx, by)
        rhs = evaluate_padded(expr.rhs, env_padded, target_z, h, bx, by)
        return st._BINOPS[expr.op](lhs, rhs)
    raise TypeError(type(expr))


def interp_step_sharded(ops, ax_x: str, ax_y: str, mx: int, my: int):
    """Roll-interpreter step for one op group on halo-padded bricks.

    The ``shard_map``-local analogue of ``program._interp_step``: one halo
    exchange + padded evaluation per op, Moat mask from mesh coordinates.
    The engine hands this out (via ``compile_body``) as the ``jit`` backend
    and the sharded interpreter fallback, so the two cannot diverge.
    """

    def step(e):
        e = dict(e)
        masks = {}  # (bx, by) -> traced Moat mask, built once per step
        for op in ops:
            h = max(1, op.expr.max_offset())
            names = {t.field_name for t in op.expr.terms()}
            padded = {n: halo_pad(e[n], h, ax_x, ax_y, mx, my) for n in names}
            f = e[op.field_name]
            bx, by, _ = f.shape
            val = evaluate_padded(op.expr, padded, op.target_z, h, bx, by)
            if (bx, by) not in masks:
                masks[bx, by] = local_moat_mask(bx, by, ax_x, ax_y, mx, my)
            new_z = jnp.where(masks[bx, by], val, f[:, :, op.target_z])
            start = op.target_z.indices(f.shape[2])[0]
            e[op.field_name] = jax.lax.dynamic_update_slice(
                f, new_z, (0, 0, start))
        return e

    return step


def default_mesh2d():
    """Largest 2-D mesh over the available devices (rows ~ sqrt)."""
    n = len(jax.devices())
    mx = int(np.sqrt(n))
    while n % mx:
        mx -= 1
    return jax.make_mesh((mx, n // mx), ("data", "model"))


def run_sharded(program: Program, env: Dict[str, np.ndarray], mesh=None,
                use_pallas=None, time_tile=None, resident=None, *,
                options=None):
    """Execute a recorded WFA program on a 2-D device mesh.

    A thin wrapper over the unified engine: plans the program for the
    ``pallas`` (``use_pallas=True``; halo-pad brick → fused kernel inside
    the mapped function, ``time_tile=k`` amortizing one depth-``k·h``
    exchange over k steps) or ``jit`` backend and executes it inside one
    ``shard_map``.  Bodies that cannot be lowered fall back to
    :func:`interp_step_sharded` with a logged reason.  Fused bricks step
    halo-resident (standing padded brick buffers, margin-slab ppermute
    refresh via :func:`halo_refresh`, donated entry buffers);
    ``resident=False`` forces the legacy repacking steps — both are bitwise
    identical.

    ``env`` maps field names to global ``(X, Y, Z)`` arrays; the returned
    env holds the final values, gathered back to host NumPy.  With
    ``mesh=None`` the default mesh covers all available devices (a single
    device degenerates to one brick, so the same script runs anywhere):

    >>> import numpy as np
    >>> from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
    >>> with WSE_Interface() as wse:
    ...     T = WSE_Array("T", init_data=np.full((8, 8, 4), 2.0, np.float32))
    ...     with WSE_For_Loop("time_loop", 2):
    ...         T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
    >>> out = run_sharded(wse.program, {"T": T.init_data})
    >>> float(out["T"][3, 3, 1])
    0.5

    Execution policy can equivalently travel as one frozen bundle,
    ``options=RunOptions(...)`` — the legacy ``use_pallas=`` / ``time_tile=``
    / ``resident=`` keywords are deprecation shims that warn once and
    forward (``use_pallas=True`` maps to ``backend="pallas"``).
    """
    from repro.engine import execute, plan
    from repro.engine.options import UNSET, _warn_once, resolve_options

    options = resolve_options(
        options,
        "run_sharded",
        time_tile=UNSET if time_tile is None else time_tile,
        resident=UNSET if resident is None else resident,
    )
    if use_pallas is not None:
        _warn_once("run_sharded", "use_pallas", "backend='pallas'")
        options = options.replace(backend="pallas" if use_pallas else "jit")
    if mesh is None:
        mesh = options.mesh if options.mesh is not None else default_mesh2d()
    options = options.replace(
        backend=options.resolved_backend("jit"), mesh=mesh
    )
    p = plan(program, options)
    return execute(p, env)

"""Analytic performance models — the paper's equations + TPU analogues.

Paper equations implemented verbatim (units: iterations/s unless noted):

* Eq. 4/5:   OpenFOAM explicit weak scaling on Joule 2.0
* Eq. 6:     WSE explicit roofline    R_i = F_c / (6.5 W + 78)
* Eq. 11/12: GPU bound  t_min = 8W / w_m ;  R_max = w_m / (8W)
* Eq. 13-15: OpenFOAM implicit weak scaling
* Eq. 16:    WSE CG roofline          R_i = F_c / (10.5 W + 2(X+Y) + 337)
* Eq. 17:    WSE dot product          t = (W + X + Y + 66) / F_c

TPU adaptation: the WSE counts cycles because compute, memory and fabric all
run at one cycle per element; a TPU chip does not, so the analogue is the
three-term roofline  t = max(t_compute, t_memory) + t_collective  (collective
unoverlapped, matching Eq. 7's max(comp, comm) + t_b structure), evaluated
from per-step FLOPs / bytes / collective-bytes.  Constants are TPU v5e.
"""
from __future__ import annotations

import dataclasses

# -- hardware constants ------------------------------------------------------

WSE_CLOCK_HZ = 850e6          # CS-2 nominal fabric clock (used for Eq. 6/16)

TPU_V5E_BF16_FLOPS = 197e12   # peak bf16 FLOP/s per chip
TPU_V5E_FP32_FLOPS = 98.5e12  # fp32 ≈ half bf16 on v5e MXU
TPU_V5E_HBM_BW = 819e9        # B/s per chip
TPU_V5E_ICI_BW = 50e9         # B/s per link (~, per brief)
TPU_V5E_ICI_LAT = 1e-6        # s per hop (order of magnitude)


# -- paper equations ---------------------------------------------------------

def wse_explicit_rate(W: float, fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 6 — perfect weak scaling: no dependence on processor count."""
    return fc / (6.5 * W + 78.0)


def wse_implicit_rate(W: float, X: int, Y: int,
                      fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 16 — CG iteration rate; 2(X+Y) is the dual-reduction latency."""
    return fc / (10.5 * W + 2.0 * (X + Y) + 337.0)


def wse_dot_time(W: float, X: int, Y: int, fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 17 — one dot product (reduce-to-center + broadcast), seconds."""
    return (W + X + Y + 66.0) / fc


def openfoam_explicit_rate(W: int, n_cells: float) -> float:
    """Eqs. 4–5 — measured Joule 2.0 fits at the two benchmarked workloads."""
    if W == 4096:
        return 1.36e4 - 2.55e-4 * n_cells
    if W == 15625:
        return 4.20e3 - 1.37e-5 * n_cells
    raise ValueError(f"no fit for W={W}")


def openfoam_implicit_rate(W: int, n_cells: float) -> float:
    """Eqs. 13–15."""
    fits = {13824: (3.98e3, 2.75e-5), 21952: (2.45e3, 8.63e-6),
            27000: (2.05e3, 5.66e-6)}
    if W not in fits:
        raise ValueError(f"no fit for W={W}")
    a, b = fits[W]
    return a - b * n_cells


def gpu_max_rate(W: float, mem_bw: float) -> float:
    """Eq. 12 — optimistic single-field bound: R = w_m / (8W) (fp32, D_k=0)."""
    return mem_bw / (8.0 * W)


# -- TPU three-term roofline for the field solver ----------------------------

@dataclasses.dataclass
class StepCost:
    flops: float              # per chip per iteration
    hbm_bytes: float          # per chip per iteration
    collective_bytes: float   # per chip per iteration (ICI)
    hops: int = 1             # ICI hops on the critical path


def ftcs_brick_cost(bx: int, by: int, nz: int, dtype_bytes: int = 4,
                    halo_depth: int = 1) -> StepCost:
    """Per-chip cost of one FTCS step on a (bx, by, nz) brick.

    8 flops/cell (5 adds for the 6-neighbour sum + fmac + fmul, matching the
    paper's 8-flop count), 2 reads + 1 write per cell through HBM (stencil
    kernel re-uses neighbours in VMEM), 4 halo planes of ``halo_depth``.
    """
    w = bx * by * nz
    halo = 2 * (bx + by) * nz * halo_depth * dtype_bytes
    return StepCost(flops=8.0 * w,
                    hbm_bytes=2.0 * w * dtype_bytes,
                    collective_bytes=halo,
                    hops=1)


def cg_brick_cost(bx: int, by: int, nz: int, mesh_x: int, mesh_y: int,
                  dtype_bytes: int = 4, fused_reductions: bool = False
                  ) -> StepCost:
    """Per-chip cost of one classic-CG iteration (SpMV + 2 axpy + 2 dots)."""
    w = bx * by * nz
    halo = 2 * (bx + by) * nz * dtype_bytes
    n_red = 1 if fused_reductions else 2
    # all-reduce of a scalar: latency-dominated; charge diameter hops
    hops = n_red * 2 * (mesh_x + mesh_y)
    return StepCost(flops=15.0 * w,                    # paper: 15 vs 8 flops
                    hbm_bytes=10.0 * w * dtype_bytes,  # 5 vectors r/p/x/Ap/b
                    collective_bytes=halo + n_red * 8,
                    hops=hops)


def roofline_time(c: StepCost, *, flops_peak: float = TPU_V5E_FP32_FLOPS,
                  hbm_bw: float = TPU_V5E_HBM_BW,
                  ici_bw: float = TPU_V5E_ICI_BW,
                  hop_lat: float = TPU_V5E_ICI_LAT,
                  overlap_collective: bool = False) -> dict:
    """max(compute, memory) + collective  (Eq. 7 structure on TPU terms)."""
    t_comp = c.flops / flops_peak
    t_mem = c.hbm_bytes / hbm_bw
    t_coll = c.collective_bytes / ici_bw + c.hops * hop_lat
    if overlap_collective:
        total = max(t_comp, t_mem, t_coll)
    else:
        total = max(t_comp, t_mem) + t_coll
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "t_total": total, "rate": 1.0 / total,
            "bound": max(("compute", t_comp), ("memory", t_mem),
                         ("collective", t_coll), key=lambda kv: kv[1])[0]}

"""Analytic performance models — the paper's equations + TPU analogues.

Paper equations implemented verbatim (units: iterations/s unless noted):

* Eq. 4/5:   OpenFOAM explicit weak scaling on Joule 2.0
* Eq. 6:     WSE explicit roofline    R_i = F_c / (6.5 W + 78)
* Eq. 11/12: GPU bound  t_min = 8W / w_m ;  R_max = w_m / (8W)
* Eq. 13-15: OpenFOAM implicit weak scaling
* Eq. 16:    WSE CG roofline          R_i = F_c / (10.5 W + 2(X+Y) + 337)
* Eq. 17:    WSE dot product          t = (W + X + Y + 66) / F_c

TPU adaptation: the WSE counts cycles because compute, memory and fabric all
run at one cycle per element; a TPU chip does not, so the analogue is the
three-term roofline  t = max(t_compute, t_memory) + t_collective  (collective
unoverlapped, matching Eq. 7's max(comp, comm) + t_b structure), evaluated
from per-step FLOPs / bytes / collective-bytes.  Constants are TPU v5e.

Measured cost model
-------------------

The analytic equations predict *hardware* rates; the planner's tiling and
overlap decisions need the cost of *this* body on *this* device, so the
second half of the module is a measured model: :func:`calibrate` times one
lowered loop body at a few tile factors, fits the two-parameter launch+
throughput line, measures the halo-exchange and boundary-launch overheads,
and stores the result as a :class:`MeasuredCost` in the process-wide
:data:`cost_model` (persistable to a JSON manifest; point
``REPRO_COST_MANIFEST`` at one to pre-load it).  :func:`predict_step_us`
then scores any (brick, k, fused-vs-split) schedule with the Eq. 7
``max(comp, comm) + t_b`` structure, and ``auto_tile`` /
``RunOptions(overlap="auto")`` consume those scores.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Optional, Tuple

# -- hardware constants ------------------------------------------------------

WSE_CLOCK_HZ = 850e6          # CS-2 nominal fabric clock (used for Eq. 6/16)

TPU_V5E_BF16_FLOPS = 197e12   # peak bf16 FLOP/s per chip
TPU_V5E_FP32_FLOPS = 98.5e12  # fp32 ≈ half bf16 on v5e MXU
TPU_V5E_HBM_BW = 819e9        # B/s per chip
TPU_V5E_ICI_BW = 50e9         # B/s per link (~, per brief)
TPU_V5E_ICI_LAT = 1e-6        # s per hop (order of magnitude)


# -- paper equations ---------------------------------------------------------

def wse_explicit_rate(W: float, fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 6 — perfect weak scaling: no dependence on processor count."""
    return fc / (6.5 * W + 78.0)


def wse_implicit_rate(W: float, X: int, Y: int,
                      fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 16 — CG iteration rate; 2(X+Y) is the dual-reduction latency."""
    return fc / (10.5 * W + 2.0 * (X + Y) + 337.0)


def wse_dot_time(W: float, X: int, Y: int, fc: float = WSE_CLOCK_HZ) -> float:
    """Eq. 17 — one dot product (reduce-to-center + broadcast), seconds."""
    return (W + X + Y + 66.0) / fc


def openfoam_explicit_rate(W: int, n_cells: float) -> float:
    """Eqs. 4–5 — measured Joule 2.0 fits at the two benchmarked workloads."""
    if W == 4096:
        return 1.36e4 - 2.55e-4 * n_cells
    if W == 15625:
        return 4.20e3 - 1.37e-5 * n_cells
    raise ValueError(f"no fit for W={W}")


def openfoam_implicit_rate(W: int, n_cells: float) -> float:
    """Eqs. 13–15."""
    fits = {13824: (3.98e3, 2.75e-5), 21952: (2.45e3, 8.63e-6),
            27000: (2.05e3, 5.66e-6)}
    if W not in fits:
        raise ValueError(f"no fit for W={W}")
    a, b = fits[W]
    return a - b * n_cells


def gpu_max_rate(W: float, mem_bw: float) -> float:
    """Eq. 12 — optimistic single-field bound: R = w_m / (8W) (fp32, D_k=0)."""
    return mem_bw / (8.0 * W)


# -- TPU three-term roofline for the field solver ----------------------------

@dataclasses.dataclass
class StepCost:
    flops: float              # per chip per iteration
    hbm_bytes: float          # per chip per iteration
    collective_bytes: float   # per chip per iteration (ICI)
    hops: int = 1             # ICI hops on the critical path


def ftcs_brick_cost(bx: int, by: int, nz: int, dtype_bytes: int = 4,
                    halo_depth: int = 1) -> StepCost:
    """Per-chip cost of one FTCS step on a (bx, by, nz) brick.

    8 flops/cell (5 adds for the 6-neighbour sum + fmac + fmul, matching the
    paper's 8-flop count), 2 reads + 1 write per cell through HBM (stencil
    kernel re-uses neighbours in VMEM), 4 halo planes of ``halo_depth``.
    """
    w = bx * by * nz
    halo = 2 * (bx + by) * nz * halo_depth * dtype_bytes
    return StepCost(flops=8.0 * w,
                    hbm_bytes=2.0 * w * dtype_bytes,
                    collective_bytes=halo,
                    hops=1)


def cg_brick_cost(bx: int, by: int, nz: int, mesh_x: int, mesh_y: int,
                  dtype_bytes: int = 4, fused_reductions: bool = False
                  ) -> StepCost:
    """Per-chip cost of one classic-CG iteration (SpMV + 2 axpy + 2 dots)."""
    w = bx * by * nz
    halo = 2 * (bx + by) * nz * dtype_bytes
    n_red = 1 if fused_reductions else 2
    # all-reduce of a scalar: latency-dominated; charge diameter hops
    hops = n_red * 2 * (mesh_x + mesh_y)
    return StepCost(flops=15.0 * w,                    # paper: 15 vs 8 flops
                    hbm_bytes=10.0 * w * dtype_bytes,  # 5 vectors r/p/x/Ap/b
                    collective_bytes=halo + n_red * 8,
                    hops=hops)


def roofline_time(c: StepCost, *, flops_peak: float = TPU_V5E_FP32_FLOPS,
                  hbm_bw: float = TPU_V5E_HBM_BW,
                  ici_bw: float = TPU_V5E_ICI_BW,
                  hop_lat: float = TPU_V5E_ICI_LAT,
                  overlap_collective: bool = False) -> dict:
    """max(compute, memory) + collective  (Eq. 7 structure on TPU terms)."""
    t_comp = c.flops / flops_peak
    t_mem = c.hbm_bytes / hbm_bw
    t_coll = c.collective_bytes / ici_bw + c.hops * hop_lat
    if overlap_collective:
        total = max(t_comp, t_mem, t_coll)
    else:
        total = max(t_comp, t_mem) + t_coll
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "t_total": total, "rate": 1.0 / total,
            "bound": max(("compute", t_comp), ("memory", t_mem),
                         ("collective", t_coll), key=lambda kv: kv[1])[0]}


# -- measured cost model -----------------------------------------------------

#: env var naming a JSON manifest the process-wide model lazily pre-loads
MANIFEST_ENV = "REPRO_COST_MANIFEST"

#: manifest schema version (bump on incompatible entry-field changes)
MANIFEST_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class MeasuredCost:
    """Calibrated cost of one lowered loop body on one device.

    The fitted model is per *tile* (one fused launch advancing ``k`` steps):

        t_tile(k) = launch_us + exchange_us + cell_ns·cells(k) / 1000

    where ``cells(k)`` counts every sub-step output cell of the trapezoid
    (:func:`tile_cells` — the redundant halo recompute is what the model
    trades against the amortized exchange).  ``boundary_us`` is the extra
    fixed overhead of one boundary-shell launch in the overlap split.
    """

    signature: str     # body_signature() this entry was measured for
    device: str        # jax backend (+ ":interpret" under forced interpret)
    cell_ns: float     # fitted per-sub-step-output-cell time
    launch_us: float   # fixed per-tile overhead net of the exchange
    exchange_us: float  # margin refresh / halo exchange per tile
    boundary_us: float  # extra fixed overhead per boundary shell launch

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def current_device() -> str:
    """Device tag calibration entries are keyed under.

    Forced-interpret runs (``REPRO_FORCE_INTERPRET=1``) time the pallas
    interpreter, not compiled kernels, so they get a distinct tag — an
    interpret-mode manifest can never steer a compiled run.
    """
    import jax

    from repro.kernels.ops import _interpret

    tag = jax.default_backend()
    return tag + ":interpret" if _interpret() else tag


def body_signature(group, nz: int, dtype, device: Optional[str] = None) -> str:
    """Stable identity of (lowered body, z extent, dtype, device).

    Hashes the canonical tap form — not the source spelling — so any program
    that lowers to the same :class:`~repro.compiler.ir.LoweredGroup` shares
    one calibration entry.  Brick extent is deliberately *not* part of the
    key: the fitted model is evaluated per brick at plan time, which is what
    lets one calibration serve every decomposition of the same body.
    """
    import numpy as np

    if device is None:
        device = current_device()
    key = repr((tuple(group.updates), group.halo, int(nz),
                np.dtype(dtype).name, device))
    return hashlib.sha1(key.encode()).hexdigest()[:16]


def tile_cells(brick_xy: Tuple[int, int], nz: int, h: int, k: int) -> int:
    """Sub-step output cells of one monolithic k-tile on a brick.

    Trapezoid blocking: sub-step ``s`` writes the window that still has
    ``(k-1-s)·h`` of shrink left, so the first sub-step is the widest.

    >>> tile_cells((8, 8), 4, 1, 1)   # untiled: just the brick
    256
    >>> tile_cells((8, 8), 4, 1, 2)   # + one 10x10 first sub-step
    656
    """
    return sum((brick_xy[0] + 2 * (k - 1 - s) * h)
               * (brick_xy[1] + 2 * (k - 1 - s) * h)
               for s in range(k)) * nz


def _split_cells(brick_xy, nz: int, h: int, k: int):
    """(interior_cells, shell_cells, n_shells) of the overlap split, or
    ``None`` where the interior would be empty — the same geometry as
    :func:`repro.compiler.ir.split_regions` (depth ``m = k·h``: two
    full-height X slabs plus two X-interior Y strips)."""
    m = k * h
    bx, by = brick_xy
    if m == 0 or bx <= 2 * m or by <= 2 * m:
        return None
    interior = tile_cells((bx - 2 * m, by - 2 * m), nz, h, k)
    shells = (2 * tile_cells((m, by), nz, h, k)
              + 2 * tile_cells((bx - 2 * m, m), nz, h, k))
    return interior, shells, 4


def predict_step_us(cost: MeasuredCost, brick_xy: Tuple[int, int], nz: int,
                    h: int, k: int, split: bool = False) -> float:
    """Model time per *logical step* of one schedule, in microseconds.

    Fused: ``(L + E + c·cells(k)) / k`` — the whole exchange serializes with
    the launch.  Split (Eq. 7's ``max(comp, comm) + t_b``): the exchange
    travels while the interior computes, then the boundary shells pay their
    per-launch overhead::

        (L + max(c·cells_int, E) + n_shells·B + c·cells_shells) / k

    An illegal split (empty interior at depth ``k·h``) scores ``inf`` so it
    can never be selected.
    """
    cells = tile_cells(brick_xy, nz, h, k)
    if not split:
        t = cost.launch_us + cost.exchange_us + cost.cell_ns * cells * 1e-3
        return t / k
    sp = _split_cells(brick_xy, nz, h, k)
    if sp is None:
        return float("inf")
    int_cells, sh_cells, n_sh = sp
    t = (cost.launch_us
         + max(cost.cell_ns * int_cells * 1e-3, cost.exchange_us)
         + n_sh * cost.boundary_us
         + cost.cell_ns * sh_cells * 1e-3)
    return t / k


class CostModel:
    """In-process store of :class:`MeasuredCost` entries, keyed by signature.

    The module-level :data:`cost_model` instance is what the planner
    consults; it lazily merges the manifest named by ``REPRO_COST_MANIFEST``
    on first lookup, so calibration can happen in a separate process (the
    benchmark harness) and steer later runs.
    """

    def __init__(self):
        self.entries: Dict[str, MeasuredCost] = {}
        self._env_loaded = False

    def _maybe_load_env(self) -> None:
        if self._env_loaded:
            return
        self._env_loaded = True
        path = os.environ.get(MANIFEST_ENV)
        if path and os.path.exists(path):
            self.load_manifest(path)

    def put(self, entry: MeasuredCost) -> None:
        self.entries[entry.signature] = entry

    def get(self, signature: str) -> Optional[MeasuredCost]:
        self._maybe_load_env()
        return self.entries.get(signature)

    def lookup(self, group, nz: int, dtype) -> Optional[MeasuredCost]:
        """The planner's query: this body's entry for the current device."""
        return self.get(body_signature(group, nz, dtype))

    def clear(self) -> None:
        self.entries.clear()
        self._env_loaded = False

    def save_manifest(self, path: str) -> None:
        data = {"schema": MANIFEST_SCHEMA,
                "entries": {s: e.to_json() for s, e in self.entries.items()}}
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)

    def load_manifest(self, path: str) -> int:
        """Merge entries from ``path``; returns how many were loaded."""
        with open(path) as f:
            data = json.load(f)
        if data.get("schema") != MANIFEST_SCHEMA:
            raise ValueError(
                f"cost manifest {path}: schema {data.get('schema')!r} != "
                f"{MANIFEST_SCHEMA}")
        n = 0
        for sig, e in data.get("entries", {}).items():
            self.entries[sig] = MeasuredCost(
                signature=sig, device=e["device"],
                cell_ns=float(e["cell_ns"]),
                launch_us=float(e["launch_us"]),
                exchange_us=float(e["exchange_us"]),
                boundary_us=float(e["boundary_us"]))
            n += 1
        return n


#: process-wide model the planner consults (see :class:`CostModel`)
cost_model = CostModel()


def _fit_line(xs, ys) -> Tuple[float, float]:
    """Least-squares ``y = a·x + b`` with slope clamped non-negative."""
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx == 0.0:
        return 0.0, my
    a = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
    a = max(a, 0.0)
    return a, my - a * mx


def _time_step_us(step, env, reps: int, inner: int) -> Tuple[float, dict]:
    """Best-of-``reps`` steady-state time of ``env -> env`` in microseconds.

    Jits ``step`` with donated input and chains the env through every call,
    so what is timed is the executor's resident stepping, not a repack."""
    import time

    import jax

    run = jax.jit(step, donate_argnums=0)
    env = run({k: v for k, v in env.items()})  # compile + warm
    jax.block_until_ready(list(env.values()))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            env = run(env)
        jax.block_until_ready(list(env.values()))
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e6, env


def calibrate(ops, shapes: Dict[str, tuple], dtypes: Dict[str, object], *,
              ks: Tuple[int, ...] = (1, 2, 4), reps: int = 3,
              inner: int = 8, model: Optional[CostModel] = None,
              manifest: Optional[str] = None) -> MeasuredCost:
    """Measure one loop body's :class:`MeasuredCost` and store it.

    Times the resident fused step at each legal ``k`` in ``ks`` (steady
    state, donated buffers — the schedule the executor actually runs), fits
    ``t_tile = intercept + slope·cells(k)``, measures the margin refresh
    alone for ``exchange_us``, and one overlap-split step to expose the
    per-shell ``boundary_us``.  The entry lands in ``model`` (default: the
    process-wide :data:`cost_model`) and, when ``manifest`` names a path, in
    that JSON manifest too.  Raises
    :class:`~repro.compiler.ir.LoweringError` for bodies that do not fuse —
    there is nothing to calibrate for the interpreter path.
    """
    import jax.numpy as jnp

    from repro.compiler import lower_group
    from repro.compiler.codegen import compile_group
    from repro.engine.layout import HaloLayout, wrap_refresh
    from repro.engine.stats import stats

    group = lower_group(ops)
    name0 = group.fields_written()[0]
    nx, ny, nz = shapes[name0]
    dtype = dtypes[name0]
    h = group.halo

    legal = [k for k in ks
             if h == 0 or k * h <= min(nx, ny)]
    if not legal:
        legal = [1]

    def resident_env(K: int):
        env0 = {n: jnp.zeros(shapes[n], dtypes[n]) for n in shapes}
        return HaloLayout(pad=K, shapes=shapes).enter(env0)

    points = []  # (cells per tile, measured us per tile)
    for k in sorted(set(legal)):
        K = max(k * h, 0)
        step = compile_group(ops, shapes, dtypes, time_tile=k, group=group,
                             resident=K, interpret=_calib_interpret())
        t_us, _ = _time_step_us(step, resident_env(K), reps, inner)
        points.append((tile_cells((nx, ny), nz, h, k), t_us))

    slope_us, intercept_us = _fit_line([p[0] for p in points],
                                       [p[1] for p in points])
    cell_ns = slope_us * 1e3
    intercept_us = max(intercept_us, 0.0)

    # the exchange alone: the k=1-depth margin refresh on resident buffers
    exchange_us = 0.0
    if h > 0:
        K = h

        def refresh(env):
            return {n: wrap_refresh(v, K, h) for n, v in env.items()}

        exchange_us, _ = _time_step_us(refresh, resident_env(K), reps, inner)
        exchange_us = min(exchange_us, intercept_us)
    launch_us = max(intercept_us - exchange_us, 0.0)

    # one split step exposes the per-shell overhead
    boundary_us = launch_us
    from repro.compiler.ir import split_regions

    k_b = next((k for k in sorted(set(legal), reverse=True)
                if split_regions(group, k, (nx, ny)) is not None), None)
    if k_b is not None:
        int_cells, sh_cells, n_sh = _split_cells((nx, ny), nz, h, k_b)
        K = k_b * h
        step = compile_group(ops, shapes, dtypes, time_tile=k_b, group=group,
                             resident=K, overlap=True,
                             interpret=_calib_interpret())
        t_split, _ = _time_step_us(step, resident_env(K), reps, inner)
        spent = (launch_us + max(cell_ns * int_cells * 1e-3, exchange_us)
                 + cell_ns * sh_cells * 1e-3)
        boundary_us = max((t_split - spent) / n_sh, 0.0)

    entry = MeasuredCost(
        signature=body_signature(group, nz, dtype),
        device=current_device(),
        cell_ns=cell_ns,
        launch_us=launch_us,
        exchange_us=exchange_us,
        boundary_us=boundary_us,
    )
    if model is None:
        model = cost_model
    model.put(entry)
    stats.calibrations += 1
    if manifest:
        model.save_manifest(manifest)
    return entry


def _calib_interpret() -> bool:
    from repro.kernels.ops import _interpret

    return _interpret()


def calibrate_program(program, *, ks: Tuple[int, ...] = (1, 2, 4),
                      reps: int = 3, inner: int = 8,
                      model: Optional[CostModel] = None,
                      manifest: Optional[str] = None) -> Dict[str, MeasuredCost]:
    """Calibrate every fusible loop body of a recorded program.

    Returns ``{first written field: entry}`` per calibrated body; bodies
    that do not lower are skipped (they run on the interpreter, where the
    tiling decision the model steers does not exist).
    """
    from repro.compiler import LoweringError, lower_group
    from repro.core.program import _group_ops

    shapes = {n: f.shape for n, f in program.fields.items()}
    dtypes = {n: f.dtype for n, f in program.fields.items()}
    out: Dict[str, MeasuredCost] = {}
    for loop, ops in _group_ops(program):
        if loop is None:
            continue
        try:
            group = lower_group(ops)
        except LoweringError:
            continue
        entry = calibrate(ops, shapes, dtypes, ks=ks, reps=reps,
                          inner=inner, model=model, manifest=manifest)
        out[group.fields_written()[0]] = entry
    return out

"""Boundary handling — the "Moat" of the WFA.

The WFA surrounds Worker tiles with Moat tiles that pin boundary cells and
feed edge data so tensor ops complete "without stalls or hangs".  In the JAX
formulation boundary cells live inside the global array; updates write only
interior cells (the mask below), so Dirichlet values persist by construction
— exactly Eq. 2's ``T_C^{n+1} = T_C^n = γ  ∀ C ∈ bc``.

Masks are built lazily per (shape, module) and cached; in distributed mode
each brick derives its *local* mask from its mesh coordinates (only bricks on
the domain edge own Moat cells).
"""
from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=None)
def _interior_mask_np(nx: int, ny: int) -> np.ndarray:
    m = np.zeros((nx, ny, 1), dtype=bool)
    m[1:-1, 1:-1, :] = True
    return m


def interior_mask(shape_xy, xp):
    """(X, Y, 1) bool mask: True on cells whose x/y are interior.

    Z interiority is expressed by the update's target z-slice itself, so the
    mask only handles the X/Y Moat.
    """
    nx, ny = shape_xy
    m = _interior_mask_np(nx, ny)
    if xp is np:
        return m
    return xp.asarray(m)


@functools.lru_cache(maxsize=None)
def _local_interior_mask_np(bx: int, by: int, at_x_lo: bool, at_x_hi: bool,
                            at_y_lo: bool, at_y_hi: bool) -> np.ndarray:
    m = np.ones((bx, by, 1), dtype=bool)
    if at_x_lo:
        m[0, :, :] = False
    if at_x_hi:
        m[-1, :, :] = False
    if at_y_lo:
        m[:, 0, :] = False
    if at_y_hi:
        m[:, -1, :] = False
    return m


def local_interior_mask(brick_xy, coords, mesh_xy, xp):
    """Per-brick Moat mask from mesh coordinates (distributed mode)."""
    bx, by = brick_xy
    cx, cy = coords
    mx, my = mesh_xy
    m = _local_interior_mask_np(bx, by, cx == 0, cx == mx - 1,
                                cy == 0, cy == my - 1)
    return m if xp is np else xp.asarray(m)

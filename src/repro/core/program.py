"""Program capture — the analogue of the WFA's RPC bytecode.

The WFA compiles the user's Python into a bytecode sequence that a Control
Tile broadcasts as RPCs to Worker/Moat tiles.  This module records the
analogous artifact: fields and update ops captured into a :class:`Program`.
Execution is owned by the unified engine (:mod:`repro.engine`) — ``make``
hands the recording to ``engine.plan`` / ``engine.execute``, which schedule
every ``ForLoop`` body onto one of the interchangeable backends:

* ``numpy``   — the WFA "validation capability" (runs the ops eagerly in NumPy)
* ``jit``     — single-device compiled execution (roll interpreter under XLA)
* ``shard_map`` — distributed bricks with halo exchange (see core/halo.py)
* ``pallas``  — the program *compiler* (repro.compiler): every ForLoop body
  lowers to one fused Pallas kernel (all taps of all updates in a single
  VMEM pass — the WFA's fused-RPC win), optionally *time-tiled* so k steps
  share one halo exchange / wrap pad (``time_tile=``), with an interpreter
  fallback for bodies that cannot be lowered; pass ``mesh=`` to compose
  with shard_map.

This module keeps only the recording machinery plus the roll-based
interpreter step (:func:`_interp_step`) that the engine and solver share as
the semantic reference for every backend.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stencil as st
from repro.core.boundary import interior_mask

_STATE = threading.local()


def current_program() -> Optional["Program"]:
    return getattr(_STATE, "program", None)


def release_program(program: "Program") -> None:
    """Deactivate ``program`` if it is the thread-local active recording.

    Every consumer of a finished recording (``make``, ``solve``, the solver
    step builders, ``WFAInterface.__exit__``) funnels through here, so the
    deactivation rule lives in one place; the program object itself stays
    usable (e.g. for building a second solver from the same recording).
    """
    if current_program() is program:
        _STATE.program = None


@contextlib.contextmanager
def scoped_program():
    """Activate a fresh :class:`Program`, restoring any active one on exit.

    Lets library code (e.g. the :mod:`repro.solver` presets) record programs
    through the frontend without clobbering a user's active ``WFAInterface``.
    """
    prev = current_program()
    p = Program()
    _STATE.program = p
    try:
        yield p
    finally:
        _STATE.program = prev


@dataclasses.dataclass
class UpdateOp:
    """One recorded field update: ``field[target_z, 0, 0] = expr``."""

    field_name: str
    target_z: slice
    expr: st.StencilExpr
    loop: Optional["ForLoop"]


class ForLoop:
    """``with ForLoop('time_loop', n):`` — the WFA's ``WSE_For_Loop``."""

    def __init__(self, name: str, n: int):
        self.name = name
        self.n = int(n)

    def __enter__(self):
        p = current_program()
        if p is None:
            raise RuntimeError("ForLoop must be used inside a WFAInterface")
        p._loop_stack.append(self)
        return self

    def __exit__(self, *exc):
        current_program()._loop_stack.pop()
        return False


class Program:
    def __init__(self):
        self.fields: Dict[str, "Field"] = {}
        self.ops: List[UpdateOp] = []
        self._loop_stack: List[ForLoop] = []

    def register_field(self, field) -> None:
        if field.name in self.fields:
            raise ValueError(f"duplicate field name {field.name!r}")
        self.fields[field.name] = field

    def record_update(self, field, target_z: slice, expr: st.StencilExpr):
        # Normalize every z slice (target and terms) to concrete non-negative
        # (start, stop) via slice.indices, so negative-start spellings like
        # T[-9:-1, 0, 0] validate and evaluate identically to their
        # non-negative equivalents, and the compiler can compute z deltas by
        # plain subtraction of starts.
        n = field.shape[2]
        t0, t1, _ = target_z.indices(n)
        target_z = slice(t0, t1)
        nz_of = {name: f.shape[2] for name, f in self.fields.items()}
        for t in expr.terms():
            if t.field_name not in nz_of:
                raise ValueError(
                    f"term references field {t.field_name!r} that is not "
                    "registered in this program")
        expr = st.normalize_zslices(expr, nz_of)
        tlen = t1 - t0
        for t in expr.terms():
            zlen = t.zslice[1] - t.zslice[0]
            if zlen != tlen:
                raise ValueError(
                    f"term {t.field_name}[{t.zslice}] length {zlen} != "
                    f"target length {tlen}"
                )
        loop = self._loop_stack[-1] if self._loop_stack else None
        self.ops.append(UpdateOp(field.name, target_z, expr, loop))


class WFAInterface:
    """The user-facing entry point (the WFA's ``WSE_Interface``).

    ``with WFAInterface() as wse:`` activates a program; Fields created and
    updated inside the context are recorded; ``wse.make(answer=...)``
    compiles and runs, returning the final value of ``answer``.

    It can also be used without the context-manager form, matching the
    paper's flat-script style: instantiation activates the program and
    ``make`` deactivates it.
    """

    def __init__(self):
        if current_program() is not None:
            raise RuntimeError("another WFAInterface program is active")
        self.program = Program()
        _STATE.program = self.program

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        release_program(self.program)
        return False

    # -- execution ---------------------------------------------------------
    def make(self, answer, backend=None, mesh=None, time_tile=None,
             resident=None, *, options=None, env=None):
        """Compile and run the recorded program; returns ``answer``'s data.

        (the WFA's ``make_WSE``; backend ``'numpy'`` is its validation mode.)
        Dispatches through the unified engine (:mod:`repro.engine`):
        execution policy travels as one frozen ``options=RunOptions(...)``
        bundle (a bare string is accepted as the backend).  ``mesh=`` runs
        brick-sharded inside ``shard_map``; ``time_tile=k`` fuses k steps
        per kernel launch on the ``pallas`` backend (one halo exchange /
        wrap pad per tile; ``None`` lets the planner auto-pick); and
        ``batch=B`` advances a B-member ensemble per launch — every field
        stacks to ``(B, X, Y, Z)`` and ``make`` returns the stacked answer
        (see :class:`repro.core.ensemble.Ensemble` for per-member values,
        which arrive through ``env=``).  The legacy ``backend=`` / ``mesh=``
        / ``time_tile=`` / ``resident=`` keywords are deprecation shims
        that warn once and forward into the bundle.

        Fused runs step on a *halo-resident* field layout (standing padded
        buffers, in-place margin refresh + kernel outputs, donated entry
        buffers — see :mod:`repro.engine.layout`); ``resident=False`` forces
        the legacy repack-per-launch stepping, which is bitwise identical.

        Example — three steps of pure decay on the interior (the Moat ring
        and the unwritten z planes keep their boundary values):

        >>> import numpy as np
        >>> from repro.core import Field, ForLoop, WFAInterface
        >>> from repro.engine import RunOptions
        >>> wse = WFAInterface()
        >>> T = Field("T", init_data=np.ones((6, 6, 4), np.float32))
        >>> with ForLoop("time_loop", 3):
        ...     T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
        >>> out = wse.make(answer=T, options=RunOptions(backend="numpy"))
        >>> float(out[2, 2, 1]), float(out[0, 2, 1])
        (0.125, 1.0)
        """
        from repro.engine.options import UNSET, resolve_options

        options = resolve_options(
            options, "make",
            backend=UNSET if backend is None else backend,
            mesh=UNSET if mesh is None else mesh,
            time_tile=UNSET if time_tile is None else time_tile,
            resident=UNSET if resident is None else resident,
        )
        for op in self.program.ops:
            if getattr(op.loop, "role", None) is not None:
                # deactivate like every other exit path from make(); the
                # program object itself stays usable for wse.solve(...)
                release_program(self.program)
                raise ValueError(
                    "this program records an implicit system "
                    "(Operator()/Rhs() groups); run wse.solve(answer, ...) "
                    "instead of make")
        try:
            from repro.engine import run_program
            out = run_program(self.program, env=env, options=options)
        finally:
            release_program(self.program)
        return np.asarray(out[answer.name])

    def solve(self, answer, method: str = "cg", backend=None,
              mesh=None, **kwargs):
        """Solve the recorded implicit system ``A(x) = b`` for ``answer``.

        The operator body (recorded inside ``with Operator():``) compiles
        through the same IR → fused-Pallas pipeline as explicit programs;
        matrix-free iterations run on top of the compiled application —
        Krylov methods, or geometric multigrid via ``method="mg"`` /
        ``precondition="mg"``.  Policy travels as ``options=RunOptions(...)``
        (backend defaults to ``"pallas"``; ``batch=B`` solves a B-member
        ensemble in one masked loop).  See :func:`repro.solver.solve` for
        the full keyword surface (``steps``, ``tol``, ``maxiter``,
        ``lambda_bounds``, ``precondition``, ``mg_opts``, ``return_info``,
        ``member_env``).
        """
        from repro.solver.api import solve as _solve
        try:
            return _solve(self.program, answer, method=method,
                          backend=backend, mesh=mesh, **kwargs)
        finally:
            release_program(self.program)

    # paper-compatible alias
    make_WSE = make


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def _group_ops(program: Program):
    """Group consecutive ops that share a loop: [(loop_or_None, [ops])]."""
    groups = []
    for op in program.ops:
        if groups and groups[-1][0] is op.loop:
            groups[-1][1].append(op)
        else:
            groups.append((op.loop, [op]))
    return groups


def _apply_op(op: UpdateOp, env, xp, roll):
    val = st.evaluate(op.expr, env, op.target_z, xp, roll)
    field = env[op.field_name]
    nx, ny, _ = field.shape
    mask = interior_mask((nx, ny), xp)  # (X, Y, 1): Moat cells stay fixed
    if xp is np:
        new = field.copy()
        new[:, :, op.target_z] = xp.where(
            mask, val, field[:, :, op.target_z])
        return new
    new_z = xp.where(mask, val, field[:, :, op.target_z])
    start = op.target_z.indices(field.shape[2])[0]
    return jax.lax.dynamic_update_slice(field, new_z, (0, 0, start))


def _interp_step(ops):
    """Traced interpreter step for one op group: one roll per stencil term.

    Shared by the ``jit`` backend and the ``pallas`` backend's fallback path
    (both via :func:`repro.engine.compile_body`) so their semantics cannot
    diverge — this is the semantic reference every backend is tested
    against.
    """
    roll = lambda a, s, ax: jnp.roll(a, s, axis=ax)

    def f(e):
        e = dict(e)
        for op in ops:
            e[op.field_name] = _apply_op(op, e, jnp, roll)
        return e
    return f

"""Program capture and compilation — the analogue of the WFA's RPC bytecode.

The WFA compiles the user's Python into a bytecode sequence that a Control
Tile broadcasts as RPCs to Worker/Moat tiles.  On TPU the analogous artifact
is an XLA SPMD executable: we trace the recorded update ops into one step
function, wrap the time loop in ``lax.fori_loop`` and ``jax.jit`` the result.
Three backends mirror the WFA's workflow:

* ``numpy``   — the WFA "validation capability" (runs the ops eagerly in NumPy)
* ``jit``     — single-device compiled execution
* ``shard_map`` — distributed bricks with halo exchange (see core/halo.py)
* ``pallas``  — the program *compiler* (repro.compiler): every ForLoop body
  lowers to one fused Pallas kernel (all taps of all updates in a single
  VMEM pass — the WFA's fused-RPC win) with an interpreter fallback for
  bodies that cannot be lowered; pass ``mesh=`` to compose with shard_map.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stencil as st
from repro.core.boundary import interior_mask

_STATE = threading.local()


def current_program() -> Optional["Program"]:
    return getattr(_STATE, "program", None)


@contextlib.contextmanager
def scoped_program():
    """Activate a fresh :class:`Program`, restoring any active one on exit.

    Lets library code (e.g. the :mod:`repro.solver` presets) record programs
    through the frontend without clobbering a user's active ``WFAInterface``.
    """
    prev = current_program()
    p = Program()
    _STATE.program = p
    try:
        yield p
    finally:
        _STATE.program = prev


@dataclasses.dataclass
class UpdateOp:
    """One recorded field update: ``field[target_z, 0, 0] = expr``."""

    field_name: str
    target_z: slice
    expr: st.StencilExpr
    loop: Optional["ForLoop"]


class ForLoop:
    """``with ForLoop('time_loop', n):`` — the WFA's ``WSE_For_Loop``."""

    def __init__(self, name: str, n: int):
        self.name = name
        self.n = int(n)

    def __enter__(self):
        p = current_program()
        if p is None:
            raise RuntimeError("ForLoop must be used inside a WFAInterface")
        p._loop_stack.append(self)
        return self

    def __exit__(self, *exc):
        current_program()._loop_stack.pop()
        return False


class Program:
    def __init__(self):
        self.fields: Dict[str, "Field"] = {}
        self.ops: List[UpdateOp] = []
        self._loop_stack: List[ForLoop] = []

    def register_field(self, field) -> None:
        if field.name in self.fields:
            raise ValueError(f"duplicate field name {field.name!r}")
        self.fields[field.name] = field

    def record_update(self, field, target_z: slice, expr: st.StencilExpr):
        # Normalize every z slice (target and terms) to concrete non-negative
        # (start, stop) via slice.indices, so negative-start spellings like
        # T[-9:-1, 0, 0] validate and evaluate identically to their
        # non-negative equivalents, and the compiler can compute z deltas by
        # plain subtraction of starts.
        n = field.shape[2]
        t0, t1, _ = target_z.indices(n)
        target_z = slice(t0, t1)
        nz_of = {name: f.shape[2] for name, f in self.fields.items()}
        for t in expr.terms():
            if t.field_name not in nz_of:
                raise ValueError(
                    f"term references field {t.field_name!r} that is not "
                    "registered in this program")
        expr = st.normalize_zslices(expr, nz_of)
        tlen = t1 - t0
        for t in expr.terms():
            zlen = t.zslice[1] - t.zslice[0]
            if zlen != tlen:
                raise ValueError(
                    f"term {t.field_name}[{t.zslice}] length {zlen} != "
                    f"target length {tlen}"
                )
        loop = self._loop_stack[-1] if self._loop_stack else None
        self.ops.append(UpdateOp(field.name, target_z, expr, loop))


class WFAInterface:
    """The user-facing entry point (the WFA's ``WSE_Interface``).

    ``with WFAInterface() as wse:`` activates a program; Fields created and
    updated inside the context are recorded; ``wse.make(answer=...)``
    compiles and runs, returning the final value of ``answer``.

    It can also be used without the context-manager form, matching the
    paper's flat-script style: instantiation activates the program and
    ``make`` deactivates it.
    """

    def __init__(self):
        if current_program() is not None:
            raise RuntimeError("another WFAInterface program is active")
        self.program = Program()
        _STATE.program = self.program

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if current_program() is self.program:
            _STATE.program = None
        return False

    # -- execution ---------------------------------------------------------
    def make(self, answer, backend: str = "jit", mesh=None):
        """Compile and run the recorded program; returns ``answer``'s data.

        (the WFA's ``make_WSE``; ``backend='numpy'`` is its validation mode.)
        """
        for op in self.program.ops:
            if getattr(op.loop, "role", None) is not None:
                # deactivate like every other exit path from make(); the
                # program object itself stays usable for wse.solve(...)
                if current_program() is self.program:
                    _STATE.program = None
                raise ValueError(
                    "this program records an implicit system "
                    "(Operator()/Rhs() groups); run wse.solve(answer, ...) "
                    "instead of make")
        try:
            env = {n: f.init_data for n, f in self.program.fields.items()}
            if backend == "numpy":
                out = _run_numpy(self.program, env)
            elif backend == "jit":
                out = _run_jax(self.program, env)
            elif backend == "shard_map":
                from repro.core.halo import run_sharded
                out = run_sharded(self.program, env, mesh=mesh)
            elif backend == "pallas":
                if mesh is not None:
                    from repro.core.halo import run_sharded
                    out = run_sharded(self.program, env, mesh=mesh,
                                      use_pallas=True)
                else:
                    out = _run_pallas(self.program, env)
            else:
                raise ValueError(f"unknown backend {backend!r}")
        finally:
            if current_program() is self.program:
                _STATE.program = None
        return np.asarray(out[answer.name])

    def solve(self, answer, method: str = "cg", backend: str = "pallas",
              mesh=None, **kwargs):
        """Solve the recorded implicit system ``A(x) = b`` for ``answer``.

        The operator body (recorded inside ``with Operator():``) compiles
        through the same IR → fused-Pallas pipeline as explicit programs;
        matrix-free Krylov iterations run on top of the compiled
        application.  See :func:`repro.solver.solve` for the full keyword
        surface (``steps``, ``tol``, ``maxiter``, ``lambda_bounds``,
        ``return_info``).
        """
        from repro.solver.api import solve as _solve
        try:
            return _solve(self.program, answer, method=method,
                          backend=backend, mesh=mesh, **kwargs)
        finally:
            if current_program() is self.program:
                _STATE.program = None

    # paper-compatible alias
    make_WSE = make


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def _group_ops(program: Program):
    """Group consecutive ops that share a loop: [(loop_or_None, [ops])]."""
    groups = []
    for op in program.ops:
        if groups and groups[-1][0] is op.loop:
            groups[-1][1].append(op)
        else:
            groups.append((op.loop, [op]))
    return groups


def _apply_op(op: UpdateOp, env, xp, roll):
    val = st.evaluate(op.expr, env, op.target_z, xp, roll)
    field = env[op.field_name]
    nx, ny, _ = field.shape
    mask = interior_mask((nx, ny), xp)  # (X, Y, 1): Moat cells stay fixed
    if xp is np:
        new = field.copy()
        new[:, :, op.target_z] = xp.where(
            mask, val, field[:, :, op.target_z])
        return new
    new_z = xp.where(mask, val, field[:, :, op.target_z])
    start = op.target_z.indices(field.shape[2])[0]
    return jax.lax.dynamic_update_slice(field, new_z, (0, 0, start))


def _run_numpy(program: Program, env):
    env = {k: v.copy() for k, v in env.items()}
    roll = lambda a, s, ax: np.roll(a, s, axis=ax)
    for loop, ops in _group_ops(program):
        n = loop.n if loop is not None else 1
        for _ in range(n):
            for op in ops:
                env[op.field_name] = _apply_op(op, env, np, roll)
    return env


def _interp_step(ops):
    """Traced interpreter step for one op group: one roll per stencil term.

    Shared by the ``jit`` backend and the ``pallas`` backend's fallback path
    so their semantics cannot diverge.
    """
    roll = lambda a, s, ax: jnp.roll(a, s, axis=ax)

    def f(e):
        e = dict(e)
        for op in ops:
            e[op.field_name] = _apply_op(op, e, jnp, roll)
        return e
    return f


def _run_jax(program: Program, env):
    env = {k: jnp.asarray(v) for k, v in env.items()}

    @jax.jit
    def run(env):
        for loop, ops in _group_ops(program):
            step = _interp_step(ops)
            if loop is None:
                env = step(env)
            else:
                env = jax.lax.fori_loop(0, loop.n, lambda i, e: step(e), env)
        return env

    return jax.device_get(run(env))


def _run_pallas(program: Program, env):
    """Compiled backend: one fused Pallas kernel per ForLoop body.

    Each loop body is lowered through repro.compiler (IR normalization →
    fused-kernel codegen, memoized by program signature); bodies that cannot
    be lowered fall back to the roll-based interpreter step with a logged
    reason, inside the same jitted run.
    """
    from repro.compiler import compile_group, try_compile
    from repro.kernels.ops import _interpret

    env = {k: jnp.asarray(v) for k, v in env.items()}
    shapes = {n: f.shape for n, f in program.fields.items()}
    dtypes = {n: env[n].dtype for n in env}

    steps = []
    for loop, ops in _group_ops(program):
        step = try_compile(
            lambda: compile_group(ops, shapes, dtypes,
                                  interpret=_interpret()), loop)
        steps.append((loop, step if step is not None else _interp_step(ops)))

    @jax.jit
    def run(env):
        for loop, step in steps:
            if loop is None:
                env = step(env)
            else:
                env = jax.lax.fori_loop(0, loop.n, lambda i, e: step(e), env)
        return env

    return jax.device_get(run(env))

"""Explicit FTCS heat-equation solver (paper Eq. 2) — functional API.

Three tiers, all computing the same update:

* :func:`ftcs_step` / :func:`ftcs_solve` — single-device reference (the
  shape the WFA "general-purpose implementation" lowers to);
* :func:`make_sharded_ftcs` — brick-decomposed ``shard_map`` solver with
  halo exchange; ``overlap=True`` splits interior/edge compute so XLA can
  hide the ppermute behind the interior stencil (the WFA's background-thread
  send/recv overlap); ``halo_depth=k`` enables communication-avoiding wide
  halos (k local steps per exchange) — a beyond-paper optimization;
* ``use_kernel=True`` routes the per-brick update through the fused Pallas
  stencil kernel (the paper's single-RPC custom kernel, Fig. 3 right).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxcompat import shard_map
from repro.core.halo import halo_pad, local_moat_mask


# ---------------------------------------------------------------------------
# single-device reference
# ---------------------------------------------------------------------------

def interior_mask3d(shape, xp=jnp):
    nx, ny, nz = shape
    m = np.zeros(shape, dtype=bool)
    m[1:-1, 1:-1, 1:-1] = True
    return m if xp is np else xp.asarray(m)


def neighbor_sum_padded(P):
    """6-neighbour sum from a halo-padded (bx+2, by+2, Z) brick → (bx,by,Z)."""
    c = P[1:-1, 1:-1, :]
    s = (P[:-2, 1:-1, :] + P[2:, 1:-1, :] + P[1:-1, :-2, :] + P[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    return s + zp + zm


def ftcs_step(T, w: float, mask=None):
    """One FTCS step on the full (X, Y, Z) grid; boundaries stay fixed."""
    if mask is None:
        mask = interior_mask3d(T.shape)
    P = jnp.pad(T, ((1, 1), (1, 1), (0, 0)))  # zero halo; masked cells unaffected
    new = (1.0 - 6.0 * w) * T + w * neighbor_sum_padded(P)
    return jnp.where(mask, new, T)


@partial(jax.jit, static_argnames=("steps", "w"))
def ftcs_solve_repack(T0, w: float, steps: int):
    """The pre-residency stepping: one full-grid zero pad + two z-shift
    copies per step (``ftcs_step`` in a loop).  Kept as the semantic and
    performance *before* reference for :func:`ftcs_solve` — benchmarks emit
    both so the zero-repack win stays measurable per container."""
    mask = interior_mask3d(T0.shape)
    return jax.lax.fori_loop(
        0, steps, lambda i, T: ftcs_step(T, w, mask), T0)


@partial(jax.jit, static_argnames=("steps", "w"))
def ftcs_solve(T0, w: float, steps: int):
    """FTCS time loop with zero-repack stepping (same update as
    :func:`ftcs_step`, to FMA rounding).

    The repacking step rebuilds three full-grid copies per step: a padded
    input (``jnp.pad``) and two z-shifted concatenations.  Here the Dirichlet
    structure makes all three redundant — boundary cells never change, so

    * the two fixed z faces stay *resident*: only the inner (X, Y, Z-2) slab
      is padded (in X/Y) and stepped, and the z-neighbour terms are plain
      z-slices of the full array instead of shifted copies;
    * the X/Y Moat ring is pinned by a broadcast iota mask (no materialized
      3-D mask array to stream).

    Per step that is one inner-slab pad + one fused stencil pass — on the
    CPU container this is the ≥25 % ``explicit_weak`` win recorded in
    BENCH_resident.json, and the same structure XLA:TPU fuses best.
    """
    nx, ny, nz = T0.shape
    if nz < 3:
        return T0  # no interior z plane: every cell is boundary-pinned
    row = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, 1), 1)
    mask_xy = (row > 0) & (row < nx - 1) & (col > 0) & (col < ny - 1)

    def step(i, T):
        Ti = T[:, :, 1:-1]
        P = jnp.pad(Ti, ((1, 1), (1, 1), (0, 0)))
        s = (P[:-2, 1:-1, :] + P[2:, 1:-1, :]
             + P[1:-1, :-2, :] + P[1:-1, 2:, :])
        zsum = T[:, :, :-2] + T[:, :, 2:]
        new = (1.0 - 6.0 * w) * Ti + w * (s + zsum)
        new = jnp.where(mask_xy, new, Ti)
        return jnp.concatenate([T[:, :, :1], new, T[:, :, -1:]], axis=2)

    return jax.lax.fori_loop(0, steps, step, T0)


@partial(jax.jit, static_argnames=("steps", "w", "chunk"))
def ftcs_solve_checkpointed(T0, w: float, steps: int, chunk: int = 0):
    """:func:`ftcs_solve` with a checkpointed reverse sweep.

    Same forward values (the step body is shared; the unrolled remainder
    may fuse differently by at most an ulp), but structured
    for ``jax.grad``: the time loop runs as a ``lax.scan`` over
    ``jax.checkpoint``-wrapped chunks of ``chunk`` steps (default
    ``⌈√steps⌉``), so the reverse pass stores one state per chunk and
    recomputes inside — O(√n) residual memory instead of the O(n) a naive
    differentiable loop saves, at one extra forward pass of compute.  The
    remainder ``steps % chunk`` runs unrolled after the scan.
    """
    nx, ny, nz = T0.shape
    if nz < 3 or steps <= 0:
        return T0
    if chunk <= 0:
        chunk = max(1, int(np.ceil(np.sqrt(steps))))
    row = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, 1), 1)
    mask_xy = (row > 0) & (row < nx - 1) & (col > 0) & (col < ny - 1)

    def step(T):
        Ti = T[:, :, 1:-1]
        P = jnp.pad(Ti, ((1, 1), (1, 1), (0, 0)))
        s = (P[:-2, 1:-1, :] + P[2:, 1:-1, :]
             + P[1:-1, :-2, :] + P[1:-1, 2:, :])
        zsum = T[:, :, :-2] + T[:, :, 2:]
        new = (1.0 - 6.0 * w) * Ti + w * (s + zsum)
        new = jnp.where(mask_xy, new, Ti)
        return jnp.concatenate([T[:, :, :1], new, T[:, :, -1:]], axis=2)

    n_chunks, rem = divmod(steps, chunk)

    @jax.checkpoint
    def chunk_fn(T):
        return jax.lax.fori_loop(0, chunk, lambda i, t: step(t), T)

    T = T0
    if n_chunks:
        T, _ = jax.lax.scan(lambda t, _: (chunk_fn(t), None), T, None,
                            length=n_chunks)
    for _ in range(rem):
        T = step(T)
    return T


# ---------------------------------------------------------------------------
# distributed bricks
# ---------------------------------------------------------------------------

def _fix_z_boundary(new, T):
    return jnp.concatenate([T[:, :, :1], new[:, :, 1:-1], T[:, :, -1:]], axis=2)


def ftcs_brick_step(T, w, mask, ax_x, ax_y, mx, my):
    """Plain halo-exchange step on one brick (paper-faithful schedule)."""
    P = halo_pad(T, 1, ax_x, ax_y, mx, my)
    new = (1.0 - 6.0 * w) * T + w * neighbor_sum_padded(P)
    return _fix_z_boundary(jnp.where(mask, new, T), T)


def ftcs_brick_step_overlapped(T, w, mask, ax_x, ax_y, mx, my):
    """Interior/edge split: ppermute overlaps with the interior stencil.

    The interior block (cells ≥1 from the brick edge) only reads local data,
    so XLA schedules it concurrently with the halo collective — the TPU
    analogue of the WFA launching send/recv background threads and summing
    local top/bottom first.
    """
    P = halo_pad(T, 1, ax_x, ax_y, mx, my)          # collective-start
    # interior stencil — no halo dependency
    c = T[1:-1, 1:-1, :]
    s_in = (T[:-2, 1:-1, :] + T[2:, 1:-1, :]
            + T[1:-1, :-2, :] + T[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    s_in = s_in + zp + zm
    # edge strips — read the received halo (collective-done)
    full = neighbor_sum_padded(P)
    s = jnp.concatenate([
        full[:1, :, :],
        jnp.concatenate([full[1:-1, :1, :], s_in, full[1:-1, -1:, :]], axis=1),
        full[-1:, :, :],
    ], axis=0)
    new = (1.0 - 6.0 * w) * T + w * s
    return _fix_z_boundary(jnp.where(mask, new, T), T)


def _padded_moat_mask(bx, by, h, ax_x, ax_y, mx, my):
    """Interior mask over a depth-h padded brick (global coords, traced)."""
    cx = jax.lax.axis_index(ax_x)
    cy = jax.lax.axis_index(ax_y)
    px, py = bx + 2 * h, by + 2 * h
    gx = cx * bx - h + jax.lax.broadcasted_iota(jnp.int32, (px, py, 1), 0)
    gy = cy * by - h + jax.lax.broadcasted_iota(jnp.int32, (px, py, 1), 1)
    nx, ny = mx * bx, my * by
    return (gx > 0) & (gx < nx - 1) & (gy > 0) & (gy < ny - 1)


def ftcs_brick_step_wide(T, w, k: int, ax_x, ax_y, mx, my):
    """Communication-avoiding: one depth-k exchange, k local steps.

    After local step j, padded cells at distance ≥ j from the padded edge are
    exact; the central brick (distance k) is exact after k steps.  Domain-
    boundary cells are pinned by the padded moat mask, so out-of-domain halo
    junk never propagates inward (it is only adjacent to pinned cells).
    """
    bx, by, _ = T.shape
    P = halo_pad(T, k, ax_x, ax_y, mx, my)
    mask = _padded_moat_mask(bx, by, k, ax_x, ax_y, mx, my)

    def one(j, P):
        PP = jnp.pad(P, ((1, 1), (1, 1), (0, 0)))
        new = (1.0 - 6.0 * w) * P + w * neighbor_sum_padded(PP)
        return _fix_z_boundary(jnp.where(mask, new, P), P)

    P = jax.lax.fori_loop(0, k, one, P)
    return P[k:-k, k:-k, :]


def make_sharded_ftcs(mesh, shape, w: float, *, overlap: bool = False,
                      halo_depth: int = 1, use_kernel=False,
                      steps_per_call: int = 1):
    """Build a jitted, brick-decomposed FTCS stepper over ``mesh``.

    Returns ``(step_fn, sharding)``; ``step_fn(T_global)`` advances
    ``steps_per_call`` (× ``halo_depth``) time steps.  ``use_kernel``:
    True → fused Pallas stencil on the padded brick; ``"planes"`` → the
    fully-fused kernel taking raw halo planes (no pad-concat, in-kernel
    moat — the optimized §Perf variant).
    """
    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    mx, my = mesh.shape[ax_x], mesh.shape[ax_y]
    nx, ny, nz = shape
    assert nx % mx == 0 and ny % my == 0, (shape, mesh.shape)
    bx, by = nx // mx, ny // my
    spec = jax.sharding.PartitionSpec(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)

    if use_kernel:
        from repro.kernels import ops as kops
    from repro.core.halo import _ppermute_shift

    def local(T):
        mask = local_moat_mask(bx, by, ax_x, ax_y, mx, my)

        def body(i, T):
            if halo_depth > 1:
                return ftcs_brick_step_wide(T, w, halo_depth, ax_x, ax_y, mx, my)
            if use_kernel == "planes":
                xlo = _ppermute_shift(T[-1:, :, :], ax_x, mx, +1)
                xhi = _ppermute_shift(T[:1, :, :], ax_x, mx, -1)
                ylo = _ppermute_shift(T[:, -1:, :], ax_y, my, +1)
                yhi = _ppermute_shift(T[:, :1, :], ax_y, my, -1)
                coords = jnp.stack(
                    [jax.lax.axis_index(ax_x),
                     jax.lax.axis_index(ax_y)]).astype(jnp.int32)[None, :]
                return kops.stencil7_planes(T, xlo, xhi, ylo, yhi, coords,
                                            1.0 - 6.0 * w, w, nx, ny)
            if use_kernel:
                P = halo_pad(T, 1, ax_x, ax_y, mx, my)
                new = kops.stencil7(P, 1.0 - 6.0 * w, w)
                return _fix_z_boundary(jnp.where(mask, new, T), T)
            if overlap:
                return ftcs_brick_step_overlapped(T, w, mask, ax_x, ax_y, mx, my)
            return ftcs_brick_step(T, w, mask, ax_x, ax_y, mx, my)

        return jax.lax.fori_loop(0, steps_per_call, body, T)

    step = jax.jit(shard_map(local, mesh=mesh, in_specs=(spec,),
                                 out_specs=spec, check=False))
    return step, sharding

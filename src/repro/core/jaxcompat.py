"""JAX version compatibility (0.4.x ↔ 0.5+).

The sharded layers are written against the modern spellings
(``jax.shard_map(..., check_vma=)``, ``jax.make_mesh(..., axis_types=)``);
on 0.4.x those live in ``jax.experimental.shard_map`` (``check_rep=``) and
``axis_types`` does not exist.  Every call site routes through here so the
same tree runs on both.  (The Pallas analogue lives in
:mod:`repro.kernels.compat`.)
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def make_mesh(shape, axis_names):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)

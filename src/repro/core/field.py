"""``Field`` — the JAX analogue of the WFA's ``WSE_Array``.

A field is a named (X, Y, Z) array living on the device mesh.  Indexing with
the paper's ``[zslice, dx, dy]`` convention yields a lazy stencil term;
assigning an expression records an update into the active
:class:`~repro.core.program.Program` (the analogue of the WFA bytecode
sequence interpreted by the Control Tile).

Example — the explicit heat step, verbatim from the paper's Fig. 3::

    wse = WFAInterface()
    T_n = Field('T_n', init_data=T_init)
    with ForLoop('time_loop', 40000):
        T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] \
            + c * (T_n[2:, 0, 0] + T_n[:-2, 0, 0]
                   + T_n[1:-1, 1, 0] + T_n[1:-1, 0, -1]
                   + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])
    result = wse.make(answer=T_n)
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import program as prog_mod
from repro.core.stencil import StencilExpr, Term


def _norm_zslice(s) -> Tuple:
    if isinstance(s, slice):
        if s.step not in (None, 1):
            raise ValueError("strided z slices are not supported by the WFA")
        return (s.start, s.stop, None)
    raise TypeError("axis 0 of a Field index must be a slice (local Z cells)")


def _norm_offset(v, axis: str) -> int:
    if not isinstance(v, int):
        raise TypeError(
            f"axis {axis} of a Field index is a relative tile offset; got {v!r}"
        )
    # The first-generation WFA understands only the immediate neighbourhood;
    # we support arbitrary radius (wide halos) as a beyond-paper extension,
    # but validate it is a plain int.
    return v


class Field:
    """A named field on the grid, stored as a global (X, Y, Z) array.

    The paper stores fields tile-local as (Z,) columns over an (X, Y) fabric;
    globally that is exactly an (X, Y, Z) tensor, which is how we shard it:
    X over the ``data`` mesh axis, Y over ``model``, Z unsharded (the 1×1×Z
    column decomposition).
    """

    def __init__(self, name: str, init_data: Optional[np.ndarray] = None,
                 shape: Optional[Tuple[int, int, int]] = None,
                 dtype=np.float32):
        if init_data is None:
            if shape is None:
                raise ValueError("need init_data or shape")
            init_data = np.zeros(shape, dtype=dtype)
        init_data = np.asarray(init_data, dtype=dtype)
        if init_data.ndim != 3:
            raise ValueError("Fields are 3-D (X, Y, Z)")
        self.name = name
        self.shape = init_data.shape
        self.dtype = init_data.dtype
        self.init_data = init_data
        p = prog_mod.current_program()
        if p is not None:
            p.register_field(self)

    # -- the WFA indexing protocol ---------------------------------------
    def __getitem__(self, idx) -> Term:
        zs, dx, dy = self._parse(idx)
        return Term(self.name, zs, dx, dy)

    def __setitem__(self, idx, expr) -> None:
        zs, dx, dy = self._parse(idx)
        if dx != 0 or dy != 0:
            raise ValueError("updates must target the local tile (dx=dy=0)")
        if not isinstance(expr, StencilExpr):
            raise TypeError("rhs of a Field update must be a stencil expression")
        p = prog_mod.current_program()
        if p is None:
            raise RuntimeError(
                "Field updates must run inside a WFAInterface program context"
            )
        p.record_update(self, slice(*zs), expr)

    def _parse(self, idx):
        if not (isinstance(idx, tuple) and len(idx) == 3):
            raise TypeError("Field indices are [zslice, dx, dy]")
        return (_norm_zslice(idx[0]), _norm_offset(idx[1], "X"),
                _norm_offset(idx[2], "Y"))

    def __repr__(self):
        return f"Field({self.name!r}, shape={self.shape}, dtype={self.dtype})"

"""Gradient compression: int8 quantization with error feedback.

At 1000+ node scale the DP gradient all-reduce dominates step time for small
models; int8 + error feedback cuts the volume 4× (vs fp32) at negligible
quality cost.  ``compress_error_feedback`` is the drop-in transform used by
the train step (the residual state rides along with the optimizer state);
``psum_compressed`` is the shard_map building block that all-reduces the
quantized payload across a named axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_error_feedback(grads, residual):
    """Quantize grads (+carry residual), return (decompressed, new_residual).

    residual is a pytree like grads (fp32); pass zeros on first use.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def psum_compressed(g, axis_name: str):
    """int8 all-reduce across ``axis_name`` (use inside shard_map).

    Quantize → psum int32 (int8 payload on the wire, accumulation widened) →
    dequantize with the max scale.
    """
    q, s = quantize_int8(g)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_max = jax.lax.pmax(s, axis_name)
    return (total.astype(jnp.float32) * s_max).astype(g.dtype)

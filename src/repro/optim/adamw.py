"""AdamW with fp32 moments over arbitrary-dtype (e.g. bf16) params.

State is a pytree congruent with params: {m, v} fp32 + scalar step.  Moments
inherit the parameter sharding (same logical axes), so optimizer memory
scales down with TP/EP exactly like the params do.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = (p.astype(jnp.float32)
                 - lr * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)

"""repro.optim — AdamW (sharded fp32 state), schedules, grad compression."""
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import (compress_error_feedback, dequantize_int8,
                                     quantize_int8)

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "quantize_int8", "dequantize_int8",
           "compress_error_feedback"]

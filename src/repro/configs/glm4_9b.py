"""glm4-9b [dense] — 40L d=4096 32H (GQA kv=2) d_ff=13696 V=151552.

RoPE (partial, 0.5 fraction per GLM convention), GQA.  [hf:THUDM/glm-4-9b]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("glm4-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=151552,
        segments=(("attn", 40),),
        rope_theta=1e4, rope_fraction=0.5,
        gated_mlp=True, mlp_act="silu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=8,
    )

"""qwen3-0.6b [dense] — 28L d=1024 16H (GQA kv=8) d_ff=3072 V=151936.

qk-norm, GQA, head_dim=128 (decoupled from d_model), tied embeddings.
[hf:Qwen/Qwen3-0.6B]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936,
        segments=(("attn", 28),),
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", num_microbatches=8,
    )

"""deepseek-v2-236b [moe] — 60L d=5120 128H d_ff(expert)=1536 V=102400.

MLA kv_lora=512 (q_lora=1536, qk_nope=128, qk_rope=64, v_head=128);
MoE: 2 shared + 160 routed experts, top-6, first layer dense (d_ff=12288).
Expert parallelism: 160 experts over model=16 → 10 experts/chip.
[arXiv:2405.04434]
"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoECfg


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12288, vocab_size=102400,
        segments=(("mla", 1), ("mla_moe", 59)),
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        moe=MoECfg(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                   capacity_factor=1.25, norm_topk=True),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=8,
    )

"""chameleon-34b [vlm] — 48L d=8192 64H (GQA kv=8) d_ff=22016 V=65536.

Early-fusion: VQ image tokens share the text vocabulary, so the modality
frontend is the tokenizer stub — inputs are plain token ids.  qk-norm per
the Chameleon recipe.  [arXiv:2405.09818]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("chameleon-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        segments=(("attn", 48),),
        qk_norm=True, rope_theta=1e4,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=8,
    )

"""zamba2-2.7b [hybrid] — 54L d=2560 (Mamba2) + shared attn, V=32000.

Mamba2 backbone (d_inner=5120, 80 heads × headdim 64, state 64) with a
single globally-shared attention+MLP block applied every 6th layer on
concat(x, x_embed) (width 5120, 32 heads), per the Zamba2 recipe.
ssm_state=64.  [arXiv:2411.15242]
"""
from repro.configs import register
from repro.configs.base import ModelConfig, SSMCfg


@register("zamba2-2.7b")
def config() -> ModelConfig:
    # 54 layers = 9 × (5 mamba + 1 mamba_shared)
    segments = (("mamba", 5), ("mamba_shared", 1)) * 9
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab_size=32000,
        segments=segments,
        ssm=SSMCfg(d_inner=5120, n_heads=80, headdim=64, d_state=64,
                   d_conv=4, chunk=64),
        zamba_period=6, shared_n_heads=32, shared_d_ff=10240,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=4,
    )

"""heat3d — the paper's own workload (Eq. 1) as a config.

Grid sizes follow the paper's test points: the Fig. 3 example (102³ with
boundary layers) and the industrially-relevant zone (5.8e6–4.67e7 cells).
``W`` (cells per processor) is the brick volume per chip.

The implicit side of the workload (Eq. 3) is parameterized here too:
``method``/``tol``/``maxiter`` feed :func:`record_implicit`, which records
the BTCS system through the WFA frontend ready for ``wse.solve`` — the
one operator-compilation path shared with the explicit programs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HeatConfig:
    name: str = "heat3d"
    nx: int = 512
    ny: int = 512
    nz: int = 128             # 3.3e7 cells ~ the industrial zone
    omega: float = 0.1        # the paper's test diagonal constant
    bc_cold: float = 300.0
    bc_hot: float = 400.0
    init: float = 500.0
    dtype: str = "float32"    # the paper runs single precision

    # implicit-solve (wfa.solve) parameters — paper Eq. 3
    method: str = "cg"        # cg | pipecg | bicgstab | chebyshev | jacobi
    tol: float = 1e-6
    maxiter: int = 500

    @property
    def cells(self) -> int:
        return self.nx * self.ny * self.nz

    def smoke(self) -> "HeatConfig":
        return dataclasses.replace(self, nx=16, ny=16, nz=12)

    def paper_example(self) -> "HeatConfig":
        """The Fig. 3 script's 102×102×102 grid."""
        return dataclasses.replace(self, nx=102, ny=102, nz=102)


def make_field(cfg: HeatConfig):
    import numpy as np
    T = np.full((cfg.nx, cfg.ny, cfg.nz), cfg.init,
                dtype=np.dtype(cfg.dtype))
    T[1:-1, 1:-1, 0] = cfg.bc_cold
    T[1:-1, 1:-1, -1] = cfg.bc_hot
    return T


def record_implicit(cfg: HeatConfig):
    """Record the config's BTCS system; returns ``(wse, field)`` ready for
    ``wse.solve(answer=field, method=cfg.method, tol=cfg.tol, ...)``."""
    from repro.solver import record_btcs
    return record_btcs(make_field(cfg), cfg.omega)

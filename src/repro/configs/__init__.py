"""Architecture registry: ``get_config(arch)`` / ``ARCHS``."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import (LONG_CONTEXT_ARCHS, SHAPES, MoECfg,
                                ModelConfig, ShapeCfg, SSMCfg, cells_for)

_FACTORIES: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _FACTORIES[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    _load_all()
    return _FACTORIES[name]()


def arch_names():
    _load_all()
    return sorted(_FACTORIES)


def _load_all():
    if _FACTORIES.get("_loaded"):
        return
    from repro.configs import (chameleon_34b, deepseek_v2_236b, glm4_9b,  # noqa
                               minicpm3_4b, mixtral_8x7b, musicgen_medium,
                               qwen3_0_6b, rwkv6_7b, starcoder2_3b,
                               zamba2_2_7b)
    _FACTORIES["_loaded"] = lambda: None


ARCHS = ["glm4-9b", "minicpm3-4b", "qwen3-0.6b", "starcoder2-3b",
         "musicgen-medium", "chameleon-34b", "mixtral-8x7b",
         "deepseek-v2-236b", "rwkv6-7b", "zamba2-2.7b"]

__all__ = ["ARCHS", "SHAPES", "LONG_CONTEXT_ARCHS", "MoECfg", "ModelConfig",
           "ShapeCfg", "SSMCfg", "cells_for", "get_config", "arch_names",
           "register"]

"""musicgen-medium [audio] — 48L d=1536 24H (MHA) d_ff=6144 V=2048.

Decoder-only over EnCodec tokens (4 codebooks, delay pattern); the EnCodec
frontend is a stub — inputs are (B, S, 4) codebook ids and input_specs()
provides them precomputed.  Non-gated GELU MLP.  [arXiv:2306.05284]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048,
        segments=(("attn", 48),),
        rope_theta=1e4, gated_mlp=False, mlp_act="gelu",
        n_codebooks=4,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", num_microbatches=2,
    )

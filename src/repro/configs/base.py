"""Config schema for the architecture pool + shape suite.

Every assigned architecture is a :class:`ModelConfig` built by its
``src/repro/configs/<id>.py`` factory; ``smoke()`` derives the reduced
variant used by CPU tests.  ``SHAPES`` defines the four assigned input
shapes; applicability (which shapes an arch runs) is resolved by
:func:`cells_for`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = False
    act: str = "silu"


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_inner: int
    n_heads: int
    headdim: int = 64
    d_state: int = 64
    d_conv: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer stack: ((kind, count), ...) — kinds: attn, attn_moe, mla,
    # mla_moe, rwkv, mamba, mamba_shared
    segments: Tuple[Tuple[str, int], ...]
    # attention options
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    gated_mlp: bool = True
    mlp_act: str = "silu"
    tie_embeddings: bool = False
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False
    # MoE / SSM / RWKV / zamba
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv_lora: int = 32
    rwkv_chunk: int = 64
    zamba_period: int = 6
    shared_n_heads: int = 0
    shared_d_ff: int = 0
    # modality frontend (musicgen: 4 EnCodec codebooks)
    n_codebooks: int = 1
    # execution policy
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"              # none | full | dots
    num_microbatches: int = 1
    # False → python loops instead of lax.scan (roofline calibration mode:
    # XLA cost_analysis counts while-loop bodies once, so calibration
    # variants must be flat; see launch/roofline.py)
    scan_layers: bool = True
    # per-config logical-axis remapping (e.g. mixtral TP-in-expert)
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def smoke(self, **kw) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        ratio = max(1, self.d_model // 64)
        moe = self.moe and dataclasses.replace(
            self.moe, n_experts=min(self.moe.n_experts, 8),
            top_k=min(self.top_k_safe(), 2), d_expert=64)
        ssm = self.ssm and dataclasses.replace(
            self.ssm, d_inner=128, n_heads=2, headdim=64, d_state=16,
            chunk=16)
        seg = tuple((kind, min(c, 2)) for kind, c in self.segments)
        repl = dict(
            n_layers=sum(c for _, c in seg), segments=seg, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16, d_ff=128, vocab_size=256, moe=moe, ssm=ssm,
            q_lora_rank=min(self.q_lora_rank, 32) if self.q_lora_rank else 0,
            kv_lora_rank=(min(self.kv_lora_rank, 16)
                          if self.kv_lora_rank else 0),
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            rwkv_lora=8, rwkv_chunk=8, zamba_period=2,
            shared_n_heads=4 if self.shared_n_heads else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            sliding_window=(8 if self.sliding_window else None),
            param_dtype="float32", compute_dtype="float32",
            remat="none", num_microbatches=1,
        )
        repl.update(kw)
        return dataclasses.replace(self, **repl)

    def top_k_safe(self) -> int:
        return self.moe.top_k if self.moe else 0


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (DESIGN.md §4)
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "zamba2-2.7b", "mixtral-8x7b")


def cells_for(arch: str):
    """Shapes applicable to ``arch`` (the dry-run cell list)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out

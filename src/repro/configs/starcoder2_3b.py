"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) d_ff=12288 V=49152.

GQA, RoPE, non-gated GELU MLP (StarCoder2 uses a standard MLP).
[arXiv:2402.19173]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("starcoder2-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
        d_ff=12288, vocab_size=49152,
        segments=(("attn", 30),),
        rope_theta=1e5, gated_mlp=False, mlp_act="gelu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", num_microbatches=4,
    )

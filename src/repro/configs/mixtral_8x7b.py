"""mixtral-8x7b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=14336 V=32000.

8 experts top-2, sliding-window attention (4096).  Experts (8) don't divide
the model axis (16), so this config remaps expert parallelism to
TP-within-expert: experts replicated, each expert's d_ff sharded.
[arXiv:2401.04088]
"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoECfg


@register("mixtral-8x7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=32000,
        segments=(("attn_moe", 32),),
        sliding_window=4096, rope_theta=1e6,
        moe=MoECfg(n_experts=8, top_k=2, d_expert=14336, n_shared=0,
                   capacity_factor=1.25, norm_topk=True),
        sharding_overrides=(("experts", None), ("expert_mlp", "model")),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=4,
    )

"""minicpm3-4b [dense] — 62L d=2560 40H d_ff=6400 V=73448 — MLA.

MLA ranks per HF config: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32,
v_head=64.  [hf:openbmb/MiniCPM3-4B]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=6400, vocab_size=73448,
        segments=(("mla", 62),),
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
        v_head_dim=64,
        rope_theta=1e4,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", num_microbatches=4,
    )

"""rwkv6-7b [ssm] — 32L d=4096 (attention-free) d_ff=14336 V=65536.

RWKV6 "Finch": data-dependent decay, DDLerp token shift, head size 64
(64 heads).  [arXiv:2404.05892]
"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("rwkv6-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab_size=65536,
        segments=(("rwkv", 32),),
        rwkv_lora=64, rwkv_chunk=64,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", num_microbatches=4,
    )

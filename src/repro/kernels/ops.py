"""Jitted public wrappers around the Pallas kernels.

On a TPU backend the kernels compile via Mosaic; on CPU (this container, and
any unit-test environment) they execute under ``interpret=True`` so the same
call sites work everywhere.  Set ``REPRO_FORCE_INTERPRET=0`` to override.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import dotprod as _dotprod
from repro.kernels import spmv as _spmv
from repro.kernels import stencil7 as _stencil7


def _interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def stencil7(P, c_diag: float, c_off: float, block=(8, 128)):
    """(bx+2, by+2, Z) halo-padded brick → fused affine 7-point stencil."""
    return _stencil7.affine_stencil(P, float(c_diag), float(c_off),
                                    block=block, interpret=_interpret())


def stencil7_planes(T, xlo, xhi, ylo, yhi, coords, c_diag, c_off,
                    nx: int, ny: int, block=(8, 128)):
    """Fully-fused FTCS step (unpadded brick + halo planes + in-kernel moat).

    The optimized explicit path: no pad-concat, no masking pass — see
    EXPERIMENTS.md §Perf (heat explicit iterations).
    """
    return _stencil7.stencil_planes(T, xlo, xhi, ylo, yhi, coords,
                                    float(c_diag), float(c_off), nx, ny,
                                    block=block, interpret=_interpret())


def spmv_hex(P, c_diag: float, c_off: float, block=(8, 128)):
    """SpMV only (discards the fused dot) — used by the CG operator."""
    av, _ = _spmv.spmv_dot(P, float(c_diag), float(c_off), block=block,
                           interpret=_interpret())
    return av


def spmv_hex_dot(P, c_diag: float, c_off: float, block=(8, 128)):
    """Fused SpMV + brick-local p·Ap.  Returns (Ap, scalar)."""
    av, partials = _spmv.spmv_dot(P, float(c_diag), float(c_off), block=block,
                                  interpret=_interpret())
    return av, jnp.sum(partials, dtype=jnp.float32)


def dual_dot(a, b, c, d, block=(256, 128)):
    """Brick-local fused dual dot: returns jnp.stack([a·b, c·d])."""
    def to2d(x):
        n = x.size
        cols = 128 if n % 128 == 0 else 1
        return x.reshape(n // cols, cols)

    out = _dotprod.dual_dot_2d(to2d(a), to2d(b), to2d(c), to2d(d),
                               block=block, interpret=_interpret())
    return jnp.sum(out, axis=0, dtype=jnp.float32)

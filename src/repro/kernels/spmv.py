"""Fused BTCS SpMV + partial dot — the CG inner-loop hot path.

Classic CG needs ``Ap`` and then the scalar ``p·Ap``.  Doing them separately
costs an extra full HBM sweep of two vectors — on the WSE the FMAC runs while
data streams; the TPU analogue is to fuse: each grid block computes its
``Ap`` tile *and* accumulates the tile's ``p·Ap`` partial in VMEM, writing a
per-block scalar.  The host-side wrapper sums the (gx·gy,) partials (a few
hundred floats) and the mesh-level ``psum`` finishes the reduction — exactly
the paper's reduce-to-center tree with the tile-local sum fused into the
compute pass (Fig. 2c).

Layout matches :mod:`repro.kernels.stencil7`: overlapping halo windows via
``pl.Element``; partials land in a (gx, gy) fp32 output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import element_block_spec
from repro.kernels.stencil7 import _pick_block


def _spmv_dot_body(c_diag: float, c_off: float, p_ref, o_ref, dot_ref):
    x = p_ref[...]
    c = x[1:-1, 1:-1, :]
    s = (x[:-2, 1:-1, :] + x[2:, 1:-1, :]
         + x[1:-1, :-2, :] + x[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    av = c_diag * c + c_off * (s + zp + zm)
    o_ref[...] = av
    dot_ref[0, 0] = jnp.sum(c * av, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("c_diag", "c_off", "block",
                                             "interpret"))
def spmv_dot(P, c_diag: float, c_off: float, block=(8, 128),
             interpret: bool = False):
    """P: (bx+2, by+2, Z) halo-padded p-brick → (Ap, p·Ap partials).

    Returns ``(Ap (bx,by,Z), partials (gx,gy) fp32)``; ``partials.sum()`` is
    the brick-local p·Ap.
    """
    bx, by, nz = P.shape[0] - 2, P.shape[1] - 2, P.shape[2]
    bxb = _pick_block(bx, block[0])
    byb = _pick_block(by, block[1])
    grid = (bx // bxb, by // byb)
    return pl.pallas_call(
        functools.partial(_spmv_dot_body, c_diag, c_off),
        grid=grid,
        in_specs=[element_block_spec(
            (bxb + 2, byb + 2, nz),
            lambda i, j: (i * bxb, j * byb, 0))],
        out_specs=[
            pl.BlockSpec((bxb, byb, nz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, nz), P.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(P)

"""Fused dual dot-product — one pass for CG's two reductions.

Pipelined CG needs (r·r, w·r) at the same point; computing them separately
sweeps r twice through HBM.  This kernel streams the operand tiles once and
emits both partials per block — the memory-side half of the optimization
whose network-side half is the single fused ``psum`` (see
``core.implicit.make_sharded_implicit``).  Eq. 17 prices each WSE reduction
at (W + X + Y + 66) cycles; fusing halves both the W sweep and the (X+Y)
tree traffic.

Operands arrive as (rows, cols) 2-D tiles (the wrapper in ops.py reshapes
bricks); blocks are (rb, 128)-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stencil7 import _pick_block


def _dual_dot_body(a_ref, b_ref, c_ref, d_ref, out_ref):
    a, b, c, d = a_ref[...], b_ref[...], c_ref[...], d_ref[...]
    out_ref[0, 0] = jnp.sum(a * b, dtype=jnp.float32)
    out_ref[0, 1] = jnp.sum(c * d, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dual_dot_2d(a, b, c, d, block=(256, 128), interpret: bool = False):
    """a,b,c,d: (rows, cols) → (nblocks, 2) partials; sum(axis=0) = dots."""
    rows, cols = a.shape
    rb = _pick_block(rows, block[0])
    cb = _pick_block(cols, block[1])
    grid = (rows // rb, cols // cb)
    spec = pl.BlockSpec((rb, cb), lambda i, j: (i, j))
    out = pl.pallas_call(
        _dual_dot_body,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((1, 2), lambda i, j: (i * grid[1] + j, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0] * grid[1], 2), jnp.float32),
        interpret=interpret,
    )(a, b, c, d)
    return out

"""Pallas API version shims.

Overlapping stencil windows need *element*-offset indexing: the index map
returns cell offsets, not block indices, so neighbouring grid blocks may read
overlapping (halo) rows.  jax ≥ 0.5 spells this ``pl.Element(n, padding=…)``
per dimension; jax 0.4.x (this container) spells it
``indexing_mode=pl.Unblocked(padding)`` on the whole BlockSpec.  The kernels
go through this helper so both spellings work.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

from jax.experimental import pallas as pl


def element_block_spec(block_shape: Sequence[int], index_map: Callable,
                       padding: Optional[Sequence[Tuple[int, int]]] = None):
    """BlockSpec with element-offset indexing + optional (lo, hi) zero pads."""
    if hasattr(pl, "Element"):
        if padding is None:
            padding = [(0, 0)] * len(block_shape)
        dims = tuple(
            pl.Element(n, padding=tuple(p)) if tuple(p) != (0, 0)
            else pl.Element(n)
            for n, p in zip(block_shape, padding))
        return pl.BlockSpec(dims, index_map)
    mode = (pl.unblocked if padding is None
            else pl.Unblocked(tuple(tuple(p) for p in padding)))
    return pl.BlockSpec(tuple(block_shape), index_map, indexing_mode=mode)

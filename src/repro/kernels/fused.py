"""Generic fused stencil kernel — arbitrary tap sets, outputs, time tiles.

This generalizes the hand-fused 7-point :mod:`repro.kernels.stencil7` to any
canonical tap form produced by :mod:`repro.compiler.ir`: arbitrary (dz, dx,
dy) offsets within a halo of depth ``h`` (off-axis/diagonal taps included),
variable-coefficient products of up to two taps, several ``UpdateOp``s — and
several *output fields* — fused into a single ``pl.pallas_call`` per loop
body.  Sequential updates inside one body see earlier updates' *local*
values (dx = dy = 0 reads only — the lowering pass rejects the rest),
mirroring the Control Tile's ordered RPC stream.

Time tiling (``time_tile=k``): each grid cell loads one overlapping
``(bxb + 2kh, byb + 2kh, Z)`` window per input field (``pl.Element``
indexing) and applies the loop body ``k`` times in VMEM, the valid region
shrinking by ``h`` per sub-step (trapezoid blocking), so the caller pays the
halo exchange / wrap pad once per *tile* instead of once per step.  The
Dirichlet Moat mask is applied per sub-step from global coordinates — with
``wrap=True`` (single device, ``jnp.pad(mode="wrap")`` margins) coordinates
are taken modulo the grid so halo cells evolve exactly like the domain cells
they mirror, keeping the tiled run bit-identical to k untiled steps.

The caller supplies halo-padded inputs: ``jnp.pad(..., mode="wrap")`` on a
single device (matching the interpreter's ``jnp.roll`` semantics exactly) or
``core.halo.halo_pad`` (ICI ppermute) inside ``shard_map`` — depth ``k·h``
either way.  ``coords`` is a (1, 2) int32 array with the brick's global cell
origin so one kernel image serves every brick — how one Worker image serves
the whole WSE fabric.

Reverse-mode AD never differentiates through this kernel: differentiable
plans (``RunOptions(differentiable=True)``) keep donation and the in-place
resident layout off, and ``engine.differentiable_runner`` wraps each launch
in a ``custom_vjp`` whose backward replays the roll-interpreter reference —
exact for the affine bodies the lowering pass admits, and indifferent to
input aliasing because the primal kernel is only ever called on
non-donated, margin-free arrays under AD.
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import element_block_spec
from repro.kernels.stencil7 import _pick_block


def _read_tap(tap, u, cur, center, h, out_x, out_y):
    """Value of one tap over the update's target block, (out_x, out_y, zlen)."""
    zlo = u.z0 + tap.dz
    if tap.field in center:
        # field already updated this sub-step: lowering guarantees
        # dx == dy == 0, so the read is block-local (already out-sized).
        return center[tap.field][:, :, zlo:zlo + u.zlen]
    a = cur[tap.field]
    x0 = h + tap.dx
    y0 = h + tap.dy
    return a[x0:x0 + out_x, y0:y0 + out_y, zlo:zlo + u.zlen]


def _apply_updates(updates, cur, nz_of, h, out_x, out_y, gx0, gy0, nx, ny,
                   wrap):
    """One sub-step: apply every update over the (out_x, out_y) region.

    ``cur`` holds full-Z arrays of extent (out_x + 2h, out_y + 2h); returns
    the post-step dict shrunk to (out_x, out_y).  ``gx0, gy0`` are the global
    coordinates of the *output* region's origin; with ``wrap`` they are taken
    modulo the grid so wrap-pad margin cells mask like the cells they mirror.
    """
    row = jax.lax.broadcasted_iota(jnp.int32, (out_x, out_y, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (out_x, out_y, 1), 1)
    gx = gx0 + row
    gy = gy0 + col
    if wrap:
        gx = gx % nx
        gy = gy % ny
    interior = (gx > 0) & (gx < nx - 1) & (gy > 0) & (gy < ny - 1)

    center: Dict[str, jnp.ndarray] = {}   # full-Z out-sized blocks, updated
    for u in updates:
        nz = nz_of[u.field]
        if u.field in center:
            old = center[u.field]
        else:
            old = cur[u.field][h:h + out_x, h:h + out_y, :]
        dtype = old.dtype
        # group products sharing a scalar coefficient: sum first, multiply
        # once — fewer VPU multiplies and the same association the source
        # spelling `c * (T_E + T_W + ...)` used, so rounding matches the
        # interpreter to ~1 ulp.
        groups: Dict[float, jnp.ndarray] = {}
        for coeff, taps in u.terms:
            t = _read_tap(taps[0], u, cur, center, h, out_x, out_y)
            for tap in taps[1:]:
                t = t * _read_tap(tap, u, cur, center, h, out_x, out_y)
            groups[coeff] = t if coeff not in groups else groups[coeff] + t
        acc = None
        for coeff, t in groups.items():
            if coeff != 1.0:
                t = dtype.type(coeff) * t
            acc = t if acc is None else acc + t
        if acc is None:
            acc = jnp.full((out_x, out_y, u.zlen), u.const, dtype)
        elif u.const != 0.0:
            acc = acc + dtype.type(u.const)

        old_z = old[:, :, u.z0:u.z0 + u.zlen]
        new_z = jnp.where(interior, acc, old_z)
        # splice the updated z window in place: dynamic_update_slice (same
        # values as concatenating the flanking slices) keeps the per-sub-step
        # splice fusible, where a concatenate chain re-materializes the whole
        # block each sub-step — the difference between time tiles costing
        # ~k× one launch and costing ~1× (see docs/time_tiling.md).
        if u.z0 == 0 and u.zlen == nz:
            center[u.field] = new_z
        else:
            center[u.field] = jax.lax.dynamic_update_slice(
                old, new_z, (0, 0, u.z0))

    out = {}
    for name, a in cur.items():
        out[name] = (center[name] if name in center
                     else a[h:h + out_x, h:h + out_y, :])
    return out


def _fused_body(updates, in_names, written, nz_of, h, k, wrap, bxb, byb,
                nx, ny, coords_ref, *refs):
    cur = dict(zip(in_names, (r[...] for r in refs[:len(in_names)])))
    out_refs = dict(zip(written, refs[len(in_names):]))
    i = pl.program_id(0)
    j = pl.program_id(1)
    # global origin of the loaded window (halo depth k·h below the block)
    gx0 = coords_ref[0, 0] + i * bxb - k * h
    gy0 = coords_ref[0, 1] + j * byb - k * h
    for s in range(k):
        out_x = bxb + 2 * (k - s - 1) * h
        out_y = byb + 2 * (k - s - 1) * h
        gx0 = gx0 + h   # origin of this sub-step's output region
        gy0 = gy0 + h
        cur = _apply_updates(updates, cur, nz_of, h, out_x, out_y, gx0, gy0,
                             nx, ny, wrap)
    for name in written:
        out_refs[name][...] = cur[name]


def build_fused_call(updates: Sequence, field_specs: Dict[str, Tuple[int, object]],
                     halo: int, bx: int, by: int, nx: int, ny: int,
                     block=(8, 128), interpret: bool = False,
                     time_tile: int = 1, wrap: bool = False,
                     margin: int = 0, region=None):
    """Build the fused kernel for one loop body.

    ``updates``     — :class:`repro.compiler.ir.AffineUpdate`s, program order.
    ``field_specs`` — ordered ``name -> (nz, dtype)`` for every field the body
                      reads or writes; all share the brick extent (bx, by).
    ``bx, by``      — brick extent (global grid on 1 device, local brick under
                      ``shard_map``); ``nx, ny`` — global extent for the Moat.
    ``time_tile``   — sub-steps fused per launch (k); inputs carry ``k·halo``
                      margins.  ``wrap`` marks wrap-pad margins (single
                      device) so the per-sub-step Moat mask wraps coordinates.
    ``margin``      — halo-resident mode: inputs arrive at the *run-wide*
                      padded extent (bx + 2·margin, by + 2·margin, nz) with
                      ``margin >= k·halo`` (the engine's
                      :class:`~repro.engine.layout.HaloLayout`), the kernel
                      reads its depth-``k·halo`` window from inside that
                      margin, and every written field is emitted **in place**
                      into its own input buffer via ``input_output_aliases``
                      — outputs keep the resident extent and zero new
                      buffers are allocated on the step path.
    ``region``      — a :class:`repro.compiler.ir.RegionSpec` *windowing*
                      the launch (resident mode only): the grid covers the
                      region's (rx, ry) output cells instead of the whole
                      brick, windows and output blocks offset by the region
                      origin.  The overlap scheduler uses this for the
                      interior launch — the region sits ``k·halo`` inside
                      the brick edge, so its input windows never touch the
                      margin frame and the launch needs no refreshed halo
                      data.  The caller must offset ``coords`` by the
                      region origin so the Moat mask stays global.

    Returns ``call(coords, *padded) -> tuple(new_fields)`` where ``padded``
    are the (bx + 2·k·halo, by + 2·k·halo, nz) inputs (resident extent when
    ``margin`` is set) in ``field_specs`` order and the outputs are the
    written fields, in first-written order — full (bx, by, nz) arrays, or
    the updated resident buffers when ``margin`` is set.
    """
    in_names = list(field_specs)
    written = []
    for u in updates:
        if u.field not in written:
            written.append(u.field)
    nz_of = {n: s[0] for n, s in field_specs.items()}
    h = halo
    k = time_tile
    if margin and margin < k * h:
        raise ValueError(f"resident margin {margin} < window halo {k * h}")
    if region is not None and not margin:
        raise ValueError("region windowing requires resident margin mode")
    # region mode: the grid tiles the region's output cells; windows and
    # output blocks shift by the region origin inside the resident buffer
    rx, ry = (bx, by) if region is None else (region.rx, region.ry)
    ox, oy = (0, 0) if region is None else (region.x0, region.y0)
    bxb = _pick_block(rx, block[0])
    byb = _pick_block(ry, block[1])
    grid = (rx // bxb, ry // byb)

    body = functools.partial(_fused_body, tuple(updates), tuple(in_names),
                             tuple(written), nz_of, h, k, wrap, bxb, byb,
                             nx, ny)
    # window origin inside the input: the kernel always consumes a
    # (bxb + 2kh, byb + 2kh) window; with a resident margin that window sits
    # `margin - kh` cells inside the buffer edge (legacy inputs arrive
    # already window-aligned — their whole extent IS the padded window).
    off_x = margin - k * h + ox if margin else 0
    off_y = margin - k * h + oy if margin else 0
    in_specs = [pl.BlockSpec((1, 2), lambda i, j: (0, 0))]
    for name in in_names:
        nz = nz_of[name]
        in_specs.append(element_block_spec(
            (bxb + 2 * k * h, byb + 2 * k * h, nz),
            lambda i, j, ax=off_x, ay=off_y: (ax + i * bxb, ay + j * byb, 0)))
    if margin:
        # in-place outputs: each written field aliases its own input buffer
        # (full resident extent); the grid writes only the region's blocks,
        # margins (and, in region mode, the rest of the brick) keep their
        # pre-launch values.
        out_specs = [element_block_spec(
            (bxb, byb, nz_of[n]),
            lambda i, j: (margin + ox + i * bxb, margin + oy + j * byb, 0))
            for n in written]
        out_shape = [jax.ShapeDtypeStruct(
            (bx + 2 * margin, by + 2 * margin, nz_of[n]), field_specs[n][1])
            for n in written]
        aliases = {1 + in_names.index(n): o for o, n in enumerate(written)}
    else:
        out_specs = [pl.BlockSpec((bxb, byb, nz_of[n]), lambda i, j: (i, j, 0))
                     for n in written]
        out_shape = [jax.ShapeDtypeStruct((bx, by, nz_of[n]), field_specs[n][1])
                     for n in written]
        aliases = {}

    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )

    def fused(coords, *padded):
        out = call(coords, *padded)
        return tuple(out) if isinstance(out, (list, tuple)) else (out,)

    return fused, tuple(written)

"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax.numpy as jnp


def affine_stencil_ref(P, c_diag: float, c_off: float):
    """Oracle for kernels.stencil7.affine_stencil."""
    c = P[1:-1, 1:-1, :]
    s = (P[:-2, 1:-1, :] + P[2:, 1:-1, :]
         + P[1:-1, :-2, :] + P[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    return c_diag * c + c_off * (s + zp + zm)


def spmv_dot_ref(P, c_diag: float, c_off: float):
    """Oracle for kernels.spmv.spmv_dot — returns (Ap, scalar p·Ap)."""
    av = affine_stencil_ref(P, c_diag, c_off)
    c = P[1:-1, 1:-1, :]
    return av, jnp.sum(c * av, dtype=jnp.float32)


def stencil_planes_ref(T, xlo, xhi, ylo, yhi, coords, c_diag, c_off,
                       nx, ny):
    """Oracle for kernels.stencil7.stencil_planes (padded assembly form)."""
    import numpy as np
    P = jnp.concatenate([xlo, T, xhi], axis=0)
    col = jnp.concatenate(
        [jnp.zeros((1, 1, T.shape[2]), T.dtype)] * 1, axis=0)
    ylo_p = jnp.concatenate([jnp.zeros((1, 1, T.shape[2]), T.dtype),
                             ylo, jnp.zeros((1, 1, T.shape[2]), T.dtype)],
                            axis=0)
    yhi_p = jnp.concatenate([jnp.zeros((1, 1, T.shape[2]), T.dtype),
                             yhi, jnp.zeros((1, 1, T.shape[2]), T.dtype)],
                            axis=0)
    P = jnp.concatenate([ylo_p, P, yhi_p], axis=1)
    out = affine_stencil_ref(P, c_diag, c_off)
    bx, by, nz = T.shape
    cx, cy = int(coords[0, 0]), int(coords[0, 1])
    gx = cx * bx + np.arange(bx)[:, None, None]
    gy = cy * by + np.arange(by)[None, :, None]
    zi = np.arange(nz)[None, None, :]
    interior = ((gx > 0) & (gx < nx - 1) & (gy > 0) & (gy < ny - 1)
                & (zi > 0) & (zi < nz - 1))
    return jnp.where(jnp.asarray(interior), out, T)


def dual_dot_ref(a, b, c, d):
    """Oracle for kernels.dotprod.dual_dot_2d — (a·b, c·d) as a (2,) vec."""
    return jnp.stack([jnp.sum(a * b, dtype=jnp.float32),
                      jnp.sum(c * d, dtype=jnp.float32)])

"""Fused 7-point affine stencil — the paper's single-RPC explicit kernel.

Computes, over a halo-padded brick ``P`` of shape (bx+2, by+2, Z):

    out[i, j, :] = c_diag · P[i+1, j+1, :] + c_off · Σ_{6 neighbours} P[·]

With ``(c_diag, c_off) = (1−6ω, ω)`` this is one FTCS step (Eq. 2); with
``(1, −ωψ)`` it is the BTCS SpMV (Eq. 3).  The WFA's hand-fused RPC performs
the neighbour sum with four background-thread fabric moves plus one FMAC; the
TPU analogue fuses the whole update into one VMEM pass: each grid cell loads
an overlapping ``(bxb+2, byb+2, Z)`` window (``pl.Element`` indexing — the
halo rows are re-read from HBM, never re-computed), does 5 VPU adds + 1 FMA
and writes the (bxb, byby, Z) tile.

TPU adaptation (vs the WSE): the Z column stays entirely local (the paper's
1×1×Z decomposition), so the two Z-neighbour terms are in-register shifts;
the X/Y terms come from the window slices; the brick's cross-chip halo was
produced by ``core.halo.halo_pad`` (ICI ppermute), mirroring fabric hops.

Block sizes default to (8, 128) sublane/lane alignment; the Z extent rides in
the lane dimension of each (x, y) plane, so VMEM per buffer is
(bxb+2)·(byb+2)·Z·4 B ≈ 5.3 MB at Z=1024 — comfortably double-bufferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import element_block_spec


def _affine_stencil_body(c_diag: float, c_off: float, p_ref, o_ref):
    x = p_ref[...]                       # (bxb+2, byb+2, Z) window in VMEM
    c = x[1:-1, 1:-1, :]
    s = (x[:-2, 1:-1, :] + x[2:, 1:-1, :]
         + x[1:-1, :-2, :] + x[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    o_ref[...] = c_diag * c + c_off * (s + zp + zm)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (TPU-aligned when possible)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("c_diag", "c_off", "block",
                                             "interpret"))
def affine_stencil(P, c_diag: float, c_off: float, block=(8, 128),
                   interpret: bool = False):
    """P: (bx+2, by+2, Z) halo-padded brick → (bx, by, Z)."""
    bx, by, nz = P.shape[0] - 2, P.shape[1] - 2, P.shape[2]
    bxb = _pick_block(bx, block[0])
    byb = _pick_block(by, block[1])
    grid = (bx // bxb, by // byb)
    return pl.pallas_call(
        functools.partial(_affine_stencil_body, c_diag, c_off),
        grid=grid,
        in_specs=[element_block_spec(
            (bxb + 2, byb + 2, nz),
            lambda i, j: (i * bxb, j * byb, 0))],
        out_specs=pl.BlockSpec((bxb, byb, nz), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bx, by, nz), P.dtype),
        interpret=interpret,
    )(P)


def _stencil_planes_body(c_diag, c_off, bxb, byb, bx, by, nx, ny,
                         coords_ref, t_ref, xlo_ref, xhi_ref, ylo_ref,
                         yhi_ref, o_ref):
    """FTCS step from an UNPADDED brick + 4 received halo planes.

    ``t_ref`` windows are zero-padded at brick edges (pl.Element padding);
    the missing neighbour contribution on a brick-edge row/col is added
    back from the plane refs, predicated on the block's grid position.
    The Dirichlet moat (domain boundary in x, y and z) is applied in-VMEM
    from global coordinates, so no extra masking pass ever touches HBM.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = t_ref[...]               # (bxb+2, byb+2, Z); OOB rows are UNDEFINED
    nz = x.shape[2]
    c = x[1:-1, 1:-1, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (bxb, byb, nz), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bxb, byb, nz), 1)
    gx_blocks = bx // bxb
    gy_blocks = by // byb

    # neighbour terms; on brick-edge rows/cols the window is out of bounds
    # (undefined values) — REPLACE those terms with the received halo plane
    sx_lo = jnp.where((i == 0) & (row == 0), xlo_ref[0, :, :][None],
                      x[:-2, 1:-1, :])
    sx_hi = jnp.where((i == gx_blocks - 1) & (row == bxb - 1),
                      xhi_ref[0, :, :][None], x[2:, 1:-1, :])
    sy_lo = jnp.where((j == 0) & (col == 0),
                      ylo_ref[:, 0, :][:, None, :], x[1:-1, :-2, :])
    sy_hi = jnp.where((j == gy_blocks - 1) & (col == byb - 1),
                      yhi_ref[:, 0, :][:, None, :], x[1:-1, 2:, :])
    zp = jnp.concatenate([c[:, :, 1:], c[:, :, -1:]], axis=2)
    zm = jnp.concatenate([c[:, :, :1], c[:, :, :-1]], axis=2)
    s = sx_lo + sx_hi + sy_lo + sy_hi + zp + zm

    out = c_diag * c + c_off * s

    # Dirichlet moat from global coordinates (x, y domain faces + z faces)
    cx = coords_ref[0, 0]
    cy = coords_ref[0, 1]
    gxi = cx * bx + i * bxb + row
    gyj = cy * by + j * byb + col
    zi = jax.lax.broadcasted_iota(jnp.int32, (bxb, byb, nz), 2)
    interior = ((gxi > 0) & (gxi < nx - 1) & (gyj > 0) & (gyj < ny - 1)
                & (zi > 0) & (zi < nz - 1))
    o_ref[...] = jnp.where(interior, out, c)


@functools.partial(jax.jit, static_argnames=("c_diag", "c_off", "nx", "ny",
                                             "block", "interpret"))
def stencil_planes(T, xlo, xhi, ylo, yhi, coords, c_diag: float,
                   c_off: float, nx: int, ny: int, block=(8, 128),
                   interpret: bool = False):
    """Fully-fused FTCS step: unpadded (bx, by, Z) brick + halo planes.

    Removes every HBM round-trip of the unfused path (pad-concat ×2,
    boundary where, z-boundary concat): traffic = read T + read planes +
    write out.  ``coords`` is a (1, 2) int32 array with this brick's mesh
    coordinates; ``nx, ny`` the global grid extent.
    """
    bx, by, nz = T.shape
    bxb = _pick_block(bx, block[0])
    byb = _pick_block(by, block[1])
    grid = (bx // bxb, by // byb)
    body = functools.partial(_stencil_planes_body, c_diag, c_off, bxb, byb,
                             bx, by, nx, ny)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            # NB: element padding shifts the window start by -pad_lo, so the
            # index map uses the unshifted element offset (verified).
            element_block_spec((bxb + 2, byb + 2, nz),
                               lambda i, j: (i * bxb, j * byb, 0),
                               padding=((1, 1), (1, 1), (0, 0))),
            pl.BlockSpec((1, byb, nz), lambda i, j: (0, j, 0)),
            pl.BlockSpec((1, byb, nz), lambda i, j: (0, j, 0)),
            pl.BlockSpec((bxb, 1, nz), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bxb, 1, nz), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bxb, byb, nz), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bx, by, nz), T.dtype),
        interpret=interpret,
    )(coords, T, xlo, xhi, ylo, yhi)

"""Inter-grid transfer kernels: full-weighting restriction + trilinear
prolongation.

The multigrid subsystem (:mod:`repro.solver.multigrid`) moves residuals down
and corrections up a hierarchy of grids; each move is one Pallas kernel built
here and cached by :func:`repro.compiler.codegen.compile_transfer` — the
inter-grid analogue of the fused per-level stencil kernels.

Alignment is *even vertex-centred*: coarse cell ``I`` sits on fine cell
``2I``, so a fine extent ``n`` coarsens to ``n//2 + 1`` (Moat planes
included) for every parity — even extents stay mesh-divisible for the
sharded path.  Both transfers are separable, so each axis is handled with
three strided slices (restriction) or an interleave (prolongation):

* restriction — ``coarse[I] = 1/4·fine[2I−1] + 1/2·fine[2I] + 1/4·fine[2I+1]``
  per axis over the coarse interior; coarse Moat planes are written as zero
  (the coarse problem is an error equation with homogeneous Dirichlet rows);
* prolongation — ``fine[2I] = coarse[I]``, ``fine[2I+1] = (coarse[I] +
  coarse[I+1])/2`` per axis; the fine Moat planes are written as zero so the
  correction never touches boundary rows.

Both kernels run as one grid cell over the whole level (coarse levels are
small; the finest transfer is bandwidth-bound either way).  The interleave
uses reshapes off the minor axis, which Mosaic restricts on real TPUs —
this container (and CI) executes in interpret mode; blocking the transfers
for Mosaic is future work tracked in docs/solvers.md.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sl(a, axis: int, start: int, stop: int, step: int = 1):
    """Static (possibly strided) slice of ``a`` along one axis."""
    idx = [slice(None)] * a.ndim
    idx[axis] = slice(start, stop, step)
    return a[tuple(idx)]


def _restrict_axis(a, axis: int, m: int):
    """Full weighting along ``axis``: fine extent n → coarse interior m.

    ``m = n//2 − 1`` coarse interior cells; coarse cell i (1-based) averages
    fine cells 2i−1, 2i, 2i+1 with weights 1/4, 1/2, 1/4.
    """
    lo = _sl(a, axis, 1, 2 * m, 2)
    mid = _sl(a, axis, 2, 2 * m + 1, 2)
    hi = _sl(a, axis, 3, 2 * m + 2, 2)
    return 0.5 * mid + 0.25 * (lo + hi)


def _prolong_axis(c, axis: int, n: int):
    """Trilinear interpolation along ``axis``: coarse extent n//2+1 → fine n.

    Even fine cells copy the coincident coarse cell, odd fine cells average
    the two spanning coarse cells; the fine Moat planes are zero (coarse
    Moat values are zero by construction, and the high plane is dropped).
    """
    m = n // 2 - 1
    odd = 0.5 * (_sl(c, axis, 0, m + 1) + _sl(c, axis, 1, m + 2))
    even = _sl(c, axis, 1, m + 1)
    pairs = jnp.stack([_sl(odd, axis, 0, m), even], axis=axis + 1)
    shape = list(pairs.shape)
    shape[axis : axis + 2] = [2 * m]
    seq = jnp.concatenate([pairs.reshape(shape), _sl(odd, axis, m, m + 1)], axis=axis)
    interior = _sl(seq, axis, 0, n - 2)
    pad = [(0, 0)] * c.ndim
    pad[axis] = (1, 1)
    return jnp.pad(interior, pad)


def _restrict_body(ms: Tuple[int, int, int], fine_ref, coarse_ref):
    a = fine_ref[...]
    for axis, m in enumerate(ms):
        a = _restrict_axis(a, axis, m)
    coarse_ref[...] = jnp.pad(a, ((1, 1), (1, 1), (1, 1)))


def _prolong_body(ns: Tuple[int, int, int], coarse_ref, fine_ref):
    a = coarse_ref[...]
    for axis, n in enumerate(ns):
        a = _prolong_axis(a, axis, n)
    fine_ref[...] = a


def _whole_array_call(body, in_shape, out_shape, dtype, interpret):
    return pl.pallas_call(
        body,
        grid=(1,),
        in_specs=[pl.BlockSpec(tuple(in_shape), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec(tuple(out_shape), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(tuple(out_shape), dtype),
        interpret=interpret,
    )


def restrict_ref(fine):
    """Pure-jnp full weighting — the ``jit``-backend path and test oracle."""
    a = fine
    for axis in range(3):
        a = _restrict_axis(a, axis, fine.shape[axis] // 2 - 1)
    return jnp.pad(a, ((1, 1), (1, 1), (1, 1)))


def prolong_ref(coarse, fine_shape):
    """Pure-jnp trilinear interpolation — the ``jit``-backend path."""
    a = coarse
    for axis, n in enumerate(fine_shape):
        a = _prolong_axis(a, axis, int(n))
    return a


def build_restrict_call(fine_shape, coarse_shape, dtype, interpret: bool = False):
    """``call(fine) -> coarse`` — 27-point full weighting, zero coarse Moat."""
    ms = tuple(int(n) // 2 - 1 for n in fine_shape)
    body = functools.partial(_restrict_body, ms)
    return _whole_array_call(body, fine_shape, coarse_shape, dtype, interpret)


def build_prolong_call(coarse_shape, fine_shape, dtype, interpret: bool = False):
    """``call(coarse) -> fine`` — trilinear interpolation, zero fine Moat."""
    ns = tuple(int(n) for n in fine_shape)
    body = functools.partial(_prolong_body, ns)
    return _whole_array_call(body, coarse_shape, fine_shape, dtype, interpret)

"""Executor + planner instrumentation hooks (fault injection, tracing).

Two optional callbacks the engine consults at its natural failure
boundaries, so a fault injector (``repro.runtime.fault.FaultInjector``) can
drive the *real* degradation paths instead of simulating them from outside:

* the **step hook** fires before the engine advances state — once per
  :func:`repro.engine.execute` call, and once per chunk on the service's
  chunked stepping path — with a monotonically increasing logical step
  counter.  Raising makes the run fail exactly where a dead device would
  (after the previous chunk's checkpoint, before the next one); sleeping
  models a straggler;
* the **compile hook** fires inside the compile attempt of
  :func:`repro.engine.plan.compile_body`'s pallas branch.  Raising
  :class:`repro.compiler.LoweringError` routes the body through
  ``try_compile``'s existing catch — counted, logged, interpreter fallback —
  which is precisely the degraded mode a real Mosaic compile failure takes.

Hooks are process-global (matching the engine's global stats); install and
remove them through :class:`repro.runtime.fault.FaultInjector`'s context
manager rather than setting them ad hoc.
"""

from __future__ import annotations

from typing import Callable, Optional

_step_hook: Optional[Callable[[int, str], None]] = None
_compile_hook: Optional[Callable[[Optional[str]], None]] = None


def set_step_hook(fn: Optional[Callable[[int, str], None]]):
    """Install ``fn(step, tag)`` as the pre-step hook; returns the previous
    hook so installers can restore it."""
    global _step_hook
    prev, _step_hook = _step_hook, fn
    return prev


def set_compile_hook(fn: Optional[Callable[[Optional[str]], None]]):
    """Install ``fn(loop_name)`` inside the pallas compile attempt; returns
    the previous hook."""
    global _compile_hook
    prev, _compile_hook = _compile_hook, fn
    return prev


def fire_step_hook(step: int, tag: str = "") -> None:
    """Called by the executor (and the service's chunk loop) before
    advancing state; exceptions propagate to the caller's retry logic."""
    if _step_hook is not None:
        _step_hook(step, tag)


def fire_compile_hook(loop_name: Optional[str]) -> None:
    """Called inside the pallas compile attempt; a raised ``LoweringError``
    becomes a counted, logged interpreter fallback."""
    if _compile_hook is not None:
        _compile_hook(loop_name)

"""Execution counters for the unified engine.

One global :data:`stats` instance (mirroring ``repro.compiler.stats``) that
:func:`repro.engine.plan` and :func:`repro.engine.execute` update in place;
tests and benchmarks ``reset_stats()`` around a run and assert on the
communication accounting — the headline being :attr:`EngineStats.
exchanges_per_step`, which temporal blocking must drop k×.

Exchange counting is *static*: execution is traced (``lax.fori_loop`` /
``shard_map``), so the executor derives the counts from the plan — one pad /
halo-exchange event per fused-kernel launch (zero for halo-free bodies, the
wrap pad on a single device counts as the exchange analogue), and one event
per op application on the roll-interpreter paths (which pad per op, per
step).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass
class EngineStats:
    """Counters for engine planning + execution (reset with ``reset_stats``).

    One live instance is exported as ``repro.engine.stats``; read the
    counters after a run (and ``reset_stats()`` between runs you want to
    compare):

    >>> from repro.engine import reset_stats, stats
    >>> reset_stats()
    >>> (stats.steps_run, stats.exchanges_per_step, stats.mg_levels_built)
    (0, 0.0, 0)
    """

    plans_built: int = 0
    bodies_compiled: int = 0  # compile_body calls (every backend dispatch)
    segments_fused: int = 0  # loop bodies routed to a fused kernel
    segments_interp: int = 0  # loop bodies routed to the roll interpreter
    steps_run: int = 0  # logical time steps executed
    launches: int = 0  # kernel / interpreter-step invocations
    exchanges: int = 0  # halo exchanges, wrap pads or margin refreshes
    tiles_fused: int = 0  # k>1 tiled launches (k steps per launch)
    resident_runs: int = 0  # executions stepping on a halo-resident layout
    #: full-field pad/copy conversions: one per fused launch on the legacy
    #: path; on a resident run only the layout enter/exit events (2 for an
    #: all-fused plan, +2 around each interpreter segment in a mixed plan)
    repacks: int = 0
    max_time_tile: int = 1  # largest k any segment ran with
    elapsed_s: float = 0.0  # wall time inside execute()
    tile_reasons: Tuple[str, ...] = ()  # why a tile factor was clamped/refused

    # -- exchange/compute overlap (interior/boundary split segments) ---------
    interior_launches: int = 0  # interior-region kernel launches
    boundary_launches: int = 0  # boundary shell kernel launches
    #: halo exchanges whose slabs travelled concurrently with an interior
    #: launch (one per split-segment tile; the overlap the split exists for)
    overlapped_exchanges: int = 0
    cost_model_hits: int = 0  # plans served by a calibrated cost-model entry
    calibrations: int = 0  # cost-model calibration runs performed
    mg_hierarchies: int = 0  # multigrid hierarchies scheduled
    mg_levels_built: int = 0  # level segments compiled across hierarchies
    #: (shape, smoother-fused, residual-fused) per level of the last hierarchy
    mg_level_log: Tuple[Tuple[Tuple[int, int, int], bool, bool], ...] = ()

    # -- batched ensembles (plans with options.batch > 1) -------------------
    ensemble_runs: int = 0  # executes of a batched plan (one launch, B members)
    ensemble_members: int = 0  # summed B over those executes
    #: per-member Krylov iteration counts of the last batched solve — the
    #: masked loop runs to the slowest member, but each member's own count
    #: freezes when its residual converges (see repro.solver.krylov)
    member_iterations: Tuple[int, ...] = ()

    # -- numerical health (guarded iterations + explicit sentinels) ----------
    health_probes: int = 0  # explicit-path isfinite sentinel evaluations
    numerical_faults: int = 0  # NumericalFaults raised (solver or sentinel)
    recovery_attempts: int = 0  # escalation-ladder re-solves driven
    #: distinct solver outcome words of the last wfa.solve call
    solve_outcomes: Tuple[str, ...] = ()

    # -- serving tier (updated by repro.service under its stats lock) -------
    requests_admitted: int = 0  # requests accepted into the bounded queue
    requests_rejected: int = 0  # admission-control rejections (queue full)
    requests_expired: int = 0  # dropped at dispatch: deadline already passed
    requests_completed: int = 0  # requests that returned a result
    requests_failed: int = 0  # requests that exhausted their retries
    requests_degraded: int = 0  # served via the interpreter fallback path
    request_retries: int = 0  # restore-and-continue attempts across requests
    plan_builds: int = 0  # service plan-cache misses (compile paid)
    plan_cache_hits: int = 0  # requests served from a warm plan
    service_checkpoints: int = 0  # resident-state snapshots written
    service_restores: int = 0  # checkpoints restored (mid-flight resume)
    service_stragglers: int = 0  # HeartbeatMonitor flags across workers
    queue_wait_s: float = 0.0  # summed submit -> dispatch wait

    @property
    def exchanges_per_step(self) -> float:
        """Halo exchanges (or wrap pads) per logical time step."""
        return self.exchanges / self.steps_run if self.steps_run else 0.0

    @property
    def steps_per_sec(self) -> float:
        """Logical time steps per wall-clock second across executes."""
        return self.steps_run / self.elapsed_s if self.elapsed_s else 0.0

    def note_tile_reason(self, reason: str) -> None:
        self.tile_reasons = self.tile_reasons + (reason,)


stats = EngineStats()


def reset_stats() -> None:
    # mutate in place so `from repro.engine import stats` stays live
    stats.plans_built = 0
    stats.bodies_compiled = 0
    stats.segments_fused = 0
    stats.segments_interp = 0
    stats.steps_run = 0
    stats.launches = 0
    stats.exchanges = 0
    stats.tiles_fused = 0
    stats.resident_runs = 0
    stats.repacks = 0
    stats.max_time_tile = 1
    stats.elapsed_s = 0.0
    stats.tile_reasons = ()
    stats.interior_launches = 0
    stats.boundary_launches = 0
    stats.overlapped_exchanges = 0
    stats.cost_model_hits = 0
    stats.calibrations = 0
    stats.mg_hierarchies = 0
    stats.mg_levels_built = 0
    stats.mg_level_log = ()
    stats.ensemble_runs = 0
    stats.ensemble_members = 0
    stats.member_iterations = ()
    stats.health_probes = 0
    stats.numerical_faults = 0
    stats.recovery_attempts = 0
    stats.solve_outcomes = ()
    stats.requests_admitted = 0
    stats.requests_rejected = 0
    stats.requests_expired = 0
    stats.requests_completed = 0
    stats.requests_failed = 0
    stats.requests_degraded = 0
    stats.request_retries = 0
    stats.plan_builds = 0
    stats.plan_cache_hits = 0
    stats.service_checkpoints = 0
    stats.service_restores = 0
    stats.service_stragglers = 0
    stats.queue_wait_s = 0.0


def service_stats() -> dict:
    """Service-level summary the benchmark and CI smoke gate on.

    Combines the serving-tier counters above with the kernel-pipeline
    counters of :data:`repro.compiler.stats` (the fallback count is the
    "unexpected interpreter fallbacks" gate on a no-fault run).

    >>> from repro.engine import reset_stats
    >>> from repro.engine.stats import service_stats
    >>> reset_stats()
    >>> s = service_stats()
    >>> (s["requests"]["completed"], s["plans"]["cache_hits"], s["faults"]["retries"])
    (0, 0, 0)
    """
    from repro.compiler import stats as kstats

    admitted = stats.requests_admitted
    return {
        "requests": {
            "admitted": admitted,
            "rejected": stats.requests_rejected,
            "expired": stats.requests_expired,
            "completed": stats.requests_completed,
            "failed": stats.requests_failed,
            "degraded": stats.requests_degraded,
            "mean_queue_wait_s": (
                stats.queue_wait_s / admitted if admitted else 0.0
            ),
        },
        "plans": {
            "builds": stats.plan_builds,
            "cache_hits": stats.plan_cache_hits,
        },
        "kernels": {
            "built": kstats.kernels_built,
            "cache_hits": kstats.cache_hits,
            "fallbacks": kstats.fallbacks,
            "launches": stats.launches,
        },
        "faults": {
            "retries": stats.request_retries,
            "checkpoints": stats.service_checkpoints,
            "restores": stats.service_restores,
            "stragglers": stats.service_stragglers,
        },
        "health": {
            "probes": stats.health_probes,
            "numerical_faults": stats.numerical_faults,
            "recovery_attempts": stats.recovery_attempts,
        },
        "steps_run": stats.steps_run,
        "repacks": stats.repacks,
    }

"""Halo-resident field layout: fields stay put, halos move.

The WFA's two-orders-of-magnitude win comes from keeping every field
resident in PE-local memory for the whole run — only halo cells travel
(Rocki et al., arXiv:2010.03660).  The engine's analogue is this module:
instead of rebuilding a padded copy of every field per kernel launch
(``jnp.pad(mode="wrap")`` on one device, ``halo_pad``'s concatenates under
``shard_map``), each stenciled field is stored **once** at its run-wide
padded extent ``(nx + 2K, ny + 2K, nz)``, where ``K`` is the largest halo
window any scheduled segment needs (``max k·h`` over the plan, computed at
:func:`repro.engine.plan.plan` time).

Execution then touches memory three ways, none of which repacks a field:

* **enter/exit** — one conversion at each *program boundary* (start and end
  of one ``execute``), never inside the step loop;
* **margin refresh** — before a kernel launch reads a depth-``ph`` window,
  only the four edge *slabs* are rewritten in place
  (``dynamic_update_slice`` of wrap slabs on one device,
  :func:`repro.core.halo.halo_refresh`'s ``ppermute`` slabs on a mesh);
* **in-place outputs** — the fused kernels write back into the resident
  buffers via ``pl.pallas_call(..., input_output_aliases=...)`` (see
  :func:`repro.kernels.fused.build_fused_call`), and the executors donate
  the entry buffers (``jax.jit(..., donate_argnums=...)``), so the step
  loop allocates nothing per step.

Margin contents are *transient*: they are refreshed to depth ``ph`` right
before each launch that reads them and are dead in between, so segments
with different halo depths share one resident buffer safely.

Every operation here is **rank-agnostic over leading axes**: batched
ensemble plans (:class:`~repro.engine.options.RunOptions` with
``batch=B``) store each field as ``(B, nx + 2K, ny + 2K, nz)`` and one
refresh rewrites all B members' slabs in a single ``dynamic_update_slice``
— the (X, Y, Z) trailing axes are the only ones the layout ever touches.

>>> import numpy as np
>>> lay = HaloLayout(pad=2, shapes={"T": (4, 4, 3)})
>>> env = {"T": np.arange(48.0, dtype=np.float32).reshape(4, 4, 3)}
>>> padded = lay.enter(env)
>>> padded["T"].shape
(8, 8, 3)
>>> bool((lay.exit(padded)["T"] == env["T"]).all())
True
>>> batched = lay.enter({"T": np.stack([env["T"]] * 5)})
>>> batched["T"].shape
(5, 8, 8, 3)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HaloLayout:
    """Resident padded layout of one plan's fields.

    ``pad`` is the run-wide margin ``K`` (0 disables residency — enter and
    exit degrade to identity).  ``shapes`` records the *global* interior
    extents the plan was built from, as metadata for introspection only:
    enter/exit pad and slice whatever env they receive, which under
    ``shard_map`` is the per-device brick — and on a batched plan the
    ``(B, ...)``-leading stack — not these shapes.
    """

    pad: int
    shapes: Dict[str, Tuple[int, int, int]]

    def enter(self, env):
        """Pad every field to the resident extent (margins start zero; they
        are refreshed before any kernel reads them).  Leading (batch) axes
        pass through unpadded."""
        if self.pad == 0:
            return dict(env)
        K = self.pad

        def _pad(v):
            v = jnp.asarray(v)
            widths = ((0, 0),) * (v.ndim - 3) + ((K, K), (K, K), (0, 0))
            return jnp.pad(v, widths)

        return {n: _pad(v) for n, v in env.items()}

    def exit(self, env):
        """Slice every field's interior back out of the resident buffers."""
        if self.pad == 0:
            return dict(env)
        K = self.pad
        return {n: v[..., K:-K, K:-K, :] for n, v in env.items()}


def slab_rects(bx: int, by: int, h: int) -> Dict[str, Tuple[int, int, int, int]]:
    """Margin-slab geometry: name -> (ox, oy, sx, sy) in *brick* coordinates.

    The four depth-``h`` margin slabs of a (bx, by) brick, X slabs spanning
    the interior rows and Y slabs spanning the x-extended rows (so corners
    carry the diagonal neighbour / double-wrap data).  The rectangles are
    pairwise disjoint and exactly cover the margin frame.  Shared by the
    wrap refresh, the mesh exchange (:func:`repro.core.halo.exchange_slabs`)
    and the overlap scheduler's strip assembly, so the three cannot drift.
    """
    return {
        "lo_x": (-h, 0, h, by),
        "hi_x": (bx, 0, h, by),
        "lo_y": (-h, -h, bx + 2 * h, h),
        "hi_y": (-h, by, bx + 2 * h, h),
    }


def wrap_slabs(resident, margin: int, h: int) -> Dict[str, jnp.ndarray]:
    """Extract the depth-``h`` wrap margin slabs into *separate* buffers.

    The double-buffered half of the single-device margin refresh: the slab
    values are exactly what ``jnp.pad(interior, h, mode="wrap")`` would put
    in the margin frame (Y slabs assembled from the X slabs + interior edge
    columns, so corners wrap in both axes bitwise), but they live in their
    own small arrays — never aliasing the resident buffer an in-flight
    interior kernel writes — until :func:`land_slabs` stores them.
    """
    K = margin
    bx = resident.shape[-3] - 2 * K
    by = resident.shape[-2] - 2 * K
    lo_x = resident[..., K + bx - h : K + bx, K : K + by, :]
    hi_x = resident[..., K : K + h, K : K + by, :]
    lo_y = jnp.concatenate(
        [
            lo_x[..., :, by - h : by, :],
            resident[..., K : K + bx, K + by - h : K + by, :],
            hi_x[..., :, by - h : by, :],
        ],
        axis=-3,
    )
    hi_y = jnp.concatenate(
        [
            lo_x[..., :, 0:h, :],
            resident[..., K : K + bx, K : K + h, :],
            hi_x[..., :, 0:h, :],
        ],
        axis=-3,
    )
    return {"lo_x": lo_x, "hi_x": hi_x, "lo_y": lo_y, "hi_y": hi_y}


def land_slabs(resident, slabs: Dict[str, jnp.ndarray], margin: int, h: int):
    """Store extracted margin slabs into the resident buffer's margin frame.

    The landing half of the refresh: four ``dynamic_update_slice`` writes at
    the :func:`slab_rects` rectangles (disjoint, so order is irrelevant).
    Leading (batch) axes pass through whole.
    """
    if h == 0:
        return resident
    K = margin
    bx = resident.shape[-3] - 2 * K
    by = resident.shape[-2] - 2 * K
    lead = (0,) * (resident.ndim - 3)
    for name, (ox, oy, _, _) in slab_rects(bx, by, h).items():
        resident = jax.lax.dynamic_update_slice(
            resident, slabs[name], lead + (K + ox, K + oy, 0)
        )
    return resident


def wrap_refresh(resident, margin: int, h: int):
    """Refresh the depth-``h`` wrap margin of a resident array in place.

    The single-device analogue of :func:`repro.core.halo.halo_refresh`:
    reproduces exactly what ``jnp.pad(interior, h, mode="wrap")`` would have
    built — the periodic margins the roll interpreter's semantics demand —
    but as four ``dynamic_update_slice`` edge slabs into the standing buffer
    (:func:`wrap_slabs` extracted, :func:`land_slabs` stored) instead of a
    fresh padded copy of the whole field.

    ``resident`` may carry leading (batch) axes: slabs span them whole, so
    one update refreshes every ensemble member's margin at once.
    """
    if h == 0:
        return resident
    return land_slabs(resident, wrap_slabs(resident, margin, h), margin, h)


def strip_window(
    resident,
    slabs: Dict[str, jnp.ndarray],
    margin: int,
    h: int,
    region,
    bx: int,
    by: int,
):
    """Assemble one boundary region's padded input window.

    ``region`` is a shell :class:`repro.compiler.ir.RegionSpec`; the window
    is the ``(rx + 2h, ry + 2h, Z)`` input its depth-``h`` (= ``k·halo``)
    kernel launch consumes — brick cells sliced from the **pre-step**
    resident buffer, margin cells overwritten from the landed ``slabs``
    (rect intersection with :func:`slab_rects`).  Cell for cell this equals
    the window the monolithic kernel would have read off a refreshed
    buffer, which is what makes the split bitwise-exact; slicing from the
    pre-step buffer is also what lets the interior kernel write the same
    buffer in place concurrently.
    """
    K = margin
    wx0, wy0 = region.x0 - h, region.y0 - h
    wx1, wy1 = region.x0 + region.rx + h, region.y0 + region.ry + h
    win = resident[..., K + wx0 : K + wx1, K + wy0 : K + wy1, :]
    lead = (0,) * (resident.ndim - 3)
    for name, (ox, oy, sx, sy) in slab_rects(bx, by, h).items():
        ix0, iy0 = max(ox, wx0), max(oy, wy0)
        ix1, iy1 = min(ox + sx, wx1), min(oy + sy, wy1)
        if ix0 >= ix1 or iy0 >= iy1:
            continue
        piece = slabs[name][..., ix0 - ox : ix1 - ox, iy0 - oy : iy1 - oy, :]
        win = jax.lax.dynamic_update_slice(
            win, piece, lead + (ix0 - wx0, iy0 - wy0, 0)
        )
    return win


def land_region(resident, out, margin: int, region):
    """Store one region's kernel output into the resident buffer interior."""
    lead = (0,) * (resident.ndim - 3)
    return jax.lax.dynamic_update_slice(
        resident, out, lead + (margin + region.x0, margin + region.y0, 0)
    )

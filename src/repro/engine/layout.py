"""Halo-resident field layout: fields stay put, halos move.

The WFA's two-orders-of-magnitude win comes from keeping every field
resident in PE-local memory for the whole run — only halo cells travel
(Rocki et al., arXiv:2010.03660).  The engine's analogue is this module:
instead of rebuilding a padded copy of every field per kernel launch
(``jnp.pad(mode="wrap")`` on one device, ``halo_pad``'s concatenates under
``shard_map``), each stenciled field is stored **once** at its run-wide
padded extent ``(nx + 2K, ny + 2K, nz)``, where ``K`` is the largest halo
window any scheduled segment needs (``max k·h`` over the plan, computed at
:func:`repro.engine.plan.plan` time).

Execution then touches memory three ways, none of which repacks a field:

* **enter/exit** — one conversion at each *program boundary* (start and end
  of one ``execute``), never inside the step loop;
* **margin refresh** — before a kernel launch reads a depth-``ph`` window,
  only the four edge *slabs* are rewritten in place
  (``dynamic_update_slice`` of wrap slabs on one device,
  :func:`repro.core.halo.halo_refresh`'s ``ppermute`` slabs on a mesh);
* **in-place outputs** — the fused kernels write back into the resident
  buffers via ``pl.pallas_call(..., input_output_aliases=...)`` (see
  :func:`repro.kernels.fused.build_fused_call`), and the executors donate
  the entry buffers (``jax.jit(..., donate_argnums=...)``), so the step
  loop allocates nothing per step.

Margin contents are *transient*: they are refreshed to depth ``ph`` right
before each launch that reads them and are dead in between, so segments
with different halo depths share one resident buffer safely.

Every operation here is **rank-agnostic over leading axes**: batched
ensemble plans (:class:`~repro.engine.options.RunOptions` with
``batch=B``) store each field as ``(B, nx + 2K, ny + 2K, nz)`` and one
refresh rewrites all B members' slabs in a single ``dynamic_update_slice``
— the (X, Y, Z) trailing axes are the only ones the layout ever touches.

>>> import numpy as np
>>> lay = HaloLayout(pad=2, shapes={"T": (4, 4, 3)})
>>> env = {"T": np.arange(48.0, dtype=np.float32).reshape(4, 4, 3)}
>>> padded = lay.enter(env)
>>> padded["T"].shape
(8, 8, 3)
>>> bool((lay.exit(padded)["T"] == env["T"]).all())
True
>>> batched = lay.enter({"T": np.stack([env["T"]] * 5)})
>>> batched["T"].shape
(5, 8, 8, 3)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HaloLayout:
    """Resident padded layout of one plan's fields.

    ``pad`` is the run-wide margin ``K`` (0 disables residency — enter and
    exit degrade to identity).  ``shapes`` records the *global* interior
    extents the plan was built from, as metadata for introspection only:
    enter/exit pad and slice whatever env they receive, which under
    ``shard_map`` is the per-device brick — and on a batched plan the
    ``(B, ...)``-leading stack — not these shapes.
    """

    pad: int
    shapes: Dict[str, Tuple[int, int, int]]

    def enter(self, env):
        """Pad every field to the resident extent (margins start zero; they
        are refreshed before any kernel reads them).  Leading (batch) axes
        pass through unpadded."""
        if self.pad == 0:
            return dict(env)
        K = self.pad

        def _pad(v):
            v = jnp.asarray(v)
            widths = ((0, 0),) * (v.ndim - 3) + ((K, K), (K, K), (0, 0))
            return jnp.pad(v, widths)

        return {n: _pad(v) for n, v in env.items()}

    def exit(self, env):
        """Slice every field's interior back out of the resident buffers."""
        if self.pad == 0:
            return dict(env)
        K = self.pad
        return {n: v[..., K:-K, K:-K, :] for n, v in env.items()}


def wrap_refresh(resident, margin: int, h: int):
    """Refresh the depth-``h`` wrap margin of a resident array in place.

    The single-device analogue of :func:`repro.core.halo.halo_refresh`:
    reproduces exactly what ``jnp.pad(interior, h, mode="wrap")`` would have
    built — the periodic margins the roll interpreter's semantics demand —
    but as four ``dynamic_update_slice`` edge slabs into the standing buffer
    instead of a fresh padded copy of the whole field.  X slabs come from
    the interior's edge rows; Y slabs span the x-extended rows so corners
    wrap in both axes, matching ``jnp.pad``'s corner rule bitwise.

    ``resident`` may carry leading (batch) axes: slabs span them whole, so
    one update refreshes every ensemble member's margin at once.
    """
    if h == 0:
        return resident
    K = margin
    nx = resident.shape[-3] - 2 * K
    ny = resident.shape[-2] - 2 * K
    lead = (0,) * (resident.ndim - 3)
    upd = jax.lax.dynamic_update_slice
    lo_x = resident[..., K + nx - h : K + nx, K : K + ny, :]
    resident = upd(resident, lo_x, lead + (K - h, K, 0))
    hi_x = resident[..., K : K + h, K : K + ny, :]
    resident = upd(resident, hi_x, lead + (K + nx, K, 0))
    lo_y = resident[..., K - h : K + nx + h, K + ny - h : K + ny, :]
    resident = upd(resident, lo_y, lead + (K - h, K - h, 0))
    hi_y = resident[..., K - h : K + nx + h, K : K + h, :]
    return upd(resident, hi_y, lead + (K - h, K + ny, 0))

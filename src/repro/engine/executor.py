"""The engine executor: run an :class:`~repro.engine.plan.ExecutionPlan`.

One executor serves every backend the planner schedules:

* ``numpy`` — eager segment interpretation (the WFA validation mode);
* single device — segments wrapped in ``lax.fori_loop`` under one ``jax.jit``;
* mesh — the same loop structure applied per brick inside one ``shard_map``
  (ppermute halo exchange in each segment's step).

Time-tiled segments advance ``k`` steps per iteration (``n // k`` tiled
launches + ``n % k`` untiled remainder launches), which is where the
communication amortization lands: one halo exchange (or wrap pad) per tile.

Halo residency (:mod:`repro.engine.layout`): when the plan carries a padded
layout, the traced run *enters* it once (pad every field to the resident
extent), steps the fused segments on those standing buffers — margin slabs
refreshed in place, kernel outputs aliased — and *exits* once at the end;
interpreter segments inside a mixed plan are bracketed by exit/enter so
their roll semantics see plain arrays.  Both jitted executors **donate**
their entry buffers (``donate_argnums``), so with an all-fused plan the
whole step loop runs without allocating or repacking a single field copy.
The executor also derives the engine's static communication accounting from
the plan (see :mod:`repro.engine.stats`).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import _apply_op
from repro.engine.hooks import fire_step_hook
from repro.engine.plan import ExecutionPlan, Segment
from repro.engine.stats import stats


def _apply_segment(seg: Segment, env):
    """Trace one segment: tiled launches + remainder, or the plain loop."""
    if seg.loop is None:
        return seg.step(env)
    n, k = seg.loop.n, seg.time_tile
    if k > 1:
        env = jax.lax.fori_loop(0, n // k, lambda i, e: seg.step(e), env)
        if n % k:
            env = jax.lax.fori_loop(0, n % k, lambda i, e: seg.step_rem(e), env)
        return env
    return jax.lax.fori_loop(0, n, lambda i, e: seg.step(e), env)


def _layout_schedule(plan: ExecutionPlan):
    """The plan's step/conversion event stream: ``"enter"``/``"exit"``
    markers interleaved with segments.  Fused segments run on the layout's
    padded buffers; interpreter segments (mixed plans, lowering fallbacks)
    are bracketed by exit/enter so both step kinds see the env form they
    were compiled for.  With an all-fused plan this is exactly one enter
    and one exit per run.  Both the tracer and the repack accounting
    consume this one stream, so they cannot drift apart.
    """
    padded = False
    for seg in plan.segments:
        if seg.kind == "fused":
            if not padded:
                yield "enter"
                padded = True
        elif padded:
            yield "exit"
            padded = False
        yield seg
    if padded:
        yield "exit"


def _trace_plan(plan: ExecutionPlan, env):
    """Trace the whole plan: resident fused segments, plain interp segments
    (see :func:`_layout_schedule` for the conversion bracketing)."""
    layout = plan.layout
    if layout is None or layout.pad == 0:
        for seg in plan.segments:
            env = _apply_segment(seg, env)
        return env
    for ev in _layout_schedule(plan):
        if ev == "enter":
            env = layout.enter(env)
        elif ev == "exit":
            env = layout.exit(env)
        else:
            env = _apply_segment(ev, env)
    return env


def fresh_buffer(v):
    """Device array safe to donate: never aliases a caller-owned buffer.

    Copies unconditionally: ``jnp.asarray`` is a no-op for device arrays,
    and on CPU backends it may *zero-copy* an aligned host numpy array —
    either way the jitted runners would donate (invalidate, then reuse)
    memory the caller still holds."""
    return jnp.array(v, copy=True)


def _account(plan: ExecutionPlan) -> None:
    """Static communication accounting for one execution of ``plan``.

    Fused segments pay one pad/exchange per kernel launch (none when the
    body is halo-free); interpreter segments pad per op, per step.  Single-
    device ``jit``/``numpy`` interpretation rolls in place — no pad events.
    On a resident plan the fused "exchange" is the in-place margin-slab
    refresh (same count, a fraction of the bytes) and the only repacking
    conversions are the layout enter/exit events — two for an all-fused
    plan, plus a pair around each interpreter segment in a mixed plan.
    """
    resident = (
        plan.layout is not None
        and plan.layout.pad > 0
        and any(seg.kind == "fused" for seg in plan.segments)
    )
    if resident:
        stats.resident_runs += 1
        stats.repacks += sum(
            1 for ev in _layout_schedule(plan) if isinstance(ev, str)
        )
    if plan.batch > 1:
        stats.ensemble_runs += 1
        stats.ensemble_members += plan.batch
    for seg in plan.segments:
        n, k = seg.n_steps, seg.time_tile
        stats.steps_run += n
        if seg.kind == "fused":
            tiled = n // k if k > 1 else 0
            launches = tiled + (n % k if k > 1 else n)
            stats.launches += launches
            stats.tiles_fused += tiled
            if seg.split:
                # overlap split: every launch event is one interior kernel
                # plus `split` boundary shells, its exchange slabs in
                # flight while the interior computes
                stats.interior_launches += launches
                stats.boundary_launches += launches * seg.split
                if seg.halo > 0:
                    stats.overlapped_exchanges += launches
            if seg.halo > 0:
                stats.exchanges += launches
                if not resident:
                    stats.repacks += launches  # full pad/concat per launch
        else:
            stats.launches += n
            if plan.mesh is not None:
                stats.exchanges += n * len(seg.ops)
                stats.repacks += n * len(seg.ops)


def _run_numpy(plan: ExecutionPlan, env: Dict[str, np.ndarray], check: int = 0):
    if plan.batch > 1:
        # the eager validation backend has no vectorizing machinery to
        # batch through — run the members one by one and restack
        outs = [
            _run_numpy_one(plan, {k: v[b] for k, v in env.items()}, check)
            for b in range(plan.batch)
        ]
        return {k: np.stack([o[k] for o in outs]) for k in env}
    return _run_numpy_one(plan, env, check)


def _run_numpy_one(plan: ExecutionPlan, env: Dict[str, np.ndarray], check=0):
    from repro.engine import health as ehealth

    env = {k: np.asarray(v).copy() for k, v in env.items()}
    roll = lambda a, s, ax: np.roll(a, s, axis=ax)  # noqa: E731
    step_idx, since, last_good, good_step = 0, 0, None, 0
    if check > 0:
        if not ehealth.probe(env):
            _sentinel_fault(env, 0, None, 0)
        last_good = {k: v.copy() for k, v in env.items()}
    for seg in plan.segments:
        for _ in range(seg.n_steps):
            for op in seg.ops:
                env[op.field_name] = _apply_op(op, env, np, roll)
            step_idx += 1
            since += 1
            if check > 0 and since >= check:
                since = 0
                if not ehealth.probe(env):
                    _sentinel_fault(env, step_idx, last_good, good_step)
                last_good = {k: v.copy() for k, v in env.items()}
                good_step = step_idx
    if check > 0 and since:
        if not ehealth.probe(env):
            _sentinel_fault(env, step_idx, last_good, good_step)
    return env


def single_runner(plan: ExecutionPlan):
    """The jitted single-device runner for ``plan`` (entry env donated).

    Exposed for the residency tests: ``runner.lower(env)`` shows the
    donation markers and ``runner(env)`` consumes its argument buffers.

    A :class:`~repro.engine.plan.ExecutionPlan` built with
    ``RunOptions(differentiable=True)`` is **not** donated: under AD the
    entry buffers become saved residuals of the reverse pass (and the
    caller's arrays must survive the call), so donation is suppressed —
    the documented donation/AD rule.
    """

    def run(env):
        return _trace_plan(plan, env)

    donate = () if plan.differentiable else (0,)
    return jax.jit(run, donate_argnums=donate)


def _run_single(plan: ExecutionPlan, env):
    env = {k: fresh_buffer(v) for k, v in env.items()}
    return jax.device_get(single_runner(plan)(env))


def sharded_runner(plan: ExecutionPlan, names=None):
    """The jitted ``shard_map`` runner for ``plan`` (entry env donated).

    Returns ``(runner, sharding)``; the layout enter/exit happens *inside*
    the mapped function, so resident buffers are per-brick and the margin
    refresh is pure neighbour ppermute.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import shard_map

    mesh = plan.mesh
    _, _, ax_x, ax_y = plan.mesh_ctx
    # batched plans brick the trailing (X, Y) axes only: every device holds
    # all B members of its brick, so ensemble steps need no extra collectives
    spec = P(None, ax_x, ax_y, None) if plan.batch > 1 else P(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    specs = {k: spec for k in (plan.program.fields if names is None else names)}

    def local(env):
        return _trace_plan(plan, env)

    stepped = jax.jit(
        shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs, check=False),
        donate_argnums=() if plan.differentiable else (0,),
    )
    return stepped, sharding


def _run_sharded(plan: ExecutionPlan, env):
    stepped, sharding = sharded_runner(plan, names=list(env))
    genv = {k: jax.device_put(fresh_buffer(v), sharding) for k, v in env.items()}
    out = stepped(genv)
    return {k: np.asarray(jax.device_get(v)) for k, v in out.items()}


# ---------------------------------------------------------------------------
# explicit-path sentinels: chunked guarded execution (RunOptions.check_finite)
# ---------------------------------------------------------------------------


def _sentinel_fault(env, step_idx, last_good, good_step, exit_fn=None):
    """Raise the NumericalFault for a tripped explicit-path probe."""
    from repro.engine import health as ehealth

    stats.numerical_faults += 1
    bad = ehealth.poisoned_fields(env)
    if last_good is not None and exit_fn is not None:
        last_good = exit_fn(last_good)
    if last_good is not None:
        last_good = {k: np.asarray(jax.device_get(v)) for k, v in last_good.items()}
    raise ehealth.NumericalFault(
        f"non-finite field state at step {step_idx} "
        f"(fields: {', '.join(bad) or 'unknown'}; "
        f"last finite probe at step {good_step})",
        outcome="NAN_RESIDUAL",
        step=step_idx,
        last_good=last_good,
    )


def _guarded_wrap(plan: ExecutionPlan, fn, names):
    """``jit(fn)`` for a single-device plan, ``jit(shard_map(fn))`` on a
    mesh — the guarded analogue of :func:`single_runner` /
    :func:`sharded_runner`, never donating (the previous chunk's env is the
    sentinel's ``last_good`` state and must survive the next launch)."""
    if plan.mesh is None:
        return jax.jit(fn)
    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import shard_map

    _, _, ax_x, ax_y = plan.mesh_ctx
    spec = P(None, ax_x, ax_y, None) if plan.batch > 1 else P(ax_x, ax_y, None)
    specs = {k: spec for k in names}
    return jax.jit(
        shard_map(fn, mesh=plan.mesh, in_specs=(specs,), out_specs=specs, check=False)
    )


def _guarded_loop_wrap(plan: ExecutionPlan, step_fn, per_chunk, names):
    """One jitted guarded loop: up to ``nchunks`` iterations of
    ``per_chunk`` launches each, with the ``isfinite`` probe fused into the
    ``while_loop`` carry — a single dispatch per segment, stopping at the
    first failed probe.

    Returns a runner ``(env, nchunks) -> (env, chunks_run, ok)``.  The
    carry holds only the current state: keeping a last-good snapshot alive
    would block XLA from ping-ponging the chunk buffers in place and cost
    an extra generation per probe, so the happy path pays one reduction per
    chunk and nothing else.  ``nchunks`` is traced, which lets the caller
    reuse the same compiled runner to replay the prefix and regenerate the
    last probed-good state on the rare failure path.  On a mesh the
    per-brick verdicts reduce with one ``pmin`` inside the loop, so the
    stop condition is uniform across devices.
    """
    from repro.engine import health as ehealth

    mesh = plan.mesh

    def chunk(e):
        return jax.lax.fori_loop(0, per_chunk, lambda i, ee: step_fn(ee), e)

    if mesh is None:
        probe = ehealth.probe_ok
    else:
        _, _, ax_x, ax_y = plan.mesh_ctx

        def probe(out):
            ok = ehealth.probe_ok(out)
            return jax.lax.pmin(ok.astype(jnp.int32), (ax_x, ax_y)) > 0

    def run(env, nchunks):
        def body(c):
            e, i, ok = c
            new = chunk(e)
            return (new, i + 1, probe(new))

        def cond(c):
            return c[2] & (c[1] < nchunks)

        init = (env, jnp.int32(0), jnp.bool_(True))
        return jax.lax.while_loop(cond, body, init)

    if mesh is None:
        return jax.jit(run)
    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import shard_map

    _, _, ax_x, ax_y = plan.mesh_ctx
    spec = P(None, ax_x, ax_y, None) if plan.batch > 1 else P(ax_x, ax_y, None)
    specs = {k: spec for k in names}
    return jax.jit(
        shard_map(
            run,
            mesh=mesh,
            in_specs=(specs, P()),
            out_specs=(specs, P(), P()),
            check=False,
        )
    )


def _run_guarded(plan: ExecutionPlan, env, every: int):
    """Chunked execution probing field finiteness every ~``every`` steps.

    The plan's compiled launches are regrouped into chunks of
    ``ceil(every / k)`` launches, and each segment runs as **one** jitted
    ``while_loop`` whose carry holds the current env and the probe word
    (:func:`_guarded_loop_wrap`) — the probe costs one fused reduction per
    ``every`` steps, with a single dispatch per segment and no extra device
    syncs.  A failed probe stops the loop; the host then replays the
    prefix from the retained segment entry to regenerate the last
    probed-good state (the rare path pays the recompute so the happy path
    carries no snapshot) and raises
    :class:`repro.engine.health.NumericalFault` with the step index and the
    last-good state.  That amortization is the ≤2% overhead budget the
    benchmark gates (``benchmarks/health_overhead.py``).
    """
    from repro.engine import health as ehealth

    names = list(env)
    if plan.mesh is None:
        env = {k: fresh_buffer(v) for k, v in env.items()}
    else:
        from jax.sharding import PartitionSpec as P

        _, _, ax_x, ax_y = plan.mesh_ctx
        spec = P(None, ax_x, ax_y, None) if plan.batch > 1 else P(ax_x, ax_y, None)
        sharding = jax.sharding.NamedSharding(plan.mesh, spec)
        env = {k: jax.device_put(fresh_buffer(v), sharding) for k, v in env.items()}

    layout = plan.layout
    use_layout = (
        layout is not None
        and layout.pad > 0
        and any(seg.kind == "fused" for seg in plan.segments)
    )
    events = list(_layout_schedule(plan)) if use_layout else list(plan.segments)
    enter = _guarded_wrap(plan, layout.enter, names) if use_layout else None
    exit_ = _guarded_wrap(plan, layout.exit, names) if use_layout else None

    # probe the entry state too: a poisoned initial condition faults at
    # step 0 with last_good=None rather than masquerading as "last good"
    if not ehealth.probe(env):
        _sentinel_fault(env, 0, None, 0)

    state = {
        "step": 0,  # logical steps completed
        "padded": False,
    }

    def run_loop(step_fn, per_chunk, chunks, steps_per_chunk):
        """One guarded while_loop over `chunks` chunks of `per_chunk`
        launches; env at entry has passed the previous probe, so replaying
        a prefix from it always lands on a probed-good state."""
        nonlocal env
        if chunks <= 0:
            return
        runner = _guarded_loop_wrap(plan, step_fn, per_chunk, names)
        entry = env  # retained: the failure path replays the good prefix
        new_env, i, ok = runner(entry, chunks)
        i = int(jax.device_get(i))
        stats.health_probes += i
        done = state["step"] + i * steps_per_chunk
        if not bool(jax.device_get(ok)):
            # the loop stops on the first failed probe, so i >= 1 and the
            # first i-1 chunks all probed finite — rerun just those to
            # recover the last-good state (deterministic compiled body)
            good = runner(entry, i - 1)[0] if i > 1 else entry
            good_step = state["step"] + (i - 1) * steps_per_chunk
            exit_fn = exit_ if state["padded"] else None
            _sentinel_fault(new_env, done, good, good_step, exit_fn)
        env = new_env
        state["step"] = done

    def chunked(step_fn, launches, steps_per_launch):
        """Split `launches` calls of step_fn into probe-granule chunks."""
        if launches <= 0:
            return
        per_chunk = max(1, -(-every // steps_per_launch))  # ceil
        per_chunk = min(per_chunk, launches)
        full, tail = divmod(launches, per_chunk)
        run_loop(step_fn, per_chunk, full, per_chunk * steps_per_launch)
        if tail:
            run_loop(step_fn, tail, 1, tail * steps_per_launch)

    for ev in events:
        if ev == "enter":
            env = enter(env)
            state["padded"] = True
            continue
        if ev == "exit":
            env = exit_(env)
            state["padded"] = False
            continue
        seg = ev
        if seg.loop is None:
            run_loop(seg.step, 1, 1, 1)
            continue
        n, k = seg.loop.n, seg.time_tile
        if k > 1:
            chunked(seg.step, n // k, k)
            chunked(seg.step_rem, n % k, 1)
        else:
            chunked(seg.step, n, 1)
    if state["padded"]:
        env = exit_(env)
    return {k: np.asarray(jax.device_get(v)) for k, v in env.items()}


def execute(plan: ExecutionPlan, env: Dict[str, np.ndarray], options=None):
    """Run the plan from ``env`` (name -> (X, Y, Z) array); returns the final
    env as host NumPy arrays.  Updates :data:`repro.engine.stats`.

    Fires the engine's step hook (:mod:`repro.engine.hooks`) before any
    state advances, so an installed fault injector interrupts the run where
    a dead device would — before this execution, after the previous one.

    ``options=RunOptions(check_finite=N)`` routes through the guarded
    chunked runners (:func:`_run_guarded`): an ``isfinite`` sentinel every
    ~N steps, aborting with :class:`repro.engine.health.NumericalFault`
    instead of returning poisoned state.  ``check_finite=0`` (default) is
    the sentinel-free fast path — bitwise identical to previous behavior.
    """
    check = int(getattr(options, "check_finite", 0) or 0)
    fire_step_hook(stats.steps_run, tag="execute")
    t0 = time.perf_counter()
    if plan.backend == "numpy":
        out = _run_numpy(plan, env, check)
    elif check > 0:
        out = _run_guarded(plan, env, check)
    elif plan.mesh is None:
        out = _run_single(plan, env)
    else:
        out = _run_sharded(plan, env)
    stats.elapsed_s += time.perf_counter() - t0
    _account(plan)
    return {k: np.asarray(v) for k, v in out.items()}


def run_program(
    program,
    env: Dict[str, np.ndarray] = None,
    options=None,
    *,
    backend=None,
    mesh=None,
    time_tile=None,
    resident=None,
):
    """plan + execute in one call (the ``WFAInterface.make`` entry point).

    Policy travels as ``options=RunOptions(...)`` (a bare string is the
    backend); the legacy keywords forward into the bundle without a
    deprecation warning — this is an internal entry point, and the public
    shims (``make``/``run_sharded``/``engine.plan``) already warned.
    ``options.batch=B`` expects every env buffer stacked to ``(B, X, Y, Z)``.
    ``resident=False`` forces the legacy repack-per-launch stepping (the
    bitwise reference for the halo-resident layout).

    With ``options.recovery.detile_explicit`` (and sentinels armed via
    ``check_finite``), a :class:`~repro.engine.health.NumericalFault` from
    an aggressively scheduled plan (time-tiled or overlap-split) triggers
    one de-escalated retry — ``time_tile=1``, ``overlap=False`` — before
    the fault propagates: the conservative schedule changes rounding, the
    cheapest recovery for a marginal explicit run."""
    from repro.engine.options import RunOptions
    from repro.engine.plan import plan as _plan

    if options is None:
        options = RunOptions()
    elif isinstance(options, str):
        options = RunOptions(backend=options)
    overrides = {
        k: v
        for k, v in (
            ("backend", backend),
            ("mesh", mesh),
            ("time_tile", time_tile),
            ("resident", resident),
        )
        if v is not None
    }
    if overrides:
        options = options.replace(**overrides)
    p = _plan(program, options)
    if env is None:
        env = {n: f.init_data for n, f in program.fields.items()}
    if p.batch > 1:
        # a batched plan steps (B, X, Y, Z) stacks; broadcast any field the
        # caller supplied unstacked (identical members — Ensemble overrides
        # arrive already stacked)
        env = {
            k: (
                np.broadcast_to(v, (p.batch,) + np.shape(v)).copy()
                if np.ndim(v) == 3
                else v
            )
            for k, v in env.items()
        }
    try:
        return execute(p, env, options)
    except Exception as fault:
        from repro.engine import health as ehealth

        if not isinstance(fault, ehealth.NumericalFault):
            raise
        rec = options.recovery
        aggressive = any(
            seg.time_tile > 1 or seg.split for seg in p.segments
        )
        if rec is None or not rec.detile_explicit or not aggressive:
            raise
        import logging

        logging.getLogger("repro.engine").warning(
            "explicit sentinel tripped at step %s; retrying with the "
            "conservative schedule (time_tile=1, overlap off)",
            fault.step,
        )
        stats.recovery_attempts += 1
        opts2 = options.replace(time_tile=1, overlap=False)
        return execute(_plan(program, opts2), env, opts2)


# ---------------------------------------------------------------------------
# reverse-mode AD: checkpointed differentiable stepping
# ---------------------------------------------------------------------------


def _diff_launch(step, ref_step):
    """Wrap one compiled launch in a ``custom_vjp``.

    The primal runs the fused kernel; the backward pass differentiates the
    *roll-interpreter* application of the same body at the saved input env —
    for the (bi)linear bodies the compiler fuses, that VJP is exactly the
    transpose of the kernel's map (both compute the same function; the
    bitwise backend-agreement tests pin it), so the gradient is exact while
    the forward sweep stays on the compiled path."""

    @jax.custom_vjp
    def f(env):
        return step(env)

    def fwd(env):
        return step(env), env

    def bwd(env, ct):
        _, pullback = jax.vjp(ref_step, env)
        return pullback(ct)

    f.defvjp(fwd, bwd)
    return f


def _chunked(launch, env, n: int, chunk: int, checkpoint: bool):
    """Run ``n`` launches, rematerializing in chunks of ``chunk``.

    ``jax.checkpoint`` over each chunk runner caps the reverse pass's saved
    residuals at O(n/chunk + chunk) envs instead of O(n) — the classic
    two-level ladder.  ``checkpoint=False`` is the all-residuals reference
    the ~1 ulp property test compares against."""
    if n <= 0:
        return env

    def chunk_fn(e, size):
        for _ in range(size):
            e = launch(e)
        return e

    if not checkpoint or n <= chunk:
        return chunk_fn(env, n)
    full, tail = divmod(n, chunk)
    ck = jax.checkpoint(lambda e: chunk_fn(e, chunk))
    env, _ = jax.lax.scan(lambda e, _: (ck(e), None), env, None, length=full)
    return chunk_fn(env, tail)


def differentiable_runner(
    plan: ExecutionPlan, *, checkpoint: bool = True, chunk_steps: int = None
):
    """Reverse-differentiable ``run(env) -> env`` for a differentiable plan.

    Requires a plan built with ``RunOptions(differentiable=True)`` (repack
    steps, no donation, no in-place residency).  Fused segments keep their
    compiled kernels on the primal sweep — each launch is wrapped in a
    ``custom_vjp`` whose backward differentiates the equivalent interpreter
    application (see :func:`_diff_launch`) — and the time loop is a
    checkpointed ladder: chunk runners of ``chunk_steps`` steps (snapped to
    the segment's time-tile factor ``k``, default ``k·ceil(sqrt(launches))``)
    rematerialize under ``jax.checkpoint``, so reverse-pass memory scales
    with the square root of the step count rather than linearly.

    ``checkpoint=False`` keeps every launch's residuals — the reference the
    checkpointed gradients are tested against.  On a mesh plan the returned
    runner maps the same ladder over bricks inside ``shard_map`` (ppermute
    carries its own transpose rule, so the exchange reverses exactly).

    The result is a plain traceable function: compose with ``jax.jit`` /
    ``jax.grad`` at the call site.  For step counts whose residuals exceed
    device memory even checkpointed, see :func:`checkpointed_vjp` (host /
    disk spill).
    """
    if not plan.differentiable:
        raise ValueError(
            "differentiable_runner needs a plan built with "
            "RunOptions(differentiable=True)"
        )
    if plan.backend == "numpy":
        raise ValueError("the eager numpy backend is not differentiable")
    from repro.engine.plan import compile_body

    shapes = {n: f.shape for n, f in plan.program.fields.items()}
    dtypes = {n: f.dtype for n, f in plan.program.fields.items()}

    staged = []
    for seg in plan.segments:
        if seg.kind == "fused":
            ref1, _ = compile_body(
                seg.ops,
                seg.loop,
                shapes,
                dtypes,
                "jit",
                mesh_ctx=plan.mesh_ctx,
                batch=plan.batch,
            )

            def _ref_k(e, _ref=ref1, _k=seg.time_tile):
                for _ in range(_k):
                    e = _ref(e)
                return e

            launch = _diff_launch(seg.step, _ref_k)
            launch_rem = (
                _diff_launch(seg.step_rem, ref1)
                if seg.step_rem is not None
                else None
            )
        else:
            launch, launch_rem = seg.step, seg.step
        staged.append((seg, launch, launch_rem))

    def run(env):
        env = dict(env)
        for seg, launch, launch_rem in staged:
            if seg.loop is None:
                env = launch(env)
                continue
            n, k = seg.loop.n, seg.time_tile
            if k > 1:
                chunk = max(1, (chunk_steps or 0) // k) or None
                launches = n // k
                chunk = chunk or max(1, int(np.ceil(np.sqrt(max(1, launches)))))
                env = _chunked(launch, env, launches, chunk, checkpoint)
                env = _chunked(launch_rem, env, n % k, max(1, n % k), checkpoint)
            else:
                chunk = chunk_steps or max(1, int(np.ceil(np.sqrt(max(1, n)))))
                env = _chunked(launch, env, n, chunk, checkpoint)
        return env

    if plan.mesh is None:
        return run

    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import shard_map

    _, _, ax_x, ax_y = plan.mesh_ctx
    spec = P(None, ax_x, ax_y, None) if plan.batch > 1 else P(ax_x, ax_y, None)
    specs = {k: spec for k in plan.program.fields}
    return shard_map(
        run, mesh=plan.mesh, in_specs=(specs,), out_specs=specs, check=False
    )


def checkpointed_vjp(chunk_fn, env0, n_chunks: int, *, spill_dir: str = None):
    """Out-of-core reverse sweep: spill chunk-boundary states, replay back.

    For runs whose checkpointed residual ladder still exceeds device memory,
    this trades the in-device ``jax.checkpoint`` ladder for host-side chunk
    snapshots: the forward sweep applies ``chunk_fn`` (any differentiable
    ``env -> env``, e.g. one chunk of :func:`differentiable_runner` steps)
    ``n_chunks`` times, saving each chunk's *input* env — to host memory, or
    to disk via :class:`repro.checkpoint.manager.CheckpointManager` when
    ``spill_dir`` is given (atomic npz snapshots, restored with their exact
    dtypes).  Returns ``(env_final, vjp_fn)``; ``vjp_fn(cotangent_env)``
    replays the chunks newest-first, restoring each saved state and pulling
    the cotangent back through ``jax.vjp(chunk_fn, state)`` — peak device
    memory is one chunk's residuals regardless of run length.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1; got {n_chunks}")
    manager = None
    snaps = []
    if spill_dir is not None:
        from repro.checkpoint.manager import CheckpointManager

        manager = CheckpointManager(spill_dir, keep=n_chunks)
    env = {k: jnp.asarray(v) for k, v in env0.items()}
    for i in range(n_chunks):
        if manager is not None:
            manager.save(i, env)
        else:
            snaps.append(env)
        env = chunk_fn(env)
    final = env

    def vjp_fn(ct):
        ct = {k: jnp.asarray(v) for k, v in ct.items()}
        for i in reversed(range(n_chunks)):
            if manager is not None:
                saved, _, _ = manager.restore(final, step=i)
            else:
                saved = snaps[i]
            _, pullback = jax.vjp(chunk_fn, saved)
            (ct,) = pullback(ct)
        return ct

    return final, vjp_fn

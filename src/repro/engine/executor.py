"""The engine executor: run an :class:`~repro.engine.plan.ExecutionPlan`.

One executor serves every backend the planner schedules:

* ``numpy`` — eager segment interpretation (the WFA validation mode);
* single device — segments wrapped in ``lax.fori_loop`` under one ``jax.jit``;
* mesh — the same loop structure applied per brick inside one ``shard_map``
  (ppermute halo exchange in each segment's step).

Time-tiled segments advance ``k`` steps per iteration (``n // k`` tiled
launches + ``n % k`` untiled remainder launches), which is where the
communication amortization lands: one halo exchange (or wrap pad) per tile.
The executor also derives the engine's static communication accounting from
the plan (see :mod:`repro.engine.stats`).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import _apply_op
from repro.engine.plan import ExecutionPlan, Segment
from repro.engine.stats import stats


def _apply_segment(seg: Segment, env):
    """Trace one segment: tiled launches + remainder, or the plain loop."""
    if seg.loop is None:
        return seg.step(env)
    n, k = seg.loop.n, seg.time_tile
    if k > 1:
        env = jax.lax.fori_loop(0, n // k, lambda i, e: seg.step(e), env)
        if n % k:
            env = jax.lax.fori_loop(0, n % k, lambda i, e: seg.step_rem(e), env)
        return env
    return jax.lax.fori_loop(0, n, lambda i, e: seg.step(e), env)


def _account(plan: ExecutionPlan) -> None:
    """Static communication accounting for one execution of ``plan``.

    Fused segments pay one pad/exchange per kernel launch (none when the
    body is halo-free); interpreter segments pad per op, per step.  Single-
    device ``jit``/``numpy`` interpretation rolls in place — no pad events.
    """
    for seg in plan.segments:
        n, k = seg.n_steps, seg.time_tile
        stats.steps_run += n
        if seg.kind == "fused":
            tiled = n // k if k > 1 else 0
            launches = tiled + (n % k if k > 1 else n)
            stats.launches += launches
            stats.tiles_fused += tiled
            if seg.halo > 0:
                stats.exchanges += launches
        else:
            stats.launches += n
            if plan.mesh is not None:
                stats.exchanges += n * len(seg.ops)


def _run_numpy(plan: ExecutionPlan, env: Dict[str, np.ndarray]):
    env = {k: v.copy() for k, v in env.items()}
    roll = lambda a, s, ax: np.roll(a, s, axis=ax)  # noqa: E731
    for seg in plan.segments:
        for _ in range(seg.n_steps):
            for op in seg.ops:
                env[op.field_name] = _apply_op(op, env, np, roll)
    return env


def _run_single(plan: ExecutionPlan, env):
    env = {k: jnp.asarray(v) for k, v in env.items()}

    @jax.jit
    def run(env):
        for seg in plan.segments:
            env = _apply_segment(seg, env)
        return env

    return jax.device_get(run(env))


def _run_sharded(plan: ExecutionPlan, env):
    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import shard_map

    mesh = plan.mesh
    _, _, ax_x, ax_y = plan.mesh_ctx
    spec = P(ax_x, ax_y, None)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    genv = {k: jax.device_put(jnp.asarray(v), sharding) for k, v in env.items()}
    specs = {k: spec for k in genv}

    def local(env):
        for seg in plan.segments:
            env = _apply_segment(seg, env)
        return env

    stepped = jax.jit(
        shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs, check=False)
    )
    out = stepped(genv)
    return {k: np.asarray(jax.device_get(v)) for k, v in out.items()}


def execute(plan: ExecutionPlan, env: Dict[str, np.ndarray]):
    """Run the plan from ``env`` (name -> (X, Y, Z) array); returns the final
    env as host NumPy arrays.  Updates :data:`repro.engine.stats`."""
    t0 = time.perf_counter()
    if plan.backend == "numpy":
        out = _run_numpy(plan, env)
    elif plan.mesh is None:
        out = _run_single(plan, env)
    else:
        out = _run_sharded(plan, env)
    stats.elapsed_s += time.perf_counter() - t0
    _account(plan)
    return {k: np.asarray(v) for k, v in out.items()}


def run_program(
    program,
    env: Dict[str, np.ndarray] = None,
    backend: str = "jit",
    mesh=None,
    time_tile=None,
):
    """plan + execute in one call (the ``WFAInterface.make`` entry point)."""
    from repro.engine.plan import plan as _plan

    p = _plan(program, backend=backend, mesh=mesh, time_tile=time_tile)
    if env is None:
        env = {n: f.init_data for n, f in program.fields.items()}
    return execute(p, env)

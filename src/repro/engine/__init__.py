"""repro.engine — the unified execution engine (planner + executor).

Every way of running a recorded WFA program — ``WFAInterface.make`` (all
backends), ``core.halo.run_sharded`` and the operator/rhs applications
behind ``wfa.solve`` — dispatches through this package:

* :func:`plan` schedules the program's op groups into
  :class:`~repro.engine.plan.Segment`s (fused kernel vs interpreter, with a
  time-tile factor per loop body);
* :func:`execute` runs a plan eagerly (``numpy``), under one ``jax.jit``
  (single device) or inside one ``shard_map`` (mesh);
* :func:`compile_body` builds a single body application ``env -> env`` —
  the one backend if/else in the tree — for the solver's matrix-free
  operator steps;
* :data:`stats` exposes the communication accounting (steps, launches,
  halo exchanges / wrap pads, tiles fused, steps/sec).

Temporal blocking: a fused segment with ``time_tile=k`` advances k steps
per kernel launch off one halo exchange (or wrap pad) of depth ``k·h`` —
the wafer-scale trapezoid schedule (Rocki et al.) on the TPU mesh.  Pass
``time_tile=`` through ``make``/``run_sharded`` to override the planner's
auto-pick; illegal factors clamp with a logged reason, non-lowerable bodies
fall back to the untiled interpreter exactly as before.
"""

from repro.engine import health
from repro.engine.executor import (
    checkpointed_vjp,
    differentiable_runner,
    execute,
    run_program,
    sharded_runner,
    single_runner,
)
from repro.engine.health import NumericalFault, RecoveryPolicy
from repro.engine.layout import HaloLayout
from repro.engine.options import UNSET, RunOptions, resolve_options
from repro.engine.plan import (
    BACKENDS,
    ExecutionPlan,
    LevelSegment,
    Segment,
    compile_body,
    plan,
    plan_mg_levels,
)
from repro.engine.stats import EngineStats, reset_stats, service_stats, stats

__all__ = [
    "BACKENDS",
    "EngineStats",
    "ExecutionPlan",
    "HaloLayout",
    "LevelSegment",
    "NumericalFault",
    "RecoveryPolicy",
    "RunOptions",
    "Segment",
    "UNSET",
    "compile_body",
    "execute",
    "health",
    "plan",
    "plan_mg_levels",
    "reset_stats",
    "resolve_options",
    "checkpointed_vjp",
    "differentiable_runner",
    "run_program",
    "service_stats",
    "sharded_runner",
    "single_runner",
    "stats",
]

"""``RunOptions`` — one frozen bundle for every execution-policy knob.

The execution entry points (``WFAInterface.make``, ``run_sharded``,
``wfa.solve``, ``engine.plan``) each grew the same ad-hoc ``backend=`` /
``mesh=`` / ``time_tile=`` / ``resident=`` keyword sprawl; this module
replaces all of it with a single frozen :class:`RunOptions` value accepted
by all four — now also carrying ``batch=``, the leading ensemble axis that
one kernel launch advances (see :mod:`repro.core.ensemble`).

The legacy keywords still work everywhere as thin deprecation shims: they
warn **once per entry point per keyword** and forward into the options
bundle (an explicit legacy keyword overrides the same field of a passed
``options=``, so half-migrated call sites behave predictably).

>>> opts = RunOptions(backend="pallas", time_tile=4, batch=8)
>>> opts.batch, opts.resident
(8, True)
>>> opts.replace(batch=1).batch
1
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Set, Tuple


class _Unset:
    """Sentinel distinguishing "not passed" from an explicit ``None``."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<unset>"


UNSET = _Unset()

#: (entry point, keyword) pairs that already warned this process
_WARNED: Set[Tuple[str, str]] = set()


@dataclasses.dataclass(frozen=True)
class RunOptions:
    """Execution policy for one plan/run, shared by every entry point.

    ``backend=None`` means "the entry point's default" (``make`` defaults to
    ``jit``, ``wfa.solve`` to ``pallas``, ``run_sharded`` to ``jit``), so
    one options value can travel between entry points without pinning a
    backend.  ``batch`` is the leading ensemble axis: every field buffer
    grows a ``(B, ...)`` leading dimension and one kernel launch advances
    all ``B`` members (``batch=1`` is the classic single-scenario path).

    ``overlap`` selects the interior/boundary kernel split that hides the
    halo exchange behind interior compute (resident pallas plans only):
    ``True`` forces the split wherever it is legal, ``False`` keeps the
    monolithic fused launch, and ``"auto"`` (the default) splits only when
    the measured cost model (:mod:`repro.core.perfmodel`) holds a
    calibrated entry for the body predicting the split faster — so
    uncalibrated runs keep today's schedule.

    ``differentiable=True`` builds the run for reverse-mode AD: jitted
    runners stop donating their entry buffers (donated buffers cannot be
    saved as VJP residuals, and callers keep their arrays), plans skip the
    halo-resident in-place layout, and ``wfa.solve`` routes through the
    implicit-function-theorem adjoint (:mod:`repro.solver.adjoint`).

    ``recovery=RecoveryPolicy(...)`` (:mod:`repro.solver.health`) arms the
    implicit path's escalation ladder — a failed solve restarts/escalates/
    re-runs at fp64 per the policy and raises ``NumericalFault`` when
    exhausted — and lets explicit plans de-escalate (``time_tile=1``,
    ``overlap=False``) after a sentinel trip.  ``check_finite=N > 0`` arms
    the explicit path's ``isfinite`` sentinel every N steps (amortized at
    the chunk granule; 0 — the default — keeps benchmarks probe-free).
    """

    backend: Optional[str] = None
    mesh: Optional[object] = None
    time_tile: Optional[int] = None
    resident: bool = True
    batch: int = 1
    overlap: object = "auto"
    differentiable: bool = False
    recovery: Optional[object] = None
    check_finite: int = 0

    def __post_init__(self):
        if int(self.batch) < 1:
            raise ValueError(f"batch must be >= 1; got {self.batch}")
        object.__setattr__(self, "batch", int(self.batch))
        if self.overlap not in (True, False, "auto"):
            raise ValueError(
                f"overlap must be True, False or 'auto'; got {self.overlap!r}"
            )
        if self.differentiable not in (True, False):
            raise ValueError(
                f"differentiable must be a bool; got {self.differentiable!r}"
            )
        if int(self.check_finite) < 0:
            raise ValueError(
                f"check_finite must be >= 0 (0 disables); got {self.check_finite}"
            )
        object.__setattr__(self, "check_finite", int(self.check_finite))
        if self.recovery is not None:
            from repro.solver.health import RecoveryPolicy

            if not isinstance(self.recovery, RecoveryPolicy):
                raise TypeError(
                    "recovery must be a repro.solver.health.RecoveryPolicy; "
                    f"got {type(self.recovery).__name__}"
                )

    def replace(self, **changes) -> "RunOptions":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)

    def resolved_backend(self, default: str) -> str:
        return default if self.backend is None else self.backend


def _warn_once(entry: str, kwarg: str, hint: str) -> None:
    key = (entry, kwarg)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(
        f"{entry}({kwarg}=...) is deprecated; pass "
        f"options=wfa.RunOptions({hint}) instead",
        DeprecationWarning,
        stacklevel=4,
    )


def resolve_options(options, entry: str, **legacy) -> RunOptions:
    """Fold an ``options=`` value and legacy keywords into one RunOptions.

    ``legacy`` maps RunOptions field names to the entry point's keyword
    values, with :data:`UNSET` marking "not passed".  Every explicitly
    passed legacy keyword emits one :class:`DeprecationWarning` per entry
    point and overrides the corresponding field of ``options``.  A bare
    string ``options`` is accepted as the backend (the historical
    positional-``backend`` spelling of ``plan``).
    """
    if options is None:
        options = RunOptions()
    elif isinstance(options, str):
        options = RunOptions(backend=options)
    elif not isinstance(options, RunOptions):
        raise TypeError(
            f"options must be a RunOptions (or backend string); "
            f"got {type(options).__name__}"
        )
    given = {k: v for k, v in legacy.items() if not isinstance(v, _Unset)}
    for k, v in given.items():
        _warn_once(entry, k, f"{k}={v!r}")
    if given:
        options = dataclasses.replace(options, **given)
    return options

"""Engine-level numerical health: explicit-path ``isfinite`` sentinels.

The implicit path classifies failures *inside* its guarded Krylov loops
(:mod:`repro.solver.health`); an explicit time loop has no residual to
watch, so the executor instead probes field-state finiteness at the
checkpoint-chunk granule when ``RunOptions(check_finite=N)`` arms it.  A
probe is one fused ``isfinite``/``all`` reduction per field — amortized
over N steps it stays under the documented 2% overhead gate — and a trip
aborts the run with :class:`NumericalFault` carrying the offending step
index plus the last state that passed a probe (``last_good``).

The failure taxonomy, recovery policy and fault type are shared with the
solver layer; this module re-exports them so engine/service code has one
import surface.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.stats import stats
from repro.solver.health import (  # noqa: F401  (re-exports)
    NumericalFault,
    RecoveryPolicy,
    RecoveryTrace,
)


def probe_ok(env) -> jnp.ndarray:
    """Traceable scalar predicate: every buffer in ``env`` is all-finite."""
    ok = jnp.bool_(True)
    for v in env.values():
        ok = ok & jnp.all(jnp.isfinite(v))
    return ok


# compiled once per env tree/shape set: the eager per-op dispatch of the
# reduction chain is what would blow the 2% probe budget, not the FLOPs
probe_ok_compiled = jax.jit(probe_ok)


def probe(env) -> bool:
    """Host-side sentinel: True when every field buffer is finite.

    Counts itself in ``stats.health_probes``.  Works on device arrays
    (including sharded globals) and host numpy alike.
    """
    stats.health_probes += 1
    return bool(jax.device_get(probe_ok_compiled(dict(env))))


def poisoned_fields(env) -> list:
    """Names of the env fields holding non-finite values (host-side)."""
    return [
        k
        for k, v in env.items()
        if not np.all(np.isfinite(np.asarray(jax.device_get(v))))
    ]

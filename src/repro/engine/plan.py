"""The engine planner: one dispatch point for every execution path.

``plan(program, backend=..., mesh=..., time_tile=...)`` walks the recorded
program's op groups exactly once and schedules each as a :class:`Segment` —
either a *fused* segment (the :mod:`repro.compiler` pipeline built one
``pallas_call`` for the body, possibly time-tiled so k steps share one halo
exchange) or an *interpreter* segment (the shared roll-based step, used by
the ``numpy``/``jit`` backends and as the logged fallback for bodies that do
not lower).  :func:`repro.engine.executor.execute` then runs the plan on a
single device or inside ``shard_map`` — ``WFAInterface.make``,
``core.halo.run_sharded`` and the :mod:`repro.solver` step builders all
dispatch through here, so backend policy lives in exactly one place.

Time-tile selection: an explicit ``time_tile=k`` is honoured up to the
legality bounds of :func:`repro.compiler.ir.tile_group` (halo depth ``k·h``
must fit the brick, ``k`` the trip count) and clamped with a logged reason
otherwise; ``time_tile=None`` auto-picks the largest power-of-two divisor of
the trip count whose tiled halo stays small next to the brick
(:func:`repro.compiler.ir.auto_tile`), so auto-tiled runs never need a
remainder kernel.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, List, Optional, Tuple

from repro.compiler import LoweringError, auto_tile, lower_group, tile_group
from repro.compiler.codegen import compile_group, compile_group_sharded, try_compile
from repro.core.program import Program, _group_ops, _interp_step
from repro.engine.options import UNSET, RunOptions, resolve_options
from repro.engine.stats import stats

log = logging.getLogger("repro.engine")

#: user-facing backends accepted by plan() (``shard_map`` is ``jit`` + mesh)
BACKENDS = ("numpy", "jit", "shard_map", "pallas")


@dataclasses.dataclass
class Segment:
    """One scheduled op group: the loop, its ops, and the compiled step(s).

    ``step`` advances ``time_tile`` logical steps per call; ``step_rem``
    (untiled) covers the ``n % k`` remainder when the tile factor does not
    divide the trip count.  ``numpy`` plans carry no compiled steps — the
    executor interprets ``ops`` eagerly.
    """

    loop: Optional[object]
    ops: Tuple
    kind: str  # "fused" | "interp" | "eager"
    step: Optional[Callable] = None
    step_rem: Optional[Callable] = None
    time_tile: int = 1
    halo: int = 0
    reason: str = ""  # fallback / clamp explanation, "" when none
    #: boundary shell launches per tile when the segment runs the
    #: interior/boundary overlap split (0 = monolithic fused launch)
    split: int = 0

    @property
    def n_steps(self) -> int:
        return self.loop.n if self.loop is not None else 1


@dataclasses.dataclass
class ExecutionPlan:
    """Scheduled execution of one recorded program.

    ``layout`` is the halo-resident field layout the executor runs under
    (see :mod:`repro.engine.layout`): fused segments step on buffers padded
    once to the plan-wide margin ``layout.pad`` (= max ``k·h`` over the
    fused segments), with enter/exit conversions only at the program
    boundaries.  ``layout.pad == 0`` (interpreter plans, halo-free bodies,
    or ``resident=False``) degrades to the repacking path.
    """

    program: Program
    backend: str  # normalized: "numpy" | "jit" | "pallas"
    mesh: Optional[object]
    segments: List[Segment]
    layout: "HaloLayout" = None
    batch: int = 1  # leading ensemble axis every env buffer carries
    #: built for reverse-mode AD: runners must not donate entry buffers
    #: (they become VJP residuals) and the plan skips the in-place
    #: halo-resident layout — see RunOptions.differentiable
    differentiable: bool = False

    @property
    def mesh_ctx(self) -> Optional[Tuple[int, int, str, str]]:
        return _mesh_ctx(self.mesh)


def _mesh_ctx(mesh) -> Optional[Tuple[int, int, str, str]]:
    """(mx, my, ax_x, ax_y) for the brick decomposition, None off-mesh."""
    if mesh is None:
        return None
    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    return mesh.shape[ax_x], mesh.shape[ax_y], ax_x, ax_y


def compile_body(
    ops,
    loop,
    shapes,
    dtypes,
    backend: str,
    *,
    mesh_ctx: Optional[Tuple[int, int, str, str]] = None,
    time_tile: int = 1,
    group=None,
    resident: int = 0,
    batch: int = 1,
    overlap: bool = False,
) -> Tuple[Callable, bool]:
    """Build one body application ``env -> env`` — THE backend dispatch.

    Returns ``(step, fused)``.  ``backend="pallas"`` routes through the
    compiler (fused kernel, ``time_tile`` sub-steps per call, interpreter
    fallback on :class:`LoweringError` counted in ``repro.compiler.stats``);
    ``backend="jit"`` returns the shared roll-interpreter step.  With
    ``mesh_ctx`` the step operates on per-device bricks inside ``shard_map``
    (ppermute halo exchange); without, on the global array.  Explicit
    program execution, ``run_sharded`` and the solver's operator/rhs
    applications all obtain their steps here.

    ``resident=K`` (fused paths only) makes the step operate on the
    halo-resident layout of :mod:`repro.engine.layout`: env buffers carry a
    standing margin ``K >= time_tile·h``, refreshed in place per launch,
    with kernel outputs aliased into the same buffers.  Interpreter steps
    ignore it (the executor converts at segment boundaries).

    ``batch=B`` builds an ensemble step over ``(B, ...)``-stacked env
    buffers: fused kernels are vmapped over the leading axis below the
    refresh/barrier (see :func:`repro.compiler.codegen.compile_group`), and
    interpreter steps are vmapped whole — every jax primitive they use
    (rolls, where, dynamic updates, ppermute) carries a batching rule.

    ``overlap=True`` (fused resident paths) requests the interior/boundary
    kernel split so the margin exchange travels concurrently with the
    interior launch; illegal splits silently keep the monolithic kernel.
    """
    stats.bodies_compiled += 1
    if backend == "pallas":
        from repro.engine.hooks import fire_compile_hook
        from repro.kernels.ops import _interpret

        if mesh_ctx is None:

            def fn():
                # the hook can raise LoweringError — the injectable stand-in
                # for a real Mosaic compile failure; try_compile catches it
                # into the counted, logged interpreter fallback
                fire_compile_hook(getattr(loop, "name", None))
                return compile_group(
                    ops,
                    shapes,
                    dtypes,
                    interpret=_interpret(),
                    time_tile=time_tile,
                    group=group,
                    resident=resident,
                    batch=batch,
                    overlap=overlap,
                )

        else:
            mx, my, ax_x, ax_y = mesh_ctx

            def fn():
                fire_compile_hook(getattr(loop, "name", None))
                return compile_group_sharded(
                    ops,
                    shapes,
                    dtypes,
                    mesh_xy=(mx, my),
                    axis_names=(ax_x, ax_y),
                    interpret=_interpret(),
                    time_tile=time_tile,
                    group=group,
                    resident=resident,
                    batch=batch,
                    overlap=overlap,
                )

        step = try_compile(fn, loop)
        if step is not None:
            return step, True
    elif backend != "jit":
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if mesh_ctx is None:
        base = _interp_step(ops)
    else:
        from repro.core.halo import interp_step_sharded

        mx, my, ax_x, ax_y = mesh_ctx
        base = interp_step_sharded(ops, ax_x, ax_y, mx, my)
    if batch > 1:
        import jax

        return (lambda env: jax.vmap(base)(dict(env))), False
    return base, False


@dataclasses.dataclass
class LevelSegment:
    """One multigrid level's scheduled bodies and transfers.

    The multi-level analogue of :class:`Segment`: ``smooth`` and ``resid``
    are compiled body applications (``env -> env``, fused Pallas kernel or
    roll interpreter — the same :func:`compile_body` dispatch as every other
    path), ``restrict``/``prolong`` move arrays to/from the next-coarser
    level (``None`` on the coarsest).  ``diag`` is the level operator's
    constant diagonal, which the smoother and coarse solve divide by.
    """

    level: int
    shape: Tuple[int, int, int]
    smooth: Callable
    resid: Callable
    smooth_fused: bool
    resid_fused: bool
    diag: float
    restrict: Optional[Callable] = None
    prolong: Optional[Callable] = None


def plan_mg_levels(bodies, backend: str, dtype) -> List[LevelSegment]:
    """Schedule one multigrid hierarchy: every level body through the
    engine's single dispatch point, every transfer through the kernel cache.

    ``bodies`` is finest-first; each entry is a dict with ``shape``,
    ``diag`` and two recorded bodies ``smooth``/``resid`` as ``(ops,
    shapes, dtypes)`` triples (see :mod:`repro.solver.multigrid`, which
    records them per level).  ``backend="pallas"`` lowers each body to one
    fused kernel — one cache entry per level — and the transfers to the
    restriction/prolongation kernels of :mod:`repro.kernels.transfer`;
    ``backend="jit"`` uses the roll interpreter and the pure-jnp transfer
    references.  Per-level outcomes land in ``stats.mg_level_log``.
    """
    from repro.compiler.codegen import compile_transfer
    from repro.kernels.transfer import prolong_ref, restrict_ref

    segments: List[LevelSegment] = []
    log_entries = []
    for lvl, body in enumerate(bodies):
        shape = tuple(body["shape"])
        s_ops, s_shapes, s_dtypes = body["smooth"]
        r_ops, r_shapes, r_dtypes = body["resid"]
        smooth, s_fused = compile_body(s_ops, None, s_shapes, s_dtypes, backend)
        resid, r_fused = compile_body(r_ops, None, r_shapes, r_dtypes, backend)
        seg = LevelSegment(
            level=lvl,
            shape=shape,
            smooth=smooth,
            resid=resid,
            smooth_fused=s_fused,
            resid_fused=r_fused,
            diag=float(body["diag"]),
        )
        if lvl + 1 < len(bodies):
            coarse = tuple(bodies[lvl + 1]["shape"])
            use_kernels = False
            if backend == "pallas":
                from repro.kernels.ops import _interpret

                # Mosaic restricts the transfer kernels' interleave reshapes
                # (see kernels/transfer.py); on real TPUs fall back to the
                # jnp references — the documented degradation path — instead
                # of crashing at first trace.
                use_kernels = _interpret()
            if use_kernels:
                seg.restrict = compile_transfer(
                    "restrict", shape, coarse, dtype, interpret=True
                )
                seg.prolong = compile_transfer(
                    "prolong", shape, coarse, dtype, interpret=True
                )
            else:
                seg.restrict = restrict_ref
                seg.prolong = lambda c, n=shape: prolong_ref(c, n)
        segments.append(seg)
        log_entries.append((shape, s_fused, r_fused))
        stats.mg_levels_built += 1
    stats.mg_hierarchies += 1
    stats.mg_level_log = tuple(log_entries)
    return segments


def _brick_xy(program: Program, mesh_ctx, group) -> Tuple[int, int]:
    """Per-device brick extent of the fields ``group`` actually touches
    (the whole grid on a single device).  Anchored on the group's first
    written field — the same convention ``codegen._field_specs`` validates
    every fused field against — so tile legality is judged on the extent
    the kernel will really run over, not whichever field the program
    happened to declare first."""
    nx, ny, _ = program.fields[group.fields_written()[0]].shape
    if mesh_ctx is None:
        return nx, ny
    mx, my, _, _ = mesh_ctx
    return nx // mx, ny // my


def _pick_tile(
    group, loop, requested: Optional[int], brick_xy, cost=None, nz=None
) -> Tuple[int, str]:
    """Resolve the tile factor for one fused loop body: (k, clamp_reason).

    ``cost`` is this body's calibrated :class:`~repro.core.perfmodel.
    MeasuredCost` entry when one exists: auto selection then minimizes the
    measured model over the legal candidates instead of applying the static
    rule (``k = 1`` always admissible, so tiling cannot lose by
    construction — see :func:`repro.compiler.ir.auto_tile`).
    """
    n = loop.n if loop is not None else 1
    if n <= 1:
        return 1, ""
    if requested is None:
        return auto_tile(group, brick_xy, n, cost=cost, nz=nz), ""
    k = max(1, int(requested))
    try:
        tile_group(group, k, brick_xy=brick_xy, n_steps=n)
        return k, ""
    except LoweringError as e:
        kmax = n
        if group.halo > 0:
            kmax = min(kmax, min(brick_xy) // group.halo)
        k_ok = max(1, min(k, kmax))
        reason = f"time_tile={requested} clamped to k={k_ok}: {e}"
        log.warning("%s", reason)
        return k_ok, reason


def plan(
    program: Program,
    options=None,
    *,
    backend=UNSET,
    mesh=UNSET,
    time_tile=UNSET,
    resident=UNSET,
) -> ExecutionPlan:
    """Schedule a recorded program: group ops once, pick a strategy per body.

    Execution policy arrives as one frozen
    :class:`~repro.engine.options.RunOptions` bundle (a bare string is
    accepted as the backend, preserving the historical ``plan(program,
    "pallas")`` spelling).  The legacy ``backend=`` / ``mesh=`` /
    ``time_tile=`` / ``resident=`` keywords remain as deprecation shims that
    warn once per keyword and forward into the bundle.  ``options.batch=B``
    plans for ``(B, ...)``-stacked ensemble buffers: every compiled step is
    batch-aware and the plan records ``batch`` for the executor.

    Planning is two-pass so fields can be laid out *halo-resident*: pass one
    lowers every loop body and picks its tile factor, which fixes the
    run-wide margin ``K = max k·h``; pass two compiles each body against
    that layout (margin refresh in place + aliased kernel outputs — see
    :mod:`repro.engine.layout`).  ``resident=False`` forces the legacy
    repack-per-launch steps (the bitwise reference the residency tests
    compare against).
    """
    from repro.engine.layout import HaloLayout

    options = resolve_options(
        options,
        "engine.plan",
        backend=backend,
        mesh=mesh,
        time_tile=time_tile,
        resident=resident,
    )
    backend = options.resolved_backend("jit")
    mesh = options.mesh
    time_tile = options.time_tile
    resident = options.resident
    batch = options.batch
    overlap = options.overlap

    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "shard_map":
        backend = "jit"
        if mesh is None:
            from repro.core.halo import default_mesh2d

            mesh = default_mesh2d()
    if backend == "numpy":
        mesh = None  # the validation backend is eager + single-host
    mesh_ctx = _mesh_ctx(mesh)

    shapes = {n: f.shape for n, f in program.fields.items()}
    dtypes = {n: f.dtype for n, f in program.fields.items()}
    if mesh_ctx is not None:
        mx, my, _, _ = mesh_ctx
        for n, (nx, ny, _) in shapes.items():
            if nx % mx or ny % my:
                raise ValueError(
                    f"field {n} shape ({nx},{ny}) not divisible by mesh ({mx},{my})"
                )

    # pass one: lower + pick tile factors; the margin K is their max window
    scheduled = []
    for loop, ops in _group_ops(program):
        group = None
        k, reason = 1, ""
        cost = None
        if backend == "pallas":
            try:
                group = lower_group(ops)
            except LoweringError:
                group = None  # compile_body repeats the lowering to log/count
            if group is not None:
                from repro.core import perfmodel

                name0 = group.fields_written()[0]
                cost = perfmodel.cost_model.lookup(
                    group, shapes[name0][2], dtypes[name0]
                )
                if cost is not None:
                    stats.cost_model_hits += 1
                k, reason = _pick_tile(
                    group,
                    loop,
                    time_tile,
                    _brick_xy(program, mesh_ctx, group),
                    cost=cost,
                    nz=shapes[name0][2],
                )
        elif backend != "numpy" and time_tile is not None and time_tile != 1:
            # an explicit tile request on an interpreter backend is dropped,
            # not honoured — say so instead of silently running untiled
            reason = (
                f"time_tile={time_tile} ignored: backend {backend!r} has no "
                "fused kernels to tile (use backend='pallas')"
            )
            log.warning("%s", reason)
        scheduled.append((loop, ops, group, k, reason, cost))
    pad = 0
    if resident and backend == "pallas" and not options.differentiable:
        # a differentiable plan keeps the repacking steps: the resident
        # protocol's in-place aliased outputs and margin rewrites are
        # exactly the buffer reuse a reverse pass cannot tolerate — saved
        # residuals must survive the forward sweep
        from repro.kernels.ops import _interpret

        # In-place outputs are only safe where the kernel evaluates blocks
        # functionally (interpret mode, this container's correctness path):
        # on Mosaic the grid runs sequentially over an aliased HBM buffer,
        # so a block's halo window would read the in-place outputs of the
        # neighbouring blocks already executed in the same launch (a
        # read-after-write Gauss–Seidel contamination).  Until the resident
        # path double-buffers block outputs on TPU, Mosaic plans keep the
        # legacy repacking steps — the same documented degradation rule as
        # the multigrid transfer kernels (engine.plan_mg_levels).
        if _interpret():
            pad = max(
                (k * g.halo for _, _, g, k, _, _ in scheduled if g is not None),
                default=0,
            )
    layout = HaloLayout(pad=pad, shapes=shapes)

    # pass two: compile each body against the layout
    segments: List[Segment] = []
    for loop, ops, group, k, reason, cost in scheduled:
        if backend == "numpy":
            segments.append(Segment(loop=loop, ops=tuple(ops), kind="eager"))
            continue
        # overlap decision: split the launch only where legal (resident
        # layout, nonempty interior at depth k·h) and wanted — forced by
        # overlap=True, or, on "auto", predicted faster by this body's
        # calibrated cost-model entry (no entry → keep today's schedule)
        use_split = 0
        if group is not None and pad > 0 and group.halo > 0:
            from repro.compiler.ir import split_regions

            sp = split_regions(group, k, _brick_xy(program, mesh_ctx, group))
            if sp is not None and overlap is not False:
                if overlap is True:
                    use_split = len(sp.shells)
                elif cost is not None:
                    from repro.core.perfmodel import predict_step_us

                    name0 = group.fields_written()[0]
                    bxy = _brick_xy(program, mesh_ctx, group)
                    nz = shapes[name0][2]
                    t_fused = predict_step_us(cost, bxy, nz, group.halo, k)
                    t_split = predict_step_us(cost, bxy, nz, group.halo, k, split=True)
                    if t_split < t_fused:
                        use_split = len(sp.shells)
        step, fused = compile_body(
            ops,
            loop,
            shapes,
            dtypes,
            backend,
            mesh_ctx=mesh_ctx,
            time_tile=k,
            group=group,
            resident=pad,
            batch=batch,
            overlap=bool(use_split),
        )
        if not fused:
            k = 1
            use_split = 0
        seg = Segment(
            loop=loop,
            ops=tuple(ops),
            kind="fused" if fused else "interp",
            step=step,
            time_tile=k,
            halo=group.halo if group is not None else 0,
            reason=reason,
            split=use_split,
        )
        if fused and k > 1 and seg.n_steps % k:
            seg.step_rem, _ = compile_body(
                ops,
                loop,
                shapes,
                dtypes,
                backend,
                mesh_ctx=mesh_ctx,
                time_tile=1,
                group=group,
                resident=pad,
                batch=batch,
                overlap=bool(use_split),
            )
        if reason:
            stats.note_tile_reason(reason)
        if fused:
            stats.segments_fused += 1
        else:
            stats.segments_interp += 1
        segments.append(seg)

    stats.plans_built += 1
    stats.max_time_tile = max(
        stats.max_time_tile, max((s.time_tile for s in segments), default=1)
    )
    return ExecutionPlan(
        program=program,
        backend=backend,
        mesh=mesh,
        segments=segments,
        layout=layout,
        batch=batch,
        differentiable=options.differentiable,
    )

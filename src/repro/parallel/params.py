"""Parameter logical axes, resolved by leaf name (the model is ours, so the
name table is exhaustive; anything unknown is replicated and reported).

``param_specs_for(cfg, params_like, rules)`` → pytree of PartitionSpec.
``cache_specs_for(cfg, cache_like, rules)`` → same for the decode cache.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.parallel.sharding import ShardingRules

# leaf name → logical axes (without the stacked-layer leading axis)
_NAME_AXES = {
    # attention
    "wq": ("embed", "heads_flat"), "wk": ("embed", "heads_flat"),
    "wv": ("embed", "heads_flat"), "wo": ("heads_flat", "embed"),
    "q_norm": (None,), "k_norm": (None,),
    # mlp
    "up": ("embed", "mlp"), "gate": ("embed", "mlp"),
    "down": ("mlp", "embed"),
    # moe
    "router": ("embed", None),
    "w_gate": ("experts", "embed", "expert_mlp"),
    "w_up": ("experts", "embed", "expert_mlp"),
    "w_down": ("experts", "expert_mlp", "embed"),
    # mla
    "wq_a": ("embed", "q_lora"), "wq_b": ("q_lora", "heads_flat"),
    "wkv_a": ("embed", None), "wkv_b": ("kv_lora", "heads_flat"),
    # mamba2
    "in_proj": ("embed", "conv_dim"), "out_proj": ("ssm_inner", "embed"),
    "conv_w": (None, "conv_dim"), "conv_b": ("conv_dim",),
    "dt_bias": (None,), "a_log": (None,), "d_skip": (None,),
    # rwkv6
    "wr": ("embed", "heads_flat"), "wg": ("embed", "heads_flat"),
    "mu": (None, None), "ts_a": ("embed", None), "ts_b": (None, None, None),
    "w0": (None,), "w_a": ("embed", None), "w_b": (None, None),
    "u": (None,), "mu_k": (None,), "mu_r": (None,),
    # norms / embeddings / heads
    "scale": (None,),
    "embed": ("vocab", "embed"), "lm_head": ("embed", "vocab"),
    "out": (None, "embed"),       # zamba shared out-proj (2D → D)
}

# extra logical axes used only here
_EXTRA_RULES = {
    "heads_flat": "model",
    "ssm_inner": "model",
}


def rules_for(cfg, mesh, overrides: Optional[dict] = None) -> ShardingRules:
    """Build the rule table for a config (applying its overrides)."""
    table = dict(_EXTRA_RULES)
    table.update(dict(cfg.sharding_overrides))
    if overrides:
        table.update(overrides)
    return ShardingRules(mesh, table)


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
        if hasattr(p, "name"):
            return str(p.name)
    return ""


def param_specs_for(cfg, params_like, rules: ShardingRules):
    """PartitionSpec pytree congruent with ``params_like``."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    specs = []
    for path, leaf in paths_leaves:
        name = _leaf_name(path)
        axes = _NAME_AXES.get(name)
        shape = tuple(leaf.shape)
        if axes is None:
            specs.append(rules.spec([None] * len(shape), shape))
            continue
        if len(axes) < len(shape):     # stacked layers / codebooks prefix
            axes = (None,) * (len(shape) - len(axes)) + tuple(axes)
        specs.append(rules.spec(axes, shape))
    return treedef.unflatten(specs)


# cache leaf axes by (named-tuple field) name
_CACHE_AXES = {
    "k": ("cache_batch", "cache_seq", "cache_heads", None),
    "v": ("cache_batch", "cache_seq", "cache_heads", None),
    "c_kv": ("cache_batch", "cache_seq", None),
    "k_rope": ("cache_batch", "cache_seq", None),
    "tm_shift": ("cache_batch", None),
    "cm_shift": ("cache_batch", None),
    "wkv": ("cache_batch", "rwkv_heads", None, None),
    "conv": ("cache_batch", None, "conv_dim"),
    "ssm": ("cache_batch", "ssm_heads", None, None),
}


def cache_specs_for(cfg, cache_like, rules: ShardingRules):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    specs = []
    for path, leaf in paths_leaves:
        name = _leaf_name(path)
        axes = _CACHE_AXES.get(name)
        shape = tuple(leaf.shape)
        if axes is None:
            specs.append(rules.spec([None] * len(shape), shape))
            continue
        if len(axes) < len(shape):     # leading stacked-layer dim
            axes = (None,) * (len(shape) - len(axes)) + tuple(axes)
        specs.append(rules.spec(axes, shape))
    return treedef.unflatten(specs)

"""repro.parallel — logical-axis sharding rules and mesh context."""
from repro.parallel.sharding import (ShardingRules, default_rules, pshard,
                                     use_sharding, param_specs, spec_for)

__all__ = ["ShardingRules", "default_rules", "pshard", "use_sharding",
           "param_specs", "spec_for"]

"""Logical-axis sharding (MaxText-style rules → ``PartitionSpec``).

Model code annotates tensors with *logical* axis names
(``pshard(x, 'batch', 'seq', 'embed')``); a :class:`ShardingRules` table maps
logical names to physical mesh axes.  Outside a mesh context the annotation
is a no-op, so the same model code runs on one CPU device, in unit tests and
on a 512-chip dry-run unchanged.

Hillclimbs swap rule tables, not model code — e.g. remapping ``cache_seq``
from ``None`` to ``'model'`` turns replicated-KV decode into sequence-sharded
flash-decode (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_CTX = threading.local()


def default_rules() -> Dict[str, MeshAxes]:
    """Baseline DP+TP mapping for the (pod, data, model) production mesh."""
    return {
        "batch": ("pod", "data"),     # DP over pod × data
        "seq": None,
        "embed": None,                # activations replicated over model
        "heads": "model",             # TP: attention heads
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",               # TP: ffn hidden
        "vocab": "model",             # TP: embedding/lm-head vocab shard
        "experts": "model",           # EP: routed experts
        "expert_mlp": None,           # (mixtral remaps this to 'model')
        "q_lora": None,
        "kv_lora": None,
        "cache_batch": ("pod", "data"),
        # decode caches shard the SEQUENCE over the model axis (distributed
        # flash-decode: GSPMD turns the softmax/context sums into small
        # all-reduces).  Head-sharding fails divisibility for most GQA
        # configs (kv_heads < 16) and replicates the cache 16× — measured
        # 25–60× worse on qwen3 decode_32k; see EXPERIMENTS.md §Perf.
        "cache_seq": "model",
        "cache_heads": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_dim": "model",
        "rwkv_heads": "model",
        "layers": None,               # stacked-layer leading axis
        "stage": None,                # pipeline stages (PP rule set)
    }


class ShardingRules:
    def __init__(self, mesh, rules: Optional[Dict[str, MeshAxes]] = None):
        self.mesh = mesh
        self.rules = dict(default_rules())
        if rules:
            self.rules.update(rules)
        self._axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def mesh_axes(self, logical: Optional[str], dim_size: Optional[int] = None
                  ) -> MeshAxes:
        if logical is None:
            return None
        ax = self.rules.get(logical)
        if ax is None:
            return None
        # keep only axes present in this mesh (single-pod meshes have no
        # 'pod' axis; the same rule table serves both)
        axes = tuple(a for a in ((ax,) if isinstance(ax, str) else ax)
                     if a in self._axis_sizes)
        if not axes:
            return None
        # drop the mapping if the dimension does not divide the mesh axis —
        # e.g. kv_heads=8 on model=16 falls back to replication (a baseline
        # inefficiency the roofline table surfaces).
        if dim_size is not None:
            total = 1
            for a in axes:
                total *= self._axis_sizes[a]
            if dim_size % total:
                return None
        return axes[0] if len(axes) == 1 else axes

    def spec(self, logical_axes, shape=None) -> P:
        parts = []
        for i, name in enumerate(logical_axes):
            size = None if shape is None else shape[i]
            parts.append(self.mesh_axes(name, size))
        return P(*parts)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_CTX, "rules", None)


@contextlib.contextmanager
def use_sharding(rules: Optional[ShardingRules]):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def pshard(x, *logical_axes):
    """Annotate ``x`` with logical axes; no-op outside a mesh context."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def spec_for(rules: Optional[ShardingRules], logical_axes, shape=None):
    if rules is None:
        return P()
    return rules.spec(logical_axes, shape)


def param_specs(params_axes, rules: ShardingRules):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpec."""
    return jax.tree.map(
        lambda axes: rules.spec(axes.axes, axes.shape),
        params_axes,
        is_leaf=lambda v: isinstance(v, AxisInfo))


class AxisInfo:
    """Leaf marker: logical axes + shape for one parameter."""

    def __init__(self, axes, shape):
        self.axes = tuple(axes)
        self.shape = tuple(shape)

    def __repr__(self):
        return f"AxisInfo({self.axes}, {self.shape})"

"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

from repro.core.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    Single pod: 16×16 = 256 chips, axes (data, model).
    Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the `pod`
    axis carries pure data parallelism over the cross-pod (DCN-class) links.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh2d(data: int, model: int, *, pod: int = 0):
    """Arbitrary-size mesh with the production axis names (tests use 2×2)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))

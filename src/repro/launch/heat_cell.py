"""The paper's own workload (heat3d) as a production-mesh dry-run cell.

Lowers the brick-decomposed FTCS / CG steps at the 16×16 (and 2×16×16) mesh
for a ~2.1e9-cell grid (the paper weak-scales to 2.85e9) and extracts the
same three roofline terms as the LM cells.  Per-variant records drive the
paper-side §Perf hillclimb:

    explicit: baseline | overlap | wide-halo k | pallas kernel
    implicit: cg (2 psums/iter) | pipecg (1 fused psum) | chebyshev (0)

Note on loop accounting: ``fori_loop``/``while_loop`` bodies are counted
once by cost_analysis, which is exactly one time step (explicit) or one
inner iteration (implicit) — the paper's own metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.heat3d import HeatConfig
from repro.core.explicit import make_sharded_ftcs
from repro.core.implicit import make_sharded_iteration
from repro.launch import roofline

PROD_GRID = HeatConfig(nx=2048, ny=2048, nz=512)   # 2.1e9 cells, fp32


def _lower_and_analyze(step, sharding, shape, mesh, exchange_every=1):
    """Roofline record for one compiled heat step.

    ``exchange_every=k`` (wide halos): the halo exchange sits outside the
    k-step inner loop, so ONLY the collective terms are divided by k
    (loop bodies are already counted once = one time step of compute).
    Adds the latency floor term (scalar psums are diameter-bound, Eq. 16).
    """
    sds = jax.ShapeDtypeStruct(shape, jnp.float32, sharding=sharding)
    return _analyze_compiled(step.lower(sds).compile(), mesh,
                             exchange_every=exchange_every)


def _analyze_compiled(compiled, mesh, exchange_every=1):
    # fp32 peak on v5e ≈ half bf16 (the paper runs single precision)
    rec = roofline.analyze(compiled, peak_flops=roofline.PEAK_BF16 / 2)
    mx, my = list(mesh.shape.values())[-2:]
    coll = rec.pop("collective_breakdown")
    rec["collective_bytes_per_chip"] /= exchange_every
    rec["t_collective"] /= exchange_every
    rec["t_latency"] = roofline.collective_latency(coll, mx, my) \
        / exchange_every
    rec["n_collectives"] = coll["count"]
    rec["t_total"] = (max(rec["t_compute"], rec["t_memory"])
                      + rec["t_collective"] + rec["t_latency"])
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"] + rec["t_latency"]}
    rec["bound"] = max(terms, key=terms.get)
    ma = compiled.memory_analysis()
    rec["total_bytes_per_device"] = (
        getattr(ma, "argument_size_in_bytes", 0)
        - getattr(ma, "alias_size_in_bytes", 0)
        + getattr(ma, "output_size_in_bytes", 0)
        + getattr(ma, "temp_size_in_bytes", 0))
    return rec


def run_heat_cells(mesh, cfg: HeatConfig = PROD_GRID, variants=None):
    """Returns {variant: roofline record} for the heat workload on mesh."""
    shape = (cfg.nx, cfg.ny, cfg.nz)
    out = {}
    ex_variants = {
        "explicit_baseline": dict(),
        "explicit_overlap": dict(overlap=True),
        "explicit_wide_halo4": dict(halo_depth=4),
        "explicit_kernel": dict(use_kernel=True),
        "explicit_kernel_planes": dict(use_kernel="planes"),
    }
    if variants:
        ex_variants = {k: v for k, v in ex_variants.items() if k in variants}
    for name, kw in ex_variants.items():
        step, sharding = make_sharded_ftcs(mesh, shape, cfg.omega,
                                           steps_per_call=1, **kw)
        out[name] = _lower_and_analyze(
            step, sharding, shape, mesh,
            exchange_every=kw.get("halo_depth", 1))

    im_variants = ["cg", "pipecg", "chebyshev"]
    if variants:
        im_variants = [m for m in im_variants
                       if f"implicit_{m}" in variants]
    for method in im_variants:
        for kernel in ([False, True] if method == "cg" else [False]):
            step, state_sds = make_sharded_iteration(
                mesh, shape, cfg.omega, method=method, use_kernel=kernel)
            name = f"implicit_{method}" + ("_kernel" if kernel else "")
            out[name] = _analyze_compiled(step.lower(state_sds).compile(),
                                          mesh)
    return out


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    recs = run_heat_cells(mesh)
    out_f = open(args.out, "a") if args.out else None
    for name, rec in recs.items():
        rec = dict(rec, variant=name, mesh=str(dict(mesh.shape)),
                   grid=f"{PROD_GRID.nx}x{PROD_GRID.ny}x{PROD_GRID.nz}")
        print(json.dumps(rec))
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

No device allocation happens here: params/opt/cache shapes come from
``jax.eval_shape`` over the real initializers, inputs are synthesized
SDS, and shardings are derived from the logical-axis rules.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES, ShapeCfg
from repro.launch import steps as steps_mod
from repro.models import model as M
from repro.parallel.params import (cache_specs_for, param_specs_for,
                                   rules_for)


def _sds(tree_shape):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree_shape)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def batch_spec(cfg, shape: ShapeCfg, rules):
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s) if cfg.n_codebooks == 1 else (b, s, cfg.n_codebooks)
    sds = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
           "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    spec = rules.spec(("batch", "seq") + (None,) * (len(tok_shape) - 2),
                      tok_shape)
    return sds, {"tokens": spec, "labels": spec}


def cell_specs(arch: str, shape_name: str, mesh,
               overrides: dict | None = None,
               cfg=None) -> Dict[str, Any]:
    """Everything needed to jit + lower one dry-run cell.

    Returns {fn, args (SDS), in_shardings, donate_argnums, rules, cfg}.
    """
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for(cfg, mesh, overrides)

    params_shape = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs_for(cfg, params_shape, rules)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda: steps_mod.make_opt_state(params_shape))
        # moments share the param specs + ZeRO data-axis extension
        o_specs = _opt_specs(p_specs, opt_shape, mesh)
        b_sds, b_specs = batch_spec(cfg, shape, rules)
        fn = steps_mod.make_train_step(cfg)
        return dict(
            fn=fn,
            args=(_sds(params_shape), _sds(opt_shape), b_sds),
            in_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                          _named(mesh, b_specs)),
            out_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                           None),
            donate_argnums=(0, 1), rules=rules, cfg=cfg, shape=shape)

    if shape.kind == "prefill":
        b_sds, b_specs = batch_spec(cfg, shape, rules)
        fn = steps_mod.make_prefill_step(cfg)
        return dict(
            fn=fn,
            args=(_sds(params_shape), b_sds["tokens"]),
            in_shardings=(_named(mesh, p_specs),
                          NamedSharding(mesh, b_specs["tokens"])),
            out_shardings=None,
            donate_argnums=(), rules=rules, cfg=cfg, shape=shape)

    # decode: one new token against a seq_len cache
    b, s = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    c_specs = cache_specs_for(cfg, cache_shape, rules)
    tok_shape = (b, 1) if cfg.n_codebooks == 1 else (b, 1, cfg.n_codebooks)
    tok_sds = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    tok_spec = rules.spec(
        ("cache_batch",) + (None,) * (len(tok_shape) - 1), tok_shape)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = steps_mod.make_decode_step(cfg)
    return dict(
        fn=fn,
        args=(_sds(params_shape), _sds(cache_shape), tok_sds, pos_sds),
        in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, P())),
        out_shardings=(None, _named(mesh, c_specs)),
        donate_argnums=(1,), rules=rules, cfg=cfg, shape=shape)


def _zero_extend(spec: P, shape, mesh) -> P:
    """ZeRO-style optimizer-state sharding: additionally shard one free dim
    of each moment over the data axes.  Moments are touched once per step
    (the AdamW update is elementwise), so the extra layout costs nothing in
    the step and divides optimizer memory by the DP degree — without it,
    deepseek-v2 fp32 moments are 121 GB/chip (measured) and cannot deploy.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp_axes:
        return spec
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for p_ in parts:
        for a in ((p_,) if isinstance(p_, str) else (p_ or ())):
            used.add(a)
    if any(a in used for a in dp_axes):
        return spec
    # largest free, divisible dim gets the data axes
    cands = [(shape[i], i) for i, p_ in enumerate(parts)
             if p_ is None and shape[i] % dp == 0]
    if not cands:
        return spec
    _, i = max(cands)
    parts[i] = dp_axes[0] if len(dp_axes) == 1 else dp_axes
    return P(*parts)


def _opt_specs(p_specs, opt_shape, mesh=None):
    """Adam state specs: moments mirror params + ZeRO data-axis extension;
    scalar step replicated."""
    from repro.optim.adamw import AdamWState
    if mesh is not None:
        m_specs = jax.tree.map(
            lambda s, l: _zero_extend(s, l.shape, mesh),
            p_specs, opt_shape.m if isinstance(opt_shape, AdamWState)
            else opt_shape["adam"].m,
            is_leaf=lambda s: isinstance(s, P))
    else:
        m_specs = p_specs
    if isinstance(opt_shape, AdamWState):
        return AdamWState(P(), m_specs, m_specs)
    return {"adam": AdamWState(P(), m_specs, m_specs),
            "residual": m_specs}

"""Roofline extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all per-chip per-step:

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = Σ collective_result_bytes / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  Collective bytes are NOT in cost_analysis: we parse the
post-SPMD HLO text and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) gives the useful-compute ratio,
catching remat recompute and padding waste.
"""
from __future__ import annotations

import re
from typing import Dict

import jax
import numpy as np

# -- hardware constants (TPU v5e, per brief) --------------------------------
PEAK_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DEF_RE = re.compile(r"^\s*(%[\w.\-]+) = ((?:\([^)]*\)|[^ ]+)) "
                     r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")

# ops whose line is bookkeeping, not a kernel launch
_SKIP_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "iota", "broadcast")


def fused_bytes_estimate(hlo_text: str) -> float:
    """HBM-byte estimate under kernel-granularity accounting.

    XLA groups arithmetic into ``fusion`` computations; a fusion's HBM
    traffic is its operands + its result (that is the definition of
    fusion).  We therefore charge operand+result bytes for every op in
    every *non-fusion* computation (ENTRY, while bodies, conditional
    branches) and skip fusion-internal lines; scalar reducer regions are
    skipped by the scalar filter naturally (bytes ≈ 0).
    """
    total = 0.0
    in_fusion_body = False
    sizes: Dict[str, int] = {}
    depth = 0
    for raw in hlo_text.splitlines():
        line = raw.strip()
        mc = _COMP_RE.match(line)
        if mc and depth == 0:
            name = mc.group(2)
            in_fusion_body = "fused_computation" in name
            sizes = {}
            depth = 1
            continue
        if line.startswith("}"):
            depth = max(0, depth - 1)
            continue
        if depth == 0 or in_fusion_body:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        nbytes = _shape_bytes(type_str)
        sizes[name] = nbytes
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _SKIP_OPS:
            continue
        total += nbytes                                       # write
        rest = line[line.index(opcode + "("):]
        head = rest.split(")", 1)[0]
        for ref in _OPERAND_RE.findall(head):
            total += sizes.get(ref, 0)                        # reads
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes + op counts of every collective in a
    partitioned HLO (counts drive the latency term: scalar all-reduces are
    diameter-latency-bound, the paper's 2(X+Y) story)."""
    out = {k: 0 for k in _COLLECTIVES}
    out.update({k + "_n": 0 for k in _COLLECTIVES})
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":      # avoid double counting async pairs
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out[m.group(2) + "_n"] += 1
        out["count"] += 1
    return out


def collective_latency(coll: Dict[str, int], mesh_x: int, mesh_y: int,
                       hop_lat: float = 1e-6) -> float:
    """Latency floor of the collective schedule on an (X, Y) ICI torus:
    permutes are single-hop; reductions traverse ~the mesh diameter both
    ways (the Eq. 16/17 ``2(X+Y)`` analogue)."""
    diam = 2 * (mesh_x + mesh_y)
    lat = coll.get("collective-permute_n", 0) * hop_lat
    for kind in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
        lat += coll.get(kind + "_n", 0) * diam * hop_lat
    return lat


def analyze(compiled, *, steps_per_call: int = 1,
            peak_flops: float = PEAK_BF16) -> Dict:
    """Roofline terms from one compiled executable (per chip, per step)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # one dict per partition on some backends
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) / steps_per_call
    mem_bytes = float(cost.get("bytes accessed", 0.0)) / steps_per_call
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    coll_total = sum(v for k, v in coll.items() if k != "count")
    coll_total /= steps_per_call
    fused = fused_bytes_estimate(hlo) / steps_per_call

    t_comp = flops / peak_flops
    t_mem = mem_bytes / HBM_BW             # brief-defined: HLO bytes accessed
    t_mem_fused = fused / HBM_BW           # kernel-granularity estimate
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bound = max(terms, key=terms.get)
    return {
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": mem_bytes,
        "hbm_fused_bytes_per_chip": fused,
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "t_compute": t_comp, "t_memory": t_mem,
        "t_memory_fused": t_mem_fused,
        "t_collective": t_coll,
        "t_total": max(t_comp, t_mem) + t_coll,
        "bound": bound,
    }


# ---------------------------------------------------------------------------
# calibrated per-step costs
#
# XLA's cost_analysis counts while-loop bodies ONCE regardless of trip count
# (verified empirically), so a scan-over-layers step under-reports FLOPs by
# ~L×.  Calibration: compile small FLAT variants (python-loop layers, 1 vs 2
# layers per segment kind) on the SAME mesh — the SPMD per-device program is
# layer-count-independent, so the per-layer body cost B_k extrapolates
# exactly:
#
#     metric(full) = f(all counts = 1) + Σ_kind (T_k − m_k) · B_k
#
# Microbatching needs no calibration dimension: the global token count is
# fixed, per-microbatch costs are linear in batch rows, so total step cost is
# microbatch-count-invariant; calibration variants run mb=1 (flat).
# ---------------------------------------------------------------------------

_METRICS = ("flops_per_chip", "hbm_bytes_per_chip",
            "hbm_fused_bytes_per_chip", "collective_bytes_per_chip")


def _variant_cfg(cfg, seg_counts, mb):
    import dataclasses
    segments = tuple((k, seg_counts.get(k, 1)) for k, _ in cfg.segments)
    return dataclasses.replace(
        cfg, segments=segments, n_layers=sum(c for _, c in segments),
        num_microbatches=mb, scan_layers=False)


def _compile_metrics(arch, shape_name, mesh, cfg, overrides):
    from repro.launch.specs import cell_specs
    from repro.parallel.sharding import use_sharding
    import jax as _jax
    spec = cell_specs(arch, shape_name, mesh, overrides, cfg=cfg)
    jitted = _jax.jit(spec["fn"], in_shardings=spec["in_shardings"],
                      out_shardings=spec["out_shardings"],
                      donate_argnums=spec["donate_argnums"])
    with use_sharding(spec["rules"]):
        compiled = jitted.lower(*spec["args"]).compile()
    return analyze(compiled)


def calibrated_terms(arch, shape_name, mesh, overrides=None, cfg=None):
    """Extrapolated per-chip (flops, bytes, collective) for the full cell."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    kinds = []
    m_k: Dict[str, int] = {}
    t_k: Dict[str, int] = {}
    for kind, count in cfg.segments:
        if kind not in m_k:
            kinds.append(kind)
            m_k[kind] = 0
            t_k[kind] = 0
        m_k[kind] += 1
        t_k[kind] += count

    f_a = _compile_metrics(arch, shape_name, mesh,
                           _variant_cfg(cfg, {}, 1), overrides)
    b_k = {}
    for kind in kinds:
        f_b = _compile_metrics(arch, shape_name, mesh,
                               _variant_cfg(cfg, {kind: 2}, 1), overrides)
        b_k[kind] = {m: max(0.0, (f_b[m] - f_a[m]) / m_k[kind])
                     for m in _METRICS}

    out = {}
    for m in _METRICS:
        out[m] = f_a[m] + sum((t_k[k] - m_k[k]) * b_k[k][m] for k in kinds)

    t_comp = out["flops_per_chip"] / PEAK_BF16
    t_mem = out["hbm_bytes_per_chip"] / HBM_BW
    t_mem_fused = out["hbm_fused_bytes_per_chip"] / HBM_BW
    t_coll = out["collective_bytes_per_chip"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    out.update({"t_compute": t_comp, "t_memory": t_mem,
                "t_memory_fused": t_mem_fused, "t_collective": t_coll,
                "t_total": max(t_comp, t_mem) + t_coll,
                "bound": max(terms, key=terms.get)})
    return out


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode), GLOBAL."""
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    return 2.0 * n_active * shape.global_batch      # decode: one token


def count_params(cfg) -> Dict[str, int]:
    """Total + active (MoE-discounted) parameter counts from eval_shape."""
    from repro.models import model as M
    shapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        names = [getattr(p, "key", "") for p in path]
        if any(str(n_) in ("w_gate", "w_up", "w_down") for n_ in names):
            routed += n
    active = total - routed
    if cfg.moe:
        active += routed * cfg.moe.top_k // cfg.moe.n_experts
    return {"total": total, "active": active}

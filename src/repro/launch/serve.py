"""Legacy LM demo: batched prefill + decode over the mesh.

This module predates the field-equation focus of the repo — it serves a
toy transformer, not the PDE stack, and is kept only as a sharding /
mesh-launch exercise (``examples/serve_lm.py`` smoke-tests it in CI).
The supported serving path for simulations is ``repro.service``::

    PYTHONPATH=src python -m repro.service --smoke

See ``docs/service.md``.  CPU demo of this legacy driver:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh2d
from repro.models import model as M
from repro.parallel.params import param_specs_for, rules_for
from repro.parallel.sharding import use_sharding


def serve(cfg, mesh, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    rules = rules_for(cfg, mesh)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    p_specs = param_specs_for(cfg, params, rules)
    params = jax.tree.map(
        lambda a, s: jax.device_put(
            a, jax.sharding.NamedSharding(mesh, s)), params, p_specs)

    s_max = prompt_len + gen
    shape = ((batch, prompt_len) if cfg.n_codebooks == 1
             else (batch, prompt_len, cfg.n_codebooks))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1), shape, 1,
                                 cfg.vocab_size)

    with use_sharding(rules):
        prefill = jax.jit(lambda p, t: M.prefill(p, t, cfg, s_max))
        decode = jax.jit(
            lambda p, c, t, i: M.decode_step(p, c, t, i, cfg),
            donate_argnums=(1,))
        logits, cache = prefill(params, prompts)
        out_tokens = [jnp.argmax(logits, axis=-1)]
        t0 = time.time()
        for i in range(prompt_len, prompt_len + gen - 1):
            tok = out_tokens[-1]
            if cfg.n_codebooks == 1 and tok.ndim == 2:
                pass
            logits, cache = decode(params, cache, tok, i)
            out_tokens.append(jnp.argmax(logits, axis=-1))
        jax.block_until_ready(out_tokens[-1])
        dt = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    rate = batch * (gen - 1) / max(dt, 1e-9)
    return toks, rate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    n = len(jax.devices())
    mesh = make_mesh2d(max(1, n // 2), min(2, n) if n > 1 else 1)
    toks, rate = serve(cfg, mesh, batch=args.batch,
                       prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {toks.shape} tokens at {rate:.1f} tok/s")


if __name__ == "__main__":
    main()

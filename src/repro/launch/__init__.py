"""repro.launch — mesh, step builders, dry-run and drivers."""

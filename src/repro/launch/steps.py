"""Step builders: train (grad-accum + AdamW), prefill, decode.

``make_train_step`` implements the production step: microbatched gradient
accumulation (fp32), global-norm clip, cosine LR, AdamW, optional int8
error-feedback gradient compression.  All functions are mesh-agnostic; the
caller jits them with shardings from ``input_specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_error_feedback, cosine_schedule)
from repro.parallel import pshard


def make_train_step(cfg, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, clip: float = 1.0,
                    compress: bool = False):
    mb = cfg.num_microbatches

    def loss_for(p, batch):
        return M.loss_fn(p, batch, cfg)

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state, batch):
        if mb > 1:
            def split(x):
                x = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
                return pshard(x, None, "batch", *([None] * (x.ndim - 2)))
            batch = jax.tree.map(split, batch)

            def micro(carry, mbatch):
                gacc, lacc = carry
                (loss, metrics), grads = grad_fn(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            carry = (zeros, jnp.zeros((), jnp.float32))
            if cfg.scan_layers:
                (grads, loss_sum), _ = jax.lax.scan(micro, carry, batch)
            else:                      # flat calibration mode
                for i in range(mb):
                    mbatch = jax.tree.map(lambda x: x[i], batch)
                    carry, _ = micro(carry, mbatch)
                grads, loss_sum = carry
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss_sum / mb
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if compress:
            resid = opt_state["residual"]
            grads, resid = compress_error_feedback(grads, resid)
            opt_state = dict(opt_state, residual=resid)

        grads, gnorm = clip_by_global_norm(grads, clip)
        adam = opt_state["adam"] if isinstance(opt_state, dict) else opt_state
        lr = cosine_schedule(adam.step + 1, peak_lr=peak_lr, warmup=warmup,
                             total=total_steps)
        params, adam = adamw_update(params, grads, adam, lr)
        if isinstance(opt_state, dict):
            opt_state = dict(opt_state, adam=adam)
        else:
            opt_state = adam
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}

    return train_step


def make_opt_state(params, *, compress: bool = False):
    adam = adamw_init(params)
    if not compress:
        return adam
    resid = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"adam": adam, "residual": resid}


def make_prefill_step(cfg):
    def prefill_step(params, tokens):
        logits, _ = M.forward(params, tokens, cfg, last_only=True)
        return logits
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, tokens, pos):
        return M.decode_step(params, cache, tokens, pos, cfg)
    return decode_step

"""Training driver: mesh → params → resilient loop → checkpoints.

Usage (CPU demo: reduced config, a few steps):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 20 --batch 8 --seq 64

On a pod the same driver runs the full config on the production mesh (the
mesh builder and sharding rules are identical; only device count changes).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenDataset, shard_batch
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh2d, make_production_mesh
from repro.models import model as M
from repro.parallel.params import param_specs_for, rules_for
from repro.parallel.sharding import use_sharding
from repro.runtime import HeartbeatMonitor, ResilientLoop


def build(cfg, mesh, *, compress: bool = False, seed: int = 0, **step_kw):
    rules = rules_for(cfg, mesh)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    p_specs = param_specs_for(cfg, params, rules)
    params = jax.tree.map(
        lambda a, s: jax.device_put(
            a, jax.sharding.NamedSharding(mesh, s)), params, p_specs)
    opt = steps_mod.make_opt_state(params, compress=compress)
    step_fn = steps_mod.make_train_step(cfg, compress=compress, **step_kw)
    with use_sharding(rules):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    return params, opt, jitted, rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n = len(jax.devices())
        mesh = make_mesh2d(max(1, n // 2), min(2, n) if n > 1 else 1)

    params, opt, jitted, rules = build(cfg, mesh, compress=args.compress)
    ds = TokenDataset(cfg.vocab_size, args.seq, args.batch,
                      n_codebooks=cfg.n_codebooks)
    mgr = CheckpointManager(args.ckpt_dir)
    batch_sharding = jax.sharding.NamedSharding(
        mesh, rules.spec(("batch", "seq"), (args.batch, args.seq)))

    state = {"params": params, "opt": opt}

    def step_fn(state, batch):
        with use_sharding(rules):
            batch = shard_batch(batch, batch_sharding)
            p, o, metrics = jitted(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    def save_fn(step, state):
        mgr.save(step, state, blocking=False,
                 extra={"data": ds.state()})

    def restore_fn():
        tgt = jax.tree.map(lambda x: x, state)
        restored, step, extra = mgr.restore(tgt)
        ds.restore(extra["data"])
        return restored, step

    loop = ResilientLoop(step_fn, save_fn, restore_fn, ds,
                         ckpt_every=args.ckpt_every,
                         monitor=HeartbeatMonitor())
    t0 = time.time()
    state, step, metrics = loop.run(state, 0, args.steps)
    dt = time.time() - t0
    mgr.wait()
    loss = float(metrics["loss"]) if metrics else float("nan")
    print(f"trained {args.steps} steps in {dt:.1f}s  "
          f"final loss {loss:.4f}")
    return state


if __name__ == "__main__":
    main()

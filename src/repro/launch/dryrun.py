import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (reduced-scale override for CI/tests; must still precede the jax import)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step),
  * it fits (memory_analysis), and
  * what it costs (cost_analysis + collective schedule → §Roofline).

Usage:
    python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out dryrun.jsonl
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, cells_for, get_config          # noqa: E402
from repro.configs.base import SHAPES                           # noqa: E402
from repro.launch import roofline                               # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.specs import cell_specs                       # noqa: E402
from repro.parallel.sharding import use_sharding                # noqa: E402


def _mem_fields(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                # pragma: no cover
        return {"memory_analysis_error": str(e)}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, name, None)
        if v is not None:
            out[name] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, overrides=None, cfg=None, verbose: bool = True,
             calibrate: bool = True):
    """Lower + compile one cell; returns the §Dry-run/§Roofline record.

    The full compile proves the sharding and yields memory_analysis; the
    roofline terms come from the calibrated flat variants (``calibrate``),
    since cost_analysis counts scan bodies once.
    """
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    spec = cell_specs(arch, shape_name, mesh, overrides, cfg=cfg)
    jitted = jax.jit(spec["fn"],
                     in_shardings=spec["in_shardings"],
                     out_shardings=spec["out_shardings"],
                     donate_argnums=spec["donate_argnums"])
    t0 = time.time()
    with use_sharding(spec["rules"]):
        lowered = jitted.lower(*spec["args"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = {"arch": arch, "shape": shape_name, "mesh": str(mesh.shape),
           "chips": chips, "lower_s": round(t_lower, 1),
           "compile_s": round(t_compile, 1)}
    rec.update(_mem_fields(compiled))
    raw = roofline.analyze(compiled)
    rec["raw_flops_per_chip"] = raw["flops_per_chip"]
    rec["raw_collective_bytes_per_chip"] = raw["collective_bytes_per_chip"]

    if calibrate:
        rec.update(roofline.calibrated_terms(
            arch, shape_name, mesh, overrides, cfg=spec["cfg"]))
    else:
        raw.pop("collective_breakdown", None)
        rec.update(raw)

    counts = roofline.count_params(spec["cfg"])
    rec["n_params"] = counts["total"]
    rec["n_active"] = counts["active"]
    mf = roofline.model_flops(spec["cfg"], spec["shape"], counts["total"],
                              counts["active"])
    rec["model_flops_per_chip"] = mf / chips
    if rec.get("flops_per_chip"):
        rec["useful_flop_ratio"] = mf / chips / rec["flops_per_chip"]
    if verbose:
        print(json.dumps(rec))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in cells_for(arch):
                cells.append((arch, shape))
    else:
        assert args.arch, "--arch or --all"
        shapes = [args.shape] if args.shape else cells_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    n = len(jax.devices())
    need = 512 if args.multi_pod else 256
    if n >= need:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        # reduced-scale CI mesh with the production axis names/ratios
        from repro.launch.mesh import make_mesh2d
        if args.multi_pod:
            per_pod = n // 2
            model = max(1, int(per_pod ** 0.5))
            while per_pod % model:
                model -= 1
            mesh = make_mesh2d(per_pod // model, model, pod=2)
        else:
            model = max(1, int(n ** 0.5))
            while n % model:
                model -= 1
            mesh = make_mesh2d(n // model, model)
    done = set()
    if args.skip_existing and args.out and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except Exception:
                pass

    out_f = open(args.out, "a") if args.out else None
    ok = True
    for arch, shape in cells:
        if (arch, shape, str(mesh.shape)) in done:
            print(f"skip {arch} {shape} (already recorded)")
            continue
        try:
            rec = run_cell(arch, shape, mesh=mesh,
                           calibrate=not args.no_calibrate)
        except Exception as e:
            ok = False
            rec = {"arch": arch, "shape": shape, "mesh": str(mesh.shape),
                   "error": repr(e)}
            print(json.dumps(rec))
            traceback.print_exc()
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Adjoint solves: reverse-mode AD through ``wfa.solve``.

The implicit-function theorem gives the VJP of a linear solve without
differentiating through the Krylov iteration (whose ``lax.while_loop``
has no reverse rule, and whose iterates are noise as far as the converged
solution is concerned): for ``x = A⁻¹ b``,

    b̄ = A⁻ᵀ x̄          (one *adjoint solve* with the transposed operator)
    θ̄ = −⟨λ, (∂A/∂θ) x⟩  with λ = A⁻ᵀ x̄   (coefficient-field gradients)

so the backward pass is one more Krylov solve with the **same compiled
machinery** as the forward:

* symmetric operators (CG / PipeCG / mg-pcg) — the transposed tap set
  re-canonicalizes to a ``LoweredGroup`` *equal* to the forward one
  (:func:`repro.compiler.ir.transpose_taps`), so the adjoint application
  hits the same kernel-cache entry; zero new kernels are built;
* non-symmetric operators (BiCGSTAB, e.g. variable-coefficient row-scaled
  stencils) — the transposed group lowers through the same IR → codegen
  path into one new fused kernel.

Moat / boundary handling.  The compiled operator is the *masked* map
``A = M·S + (I − M)`` — stencil rows on the written region ``M``
(X/Y-interior × z-window), identity rows elsewhere — so its true transpose
is ``Aᵀ = Sᵀ·M + (I − M)``, which couples boundary *columns* to interior
rows.  The adjoint solve splits this exactly: the interior part
``λᵢ = M·λ`` solves the maskable system ``Ã λᵢ = M x̄`` with
``Ã = M·S̃ + (I − M)`` (``S̃`` = the transposed tap set — a plain
``wfa``-shaped operator the Krylov drivers run unmodified, whose iterates
stay interior-supported), and the identity rows get the closed-form
correction ``λ_Moat = x̄_Moat − (S̃ λᵢ)_Moat`` applied outside the loop via
a cheap full-domain roll application.  That makes the VJP exact for
cotangents and perturbations with *boundary* support too — gradients with
respect to Dirichlet boundary values flow correctly.

Bodies that do not lower to the canonical affine form (interpreter
fallbacks) raise a clear ``ValueError`` here instead of producing a
silently wrong gradient.

    >>> import jax, jax.numpy as jnp
    >>> from repro.solver import make_differentiable_solver
    >>> from repro.solver.presets import btcs_program
    >>> solve = make_differentiable_solver(btcs_program((8, 8, 5), 0.2), "T")
    >>> solve.symmetric_adjoint
    True
    >>> x0 = jnp.ones((8, 8, 5), jnp.float32)
    >>> jax.grad(lambda v: jnp.sum(solve(v) ** 2))(x0).shape
    (8, 8, 5)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler import LoweringError, transpose_taps
from repro.compiler.codegen import compile_group
from repro.core.program import Program, _interp_step, release_program
from repro.solver import krylov
from repro.solver.api import (
    _answer_name,
    _build_mg,
    _check_precondition,
    _lower_operator,
    _split,
    _written_mask,
)

#: methods with an implicit-function-theorem adjoint: the symmetric Krylov
#: drivers (+ multigrid) reuse the forward kernel; bicgstab compiles the
#: transposed tap set.  chebyshev/jacobi are excluded — their fixed
#: iteration counts make "converged solution" (the IFT premise) a fiction.
ADJOINT_METHODS = ("cg", "pipecg", "bicgstab", "mg")


def _read(v, dz: int, dx: int, dy: int):
    """Value of ``v`` at cell ``(x+dx, y+dy, z+dz)``: periodic in X/Y (the
    roll semantics every backend implements), zero-extended in Z (the
    transpose of the in-bounds z-slice reads — correct wherever the
    interior-supported adjoint factor multiplies it)."""
    a = v
    if dx:
        a = jnp.roll(a, -dx, axis=0)
    if dy:
        a = jnp.roll(a, -dy, axis=1)
    if dz:
        nz = a.shape[2]
        src0, src1 = max(dz, 0), nz + min(dz, 0)
        out = jnp.zeros_like(a)
        a = out.at[:, :, src0 - dz : src1 - dz].set(a[:, :, src0:src1])
    return a


def _apply_update_full(update, env):
    """Unmasked full-domain roll application of one lowered update.

    Used once per backward solve for the Moat-row correction
    ``(S̃ λᵢ)_Moat`` — a handful of rolls, negligible next to the Krylov
    loop."""
    out = None
    for coeff, taps in update.terms:
        term = None
        for t in taps:
            r = _read(env[t.field], t.dz, t.dx, t.dy)
            term = r if term is None else term * r
        term = coeff * term
        out = term if out is None else out + term
    return out


def _masked_group_step(group, name):
    """Interpreter application of a :class:`LoweredGroup`: written rows get
    the tap polynomial, every other row passes through (identity Moat).
    The ``backend="jit"`` adjoint-operator step — the transposed analogue
    of :func:`repro.core.program._interp_step`."""
    masks = []
    for u in group.updates:
        masks.append((u, u.z0, u.zlen))

    def step(env):
        env = dict(env)
        v = env[name]
        nx, ny, _ = v.shape
        m2d = np.zeros((nx, ny, 1), dtype=bool)
        m2d[1:-1, 1:-1, :] = True
        interior = jnp.asarray(m2d)
        for u, z0, zlen in masks:
            val = _apply_update_full(u, env)
            win = jnp.where(interior, val, v)[:, :, z0 : z0 + zlen]
            v = jax.lax.dynamic_update_slice(v, win, (0, 0, z0))
            env[name] = v
        return env

    return step


def _validate_z(group, nz: int, what: str) -> None:
    for u in group.updates:
        for t in u.taps():
            if u.z0 + t.dz < 0 or u.z0 + u.zlen + t.dz > nz:
                raise ValueError(
                    f"{what}: tap {t} reads z "
                    f"[{u.z0 + t.dz}, {u.z0 + u.zlen + t.dz}) outside the "
                    f"field's {nz} planes — this operator's adjoint cannot "
                    "be expressed with the same z-window machinery"
                )


def make_differentiable_solver(
    program: Program,
    answer,
    *,
    method: str = "cg",
    backend: str = "pallas",
    tol: float = 1e-10,
    maxiter: int = 1000,
    steps: int = 1,
    precondition: Optional[str] = None,
    mg_opts=None,
    return_info: bool = False,
):
    """Build a traceable, reverse-differentiable solver for a recorded system.

    Returns ``solve_fn(x0, coef_env=None) -> x`` (or ``(x, (iters, res,
    outcomes))``
    with ``return_info=True``): ``x0`` is the unknown's initial state (its
    Moat carries the boundary values) and ``coef_env`` maps coefficient
    field names to arrays overriding their init data — both may be traced,
    and ``jax.grad`` through ``solve_fn`` is exact via the
    implicit-function-theorem ``custom_vjp`` (see the module docstring).
    Each of the ``steps`` implicit time steps runs the ``Rhs()`` body
    (differentiated natively through the roll interpreter — one application
    per step) and one Krylov solve on the compiled operator kernel.

    Unlike :func:`repro.solver.api.make_solver` this builder accumulates
    dot products in the field dtype (not fp32): fp64 gradient checks need
    fp64 reductions to reach tight tolerances.  Nothing is donated — the
    solver's inputs may be VJP residuals of an enclosing computation.

    Raises ``ValueError`` for non-affine operator bodies (an interpreter
    fallback has no tap set to transpose — failing loudly beats a silently
    wrong gradient), for nonlinear operators, and for the fixed-iteration
    methods outside :data:`ADJOINT_METHODS`.
    """
    if method not in ADJOINT_METHODS:
        raise ValueError(
            f"reverse-mode AD supports methods {ADJOINT_METHODS}; got "
            f"{method!r} (chebyshev/jacobi run a fixed iteration count, "
            "not a converged solve — the IFT adjoint does not apply)"
        )
    if backend not in ("jit", "pallas"):
        raise ValueError(f"unknown solver backend {backend!r}")
    _check_precondition(method, precondition)
    name = _answer_name(program, answer)
    release_program(program)
    (op_loop, op_ops), rhs_group = _split(program, name)
    group = _lower_operator(op_ops, name)
    if group is None:
        raise ValueError(
            "cannot differentiate through this solve: the operator body "
            "does not lower to the canonical affine tap form (it would run "
            "on the interpreter fallback), so there is no tap set to "
            "transpose for the adjoint system — rewrite the Operator() "
            "body as an affine stencil or drop differentiable=True"
        )
    if len(group.updates) != 1:
        raise ValueError(
            "differentiable solves support single-update Operator() bodies "
            f"(got {len(group.updates)} updates: sequentially composed "
            "updates transpose in reverse order with per-update masks, "
            "which this adjoint does not implement)"
        )
    try:
        tgroup = transpose_taps(group, name)
    except LoweringError as e:
        raise ValueError(f"cannot differentiate through this solve: {e}") from e
    field = program.fields[name]
    shape, dtype = field.shape, field.dtype
    _validate_z(group, shape[2], "operator")
    _validate_z(tgroup, shape[2], "adjoint operator")
    symmetric = tgroup == group

    mg = _build_mg(
        method, precondition, group, name, shape, dtype, backend, mg_opts
    )
    if method == "mg" or (mg is not None and precondition == "mg"):
        # build_multigrid validated symmetry; the cycle/preconditioner is
        # therefore its own adjoint and is reused verbatim below
        assert symmetric, "multigrid passed an asymmetric operator through"

    shapes = {n: f.shape for n, f in program.fields.items()}
    dtypes = {n: f.dtype for n, f in program.fields.items()}
    if backend == "pallas":
        from repro.kernels.ops import _interpret

        try:
            op_step = compile_group(
                op_ops, shapes, dtypes, interpret=_interpret(), group=group
            )
            opT_step = compile_group(
                op_ops, shapes, dtypes, interpret=_interpret(), group=tgroup
            )
        except LoweringError as e:
            raise ValueError(
                f"cannot differentiate through this solve: {e} (no silent "
                "interpreter fallback under grad)"
            ) from e
    else:
        op_step = _interp_step(op_ops)
        opT_step = _masked_group_step(tgroup, name)
    rhs_step = _interp_step(rhs_group[1]) if rhs_group is not None else None

    update = group.updates[0]
    t_update = tgroup.updates[0]
    m = jnp.asarray(_written_mask(group, shape))
    coef_names = [n for n in program.fields if n != name]
    M = mg.apply if (mg is not None and precondition == "mg") else None

    def dot(a, b):
        # field-dtype accumulation: the fp32 reduction make_solver uses
        # floors fp64 solves (and their gradient checks) at ~1e-7
        return jnp.sum(a * b)

    def dot2(a, b, c, d):
        return jnp.sum(a * b), jnp.sum(c * d)

    def _run_krylov(A, b, x0):
        if method == "mg":
            return krylov.stationary(
                lambda x: mg.cycle(x, b),
                lambda x: mg.residual_norm2(x, b, dot),
                x0,
                tol=tol,
                maxiter=maxiter,
                ref2=dot(b, b),
            )
        if method == "cg":
            return krylov.cg(A, dot, b, x0, tol=tol, maxiter=maxiter, M=M, dot2=dot2)
        if method == "pipecg":
            return krylov.pipecg(A, dot2, b, x0, tol=tol, maxiter=maxiter)
        return krylov.bicgstab(A, dot, b, x0, tol=tol, maxiter=maxiter, M=M)

    def _apply(step, v, envc):
        env = dict(envc)
        env[name] = v
        return step(env)[name]

    @jax.custom_vjp
    def solve_core(b, x0, *coef_args):
        envc = dict(zip(coef_names, coef_args))
        x, it, res, outcome = _run_krylov(
            lambda v: _apply(op_step, v, envc), b, x0
        )
        return x, it, res, outcome

    def solve_fwd(b, x0, *coef_args):
        out = solve_core(b, x0, *coef_args)
        return out, (out[0], coef_args)

    def solve_bwd(resids, cts):
        x, coef_args = resids
        ct = cts[0]  # iters/res cotangents are symbolic zeros
        envc = dict(zip(coef_names, coef_args))
        bt = jnp.where(m, ct, 0)
        lam, _, _, _ = _run_krylov(lambda v: _apply(opT_step, v, envc), bt, bt)
        lam = jnp.where(m, lam, 0)  # pin the interior support exactly
        # identity (Moat) rows of A⁻ᵀ: λ_Moat = x̄_Moat − (S̃ λᵢ)_Moat
        full = _apply_update_full(t_update, {**envc, name: lam})
        b_bar = lam + jnp.where(m, 0, ct - full)
        coef_bars = []
        for n in coef_names:
            g = None
            for coeff, taps in update.terms:
                ctap = [t for t in taps if t.field == n]
                if not ctap:
                    continue
                (tc,) = ctap
                (tx,) = [t for t in taps if t.field == name]
                piece = (
                    coeff
                    * _read(lam, -tc.dz, -tc.dx, -tc.dy)
                    * _read(x, tx.dz - tc.dz, tx.dx - tc.dx, tx.dy - tc.dy)
                )
                g = piece if g is None else g + piece
            if g is None:
                coef_bars.append(jnp.zeros(shapes[n], dtypes[n]))
            else:
                coef_bars.append(-g.astype(dtypes[n]))
        return (b_bar, jnp.zeros_like(x), *coef_bars)

    solve_core.defvjp(solve_fwd, solve_bwd)

    def run(x0, *coef_args):
        envc = dict(zip(coef_names, coef_args))

        def one(x, _):
            b = _apply(rhs_step, x, envc) if rhs_step is not None else x
            x2, it, res, outcome = solve_core(b, x, *coef_args)
            return x2, (it, res, outcome)

        return jax.lax.scan(one, x0, None, length=steps)

    def solve_fn(x0, coef_env=None):
        coef_env = coef_env or {}
        coefs = [
            jnp.asarray(coef_env.get(n, program.fields[n].init_data))
            for n in coef_names
        ]
        x, aux = run(jnp.asarray(x0), *coefs)
        return (x, aux) if return_info else x

    solve_fn.symmetric_adjoint = symmetric
    solve_fn.coef_names = tuple(coef_names)
    return solve_fn

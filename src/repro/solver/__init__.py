"""repro.solver — implicit field equations as first-class WFA programs.

The paper's implicit results (BTCS + matrix-free Krylov on the WSE) used a
hand-wired operator per PDE; this package routes the operator through the
same recorded-program → fused-Pallas pipeline as the explicit path:

1. :mod:`~repro.solver.frontend` — ``Operator()``/``Rhs()`` recording
   contexts: the operator stencil ``A(v)`` is written exactly like an
   explicit update (masked self-update of the unknown — identity Moat rows
   for free);
2. :mod:`~repro.solver.api` — ``wfa.solve``: compiles the recorded bodies
   through :mod:`repro.compiler` (kernel cache + stats + logged interpreter
   fallback) and runs matrix-free iterations on the compiled application,
   single-device or brick-sharded (``mesh=`` → halo exchange + ONE fused
   ``psum`` per reduction);
3. :mod:`~repro.solver.krylov` — the iteration kernels (CG, pipelined CG,
   BiCGSTAB, Chebyshev, Jacobi, stationary), shared with the legacy
   :mod:`repro.core.implicit` drivers;
4. :mod:`~repro.solver.multigrid` — geometric V/W-cycles whose every
   component (per-level smoother/residual programs, re-discretized coarse
   operators, restriction/prolongation transfer kernels) lowers through the
   same IR → codegen path: ``method="mg"`` and ``precondition="mg"`` keep
   iteration counts flat as grids grow;
5. :mod:`~repro.solver.presets` — canonical recorded systems (BTCS heat,
   variable-coefficient diffusion, Dirichlet Poisson).
"""

from repro.solver import health, krylov
from repro.solver.adjoint import ADJOINT_METHODS, make_differentiable_solver
from repro.solver.health import (
    GuardConfig,
    NumericalFault,
    RecoveryPolicy,
    RecoveryTrace,
)
from repro.solver.api import (
    SolveInfo,
    gershgorin_bounds,
    make_sharded_solver,
    make_solver,
    operator_fns,
    solve,
)
from repro.solver.frontend import Operator, Rhs, SolverMarker
from repro.solver.multigrid import MGOptions, Multigrid, build_multigrid
from repro.solver.presets import (
    btcs_program,
    poisson_program,
    psi,
    record_btcs,
    record_poisson,
    record_varcoef_btcs,
)

__all__ = [
    "ADJOINT_METHODS",
    "GuardConfig",
    "MGOptions",
    "Multigrid",
    "NumericalFault",
    "Operator",
    "RecoveryPolicy",
    "RecoveryTrace",
    "Rhs",
    "SolveInfo",
    "SolverMarker",
    "btcs_program",
    "build_multigrid",
    "gershgorin_bounds",
    "health",
    "krylov",
    "make_differentiable_solver",
    "make_sharded_solver",
    "make_solver",
    "operator_fns",
    "poisson_program",
    "psi",
    "record_btcs",
    "record_poisson",
    "record_varcoef_btcs",
    "solve",
]

"""Geometric multigrid through the WFA program compiler.

Krylov iteration counts on elliptic systems grow with the grid (the ceiling
the paper's BiCGSTAB runs hit — Rocki et al. stopped there); a geometric
V/W-cycle removes that growth.  The design rule of this module is that
*every* multigrid component is an ordinary recorded WFA program (or a
canonical transfer op) lowered through the existing IR → codegen path:

* the **level operators** come from :func:`repro.compiler.ir.mg_hierarchy` —
  the user's recorded taps, re-discretized per level (row-sum rule);
* the **smoother** (weighted Jacobi, or red-black Gauss–Seidel as two
  masked half-sweeps) and the **residual** are unparsed back into recorded
  programs per level (:func:`_record_smoother` / :func:`_record_residual`)
  and compiled by :func:`repro.engine.plan_mg_levels` through
  ``engine.compile_body`` — one fused Pallas kernel cache entry per level
  on ``backend="pallas"``, the roll interpreter on ``backend="jit"``;
* the **transfers** (full-weighting restriction, trilinear prolongation)
  are :class:`repro.compiler.ir.TransferStencil` ops lowered by
  :func:`repro.compiler.codegen.compile_transfer` into the kernels of
  :mod:`repro.kernels.transfer`.

``wfa.solve(..., method="mg")`` iterates the cycle as a standalone solver;
``precondition="mg"`` applies one cycle from a zero guess as an SPD
preconditioner inside CG/BiCGSTAB (see :mod:`repro.solver.api`).  Iteration
counts become grid-size independent — the property tested across three grid
sizes in ``tests/test_multigrid.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler import MGOperator, mg_fine_operator, mg_hierarchy
from repro.core.field import Field
from repro.core.program import scoped_program

#: default damping for weighted Jacobi — the classic smoothing-optimal
#: factor for the 7-point 3-D Laplacian family
JACOBI_OMEGA = 6.0 / 7.0


@dataclasses.dataclass(frozen=True)
class MGOptions:
    """Cycle shape and smoothing budget of one multigrid hierarchy.

    ``cycle``        — ``"v"`` (one coarse visit) or ``"w"`` (two);
    ``smoother``     — ``"jacobi"`` (weighted, ``omega``-damped) or ``"rb"``
                       (red-black Gauss–Seidel: two checkerboard-masked
                       half-sweeps, post-smoothing in reversed colour order
                       so the cycle stays symmetric; ``omega`` is ignored —
                       Gauss–Seidel updates are undamped — and each
                       half-sweep reuses the full-grid smoother kernel,
                       discarding the off-colour half, so one rb sweep
                       costs two kernel launches);
    ``nu1``/``nu2``  — pre-/post-smoothing sweeps (keep equal when the
                       cycle is used as a CG preconditioner: symmetry);
    ``coarse_iters`` — smoother sweeps standing in for the coarsest solve;
    ``max_levels``   — cap on hierarchy depth, >= 2 (one level would be
                       plain relaxation, not multigrid; ``None`` = coarsen
                       while every extent stays >= ``ir.MG_MIN_DIM``).

    >>> MGOptions(cycle="w", smoother="rb").nu1
    2
    >>> MGOptions(cycle="f")
    Traceback (most recent call last):
        ...
    ValueError: mg cycle must be 'v' or 'w', got 'f'
    """

    cycle: str = "v"
    smoother: str = "jacobi"
    nu1: int = 2
    nu2: int = 2
    coarse_iters: int = 40
    omega: float = JACOBI_OMEGA
    max_levels: Optional[int] = None

    def __post_init__(self):
        if self.cycle not in ("v", "w"):
            raise ValueError(f"mg cycle must be 'v' or 'w', got {self.cycle!r}")
        if self.smoother not in ("jacobi", "rb"):
            raise ValueError(
                f"mg smoother must be 'jacobi' or 'rb', got {self.smoother!r}"
            )
        if min(self.nu1, self.nu2, self.coarse_iters) < 1:
            raise ValueError("mg smoothing counts must be >= 1")
        if self.max_levels is not None and self.max_levels < 2:
            raise ValueError(
                f"mg needs max_levels >= 2 (got {self.max_levels}); one "
                "level is plain relaxation, not multigrid"
            )


def _record_smoother(op: MGOperator, omega: float, dtype):
    """Record one level's damped-Jacobi sweep as a WFA program.

    ``x ← x + (ω/d)(b − A x)`` expands to an affine update in taps of ``x``
    plus the centre tap of ``b`` — exactly the canonical form the compiler
    fuses, so each sweep is one kernel launch.  Returns the ``(ops, shapes,
    dtypes)`` triple :func:`repro.engine.plan_mg_levels` compiles.
    """
    nz = op.shape[2]
    z0, zlen = 1, nz - 2
    wd = omega / op.diag
    with scoped_program() as p:
        x = Field("x", shape=op.shape, dtype=dtype)
        b = Field("b", shape=op.shape, dtype=dtype)
        expr = wd * b[slice(z0, z0 + zlen), 0, 0]
        for (dz, dx, dy), c in op.taps:
            coeff = 1.0 - wd * c if (dz, dx, dy) == (0, 0, 0) else -wd * c
            expr = expr + coeff * x[slice(z0 + dz, z0 + dz + zlen), dx, dy]
        x[slice(z0, z0 + zlen), 0, 0] = expr
    shapes = {n: f.shape for n, f in p.fields.items()}
    dtypes = {n: f.dtype for n, f in p.fields.items()}
    return p.ops, shapes, dtypes


def _record_residual(op: MGOperator, dtype):
    """Record one level's residual ``r = b − A x`` as a WFA program.

    Writes a third field ``r`` (zero Moat — the coarse problem's
    homogeneous Dirichlet rows come for free from the unwritten cells).
    """
    nz = op.shape[2]
    z0, zlen = 1, nz - 2
    with scoped_program() as p:
        x = Field("x", shape=op.shape, dtype=dtype)
        b = Field("b", shape=op.shape, dtype=dtype)
        r = Field("r", shape=op.shape, dtype=dtype)
        expr = b[slice(z0, z0 + zlen), 0, 0]
        for (dz, dx, dy), c in op.taps:
            expr = expr - c * x[slice(z0 + dz, z0 + dz + zlen), dx, dy]
        r[slice(z0, z0 + zlen), 0, 0] = expr
    shapes = {n: f.shape for n, f in p.fields.items()}
    dtypes = {n: f.dtype for n, f in p.fields.items()}
    return p.ops, shapes, dtypes


def _parity_mask(shape) -> np.ndarray:
    """(X, Y, Z) checkerboard: True where (x + y + z) is even."""
    gx, gy, gz = np.ogrid[: shape[0], : shape[1], : shape[2]]
    return (gx + gy + gz) % 2 == 0


class Multigrid:
    """A compiled multigrid hierarchy: V/W-cycle and preconditioner apply.

    Built by :func:`build_multigrid`; holds the engine-scheduled
    :class:`~repro.engine.plan.LevelSegment` list (finest first).  All
    methods are jit-traceable — the recursion over levels unrolls at trace
    time, so a whole cycle is one XLA computation.
    """

    def __init__(self, segments, opts: MGOptions, dtype):
        self.segments = segments
        self.opts = opts
        self.dtype = dtype
        self._masks = {}
        if opts.smoother == "rb":
            for seg in segments:
                self._masks[seg.level] = jnp.asarray(_parity_mask(seg.shape))

    @property
    def n_levels(self) -> int:
        return len(self.segments)

    def _smooth(self, seg, x, b, n: int, reverse: bool = False):
        red = self._masks.get(seg.level)

        def sweep_jacobi(_, x):
            return seg.smooth({"x": x, "b": b})["x"]

        def sweep_rb(_, x):
            order = (~red, red) if reverse else (red, ~red)
            for mask in order:
                x = jnp.where(mask, seg.smooth({"x": x, "b": b})["x"], x)
            return x

        sweep = sweep_jacobi if self.opts.smoother == "jacobi" else sweep_rb
        return jax.lax.fori_loop(0, n, sweep, x)

    def _residual(self, seg, x, b):
        env = {"x": x, "b": b, "r": jnp.zeros_like(x)}
        return seg.resid(env)["r"]

    def _descend(self, level: int, x, b):
        seg = self.segments[level]
        if level == self.n_levels - 1:
            return self._smooth(seg, x, b, self.opts.coarse_iters)
        x = self._smooth(seg, x, b, self.opts.nu1)
        rc = seg.restrict(self._residual(seg, x, b))
        ec = jnp.zeros(self.segments[level + 1].shape, self.dtype)
        ec = self._descend(level + 1, ec, rc)
        if self.opts.cycle == "w" and level + 1 < self.n_levels - 1:
            ec = self._descend(level + 1, ec, rc)
        x = x + seg.prolong(ec)
        return self._smooth(seg, x, b, self.opts.nu2, reverse=True)

    def cycle(self, x, b):
        """One V/W-cycle on the finest level: ``x ← MG(x, b)``."""
        return self._descend(0, x, b)

    def apply(self, r):
        """Preconditioner action ``M⁻¹ r``: one cycle from a zero guess.

        With symmetric smoothing (``nu1 == nu2``, reversed-colour post-
        sweeps for ``"rb"``) this is a symmetric positive definite linear
        operator — safe inside CG.
        """
        return self.cycle(jnp.zeros_like(r), r)

    def residual_norm2(self, x, b, dot):
        """``dot(r, r)`` of the fine-level residual (outer-loop stopping)."""
        r = self._residual(self.segments[0], x, b)
        return dot(r, r)


def build_multigrid(
    group, answer: str, shape, dtype, backend: str, opts: MGOptions = None
) -> Multigrid:
    """Build the compiled hierarchy for a lowered operator body.

    ``group`` is the operator's :class:`~repro.compiler.ir.LoweredGroup`
    (``None`` when it did not lower — rejected here with the reason).
    Raises :class:`repro.compiler.LoweringError` when the operator or grid
    is outside multigrid's domain: non-affine / variable-coefficient /
    asymmetric stencils, taps beyond the 27-point neighbourhood, or a grid
    with no coarsenable extent.  ``repro.solver.api`` turns that into a
    clear error (``method="mg"``) or a logged fallback to the
    unpreconditioned path (``precondition="mg"``).
    """
    from repro.engine import plan_mg_levels

    opts = opts or MGOptions()
    fine = mg_fine_operator(group, answer, tuple(shape))
    levels = mg_hierarchy(fine, opts.max_levels)
    omega = 1.0 if opts.smoother == "rb" else opts.omega
    bodies = [
        {
            "shape": op.shape,
            "diag": op.diag,
            "smooth": _record_smoother(op, omega, dtype),
            "resid": _record_residual(op, dtype),
        }
        for op in levels
    ]
    segments = plan_mg_levels(bodies, backend, dtype)
    return Multigrid(segments, opts, dtype)

"""``wfa.solve`` — matrix-free implicit solves through the program compiler.

The explicit path records a program and lowers every loop body to one fused
Pallas kernel; this module does the same for *implicit* systems.  The
operator body recorded inside ``with Operator():`` (see
:mod:`repro.solver.frontend`) compiles through the engine's single backend
dispatch (:func:`repro.engine.compile_body` — the identical
IR-normalization → fused-codegen pipeline of :mod:`repro.compiler`) into one
``pallas_call`` per operator application — kernel cache, stats counters and
logged interpreter fallback included — and the matrix-free iterations of
:mod:`repro.solver.krylov` run on top of the compiled application.
``method="mg"`` / ``precondition="mg"`` add geometric multigrid
(:mod:`repro.solver.multigrid`): a compiled V/W-cycle hierarchy whose
iteration counts stay flat as grids grow.

Entry points:

* :func:`solve` — run a recorded system to convergence (also reachable as
  ``WFAInterface.solve``); ``mesh=`` composes with ``shard_map`` the same
  way ``backend="pallas"`` does for explicit programs (halo-pad brick →
  fused kernel, dot products as ONE fused ``psum`` over both mesh axes);
* :func:`make_solver` / :func:`make_sharded_solver` — build a reusable
  jitted step (benchmarks, time-stepping drivers);
* :func:`operator_fns` — just the compiled ``(A, rhs)`` applications (the
  legacy ``repro.core.implicit`` drivers are wired through this).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler import LoweringError, Tap, lower_group
from repro.core.program import Program, _group_ops, release_program
from repro.solver import health, krylov

log = logging.getLogger("repro.solver")

METHODS = ("cg", "pipecg", "bicgstab", "chebyshev", "jacobi", "mg")

#: methods that never touch a dot product — zero collectives per iteration
REDUCTION_FREE = ("chebyshev", "jacobi")

#: methods that accept ``precondition="mg"`` (CG needs an SPD M; BiCGSTAB
#: preconditions from the right, so any fixed linear M works)
PRECONDITIONABLE = ("cg", "bicgstab")


@dataclasses.dataclass
class SolveInfo:
    """Per-call convergence record returned by ``solve(..., return_info=True)``.

    On a batched solve (``options.batch = B > 1``) ``iterations``,
    ``residual`` and ``outcomes`` carry a trailing member axis — shape
    ``(steps, B)`` — with each member's own masked iteration count (see
    :mod:`repro.solver.krylov`'s batched variants).

    ``outcomes`` holds the :mod:`repro.solver.health` taxonomy name per
    time step (``CONVERGED`` / ``MAXITER`` / ``NAN_RESIDUAL`` /
    ``BREAKDOWN`` / ``STAGNATED`` / ``DIVERGED``); ``recovery`` is the
    :class:`~repro.solver.health.RecoveryTrace` when the solve went through
    the escalation ladder (None when the first attempt stood)."""

    method: str
    backend: str
    iterations: np.ndarray  # (steps,) inner iterations per time step
    residual: np.ndarray  # (steps,) final ‖r‖ per time step
    outcomes: Optional[np.ndarray] = None  # (steps,) taxonomy names
    recovery: Optional["health.RecoveryTrace"] = None


# ---------------------------------------------------------------------------
# program splitting + validation
# ---------------------------------------------------------------------------


def _answer_name(program: Program, answer) -> str:
    name = getattr(answer, "name", answer)
    if name not in program.fields:
        raise ValueError(f"answer field {name!r} is not registered in this program")
    return name


def _split(program: Program, answer: str):
    """-> ((op_loop, op_ops), (rhs_loop, rhs_ops) | None), validated."""
    op_groups, rhs_groups = [], []
    for loop, ops in _group_ops(program):
        role = getattr(loop, "role", None)
        if role == "operator":
            op_groups.append((loop, ops))
        elif role == "rhs":
            rhs_groups.append((loop, ops))
        else:
            raise ValueError(
                "wfa.solve programs may only contain Operator()/Rhs() "
                f"groups; found updates under {getattr(loop, 'name', loop)!r}"
            )
    if len(op_groups) != 1:
        raise ValueError(
            f"expected exactly one Operator() group, found {len(op_groups)}"
        )
    if len(rhs_groups) > 1:
        raise ValueError(f"expected at most one Rhs() group, found {len(rhs_groups)}")
    for _, ops in op_groups + rhs_groups:
        written = {op.field_name for op in ops}
        if written != {answer}:
            raise ValueError(
                "Operator()/Rhs() bodies must update only the unknown field "
                f"{answer!r}; they write {sorted(written)}"
            )
    return op_groups[0], (rhs_groups[0] if rhs_groups else None)


def _lower_operator(op_ops: Sequence, answer: str):
    """Lower the operator body for validation / bounds / diagonal extraction.

    Returns the :class:`LoweredGroup`, or ``None`` when the body is not
    affine-lowerable (the application then runs on the interpreter fallback
    and linearity cannot be checked statically).  Raises ``ValueError`` for
    bodies that lower but are *not linear* in the unknown — Krylov methods
    would silently diverge on those.
    """
    try:
        group = lower_group(op_ops)
    except LoweringError:
        return None
    for u in group.updates:
        if u.const != 0.0:
            raise ValueError(
                f"operator body has a constant term ({u.const}); A(x) must "
                "be linear in the unknown — move constants into the Rhs()"
            )
        for coeff, taps in u.terms:
            n_unknown = sum(t.field == answer for t in taps)
            if n_unknown == 0:
                raise ValueError(
                    "operator term reads only coefficient fields — an "
                    "affine shift; move it into the Rhs()"
                )
            if n_unknown > 1:
                raise ValueError(
                    "operator body is nonlinear in the unknown "
                    f"({n_unknown} taps of {answer!r} multiplied); Krylov "
                    "methods need a linear operator"
                )
    return group


def gershgorin_bounds(group, answer: str) -> Optional[Tuple[float, float]]:
    """Eigenvalue bounds of the lowered operator via Gershgorin circles.

    Only for constant-coefficient single-update bodies (every term one tap
    of the unknown): centre = diagonal coefficient, radius = Σ|off-diagonal|.
    The identity Moat rows contribute eigenvalue 1, so the bracket is widened
    to include it.  Returns ``None`` when bounds cannot be derived (variable
    coefficients) or the operator is indefinite — pass ``lambda_bounds=``.
    """
    if group is None or len(group.updates) != 1:
        return None
    diag = 0.0
    radius = 0.0
    for coeff, taps in group.updates[0].terms:
        if len(taps) != 1 or taps[0].field != answer:
            return None
        t = taps[0]
        if (t.dz, t.dx, t.dy) == (0, 0, 0):
            diag += coeff
        else:
            radius += abs(coeff)
    lmin = min(diag - radius, 1.0)
    lmax = max(diag + radius, 1.0)
    if lmin <= 0.0:
        return None
    return lmin, lmax


def _resolve_bounds(method, lambda_bounds, group, answer):
    if method != "chebyshev":
        return None
    bounds = lambda_bounds or gershgorin_bounds(group, answer)
    if bounds is None:
        raise ValueError(
            "chebyshev needs eigenvalue bounds: the operator does not admit "
            "automatic Gershgorin bounds — pass lambda_bounds=(lmin, lmax)"
        )
    return float(bounds[0]), float(bounds[1])


def _check_jacobi(method, group):
    if method == "jacobi" and (group is None or len(group.updates) != 1):
        raise ValueError(
            "jacobi needs a lowerable single-update affine operator (the "
            "diagonal is read off the tap form); use bicgstab instead"
        )


def _check_precondition(method, precondition):
    if precondition not in (None, "mg"):
        raise ValueError(
            f"unknown preconditioner {precondition!r}; expected None or 'mg'"
        )
    if precondition is not None and method not in PRECONDITIONABLE:
        hint = " (method='mg' is already multigrid)" if method == "mg" else ""
        raise ValueError(
            f"precondition='mg' supports methods {PRECONDITIONABLE}; "
            f"got method={method!r}{hint}"
        )


def _build_mg(method, precondition, group, name, shape, dtype, backend, mg_opts):
    """Build the multigrid hierarchy when ``method``/``precondition`` asks.

    ``method="mg"`` turns an illegal system (grid not coarsenable,
    non-affine / variable-coefficient / asymmetric operator) into a clear
    ``ValueError``; ``precondition="mg"`` degrades gracefully — a logged
    warning and a fallback to the unpreconditioned method.
    """
    if method != "mg" and precondition != "mg":
        return None
    from repro.solver.multigrid import build_multigrid

    try:
        return build_multigrid(group, name, shape, dtype, backend, mg_opts)
    except LoweringError as e:
        if method == "mg":
            raise ValueError(f"method='mg' cannot be built: {e}") from e
        log.warning(
            "precondition='mg' unavailable (%s) — falling back to "
            "unpreconditioned %s",
            e,
            method,
        )
        return None


def _jacobi_diag(group, answer: str, env):
    """Diagonal of the operator: a scalar, or an array for variable
    coefficients (center-tap products only)."""
    diag = None
    for coeff, taps in group.updates[0].terms:
        mine = [t for t in taps if t.field == answer]
        if mine != [Tap(answer, 0, 0, 0)]:
            continue  # off-diagonal term
        term = coeff
        for t in taps:
            if t.field == answer:
                continue
            if (t.dz, t.dx, t.dy) != (0, 0, 0):
                raise ValueError(
                    "jacobi: coefficient tap with nonzero offset is not "
                    "supported; use bicgstab"
                )
            term = term * env[t.field]
        diag = term if diag is None else diag + term
    if diag is None:
        raise ValueError("jacobi: operator has no diagonal (center) tap")
    return diag


def _written_mask(group, shape) -> np.ndarray:
    """(X, Y, Z) bool mask of cells the operator body writes (the rest are
    identity rows)."""
    nx, ny, nz = shape
    m = np.zeros((nx, ny, nz), dtype=bool)
    for u in group.updates:
        m[1:-1, 1:-1, u.z0 : u.z0 + u.zlen] = True
    return m


def _z_window(group, nz: int) -> np.ndarray:
    zw = np.zeros((1, 1, nz), dtype=bool)
    for u in group.updates:
        zw[0, 0, u.z0 : u.z0 + u.zlen] = True
    return zw


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------




def _make_runner(
    *,
    method: str,
    name: str,
    coef_names,
    op_step: Callable,
    rhs_step: Optional[Callable],
    dot: Callable,
    dot2: Callable,
    tol: float,
    maxiter: int,
    steps: int,
    bounds,
    group,
    jacobi_mask: Callable,
    mg=None,
    M: Optional[Callable] = None,
    batch: int = 1,
):
    """Shared solve driver: ``run(x0, *coefs) -> (x, (iters, res, outcomes))``.

    Both builders delegate here so the method dispatch and the per-step
    ``Rhs() → Krylov`` loop cannot diverge between the single-device and
    sharded paths; they differ only in the injected ``dot``/``dot2`` (the
    sharded ones own the ``psum``), ``jacobi_mask`` (static array vs traced
    from mesh coordinates inside ``shard_map``) and ``M`` (the sharded
    preconditioner gathers/slices around the cycle).  ``mg`` carries the
    compiled :class:`~repro.solver.multigrid.Multigrid` for
    ``method="mg"``; ``M`` is the preconditioner action for CG/BiCGSTAB.

    ``batch=B`` routes the Krylov methods to their per-member-masked
    batched variants (``dot``/``dot2`` then reduce to (B,) vectors) and
    broadcasts the reduction-free methods' shared iteration count to (B,),
    so ``(iters, res, outcomes)`` are uniformly per-member.  ``outcomes``
    is the per-step :mod:`repro.solver.health` taxonomy word.
    """

    def run_method(A, b, x0, envc):
        if method == "mg":
            return krylov.stationary(
                lambda x: mg.cycle(x, b),
                lambda x: mg.residual_norm2(x, b, dot),
                x0,
                tol=tol,
                maxiter=maxiter,
                ref2=dot(b, b),
            )
        if method == "cg":
            if batch > 1:
                return krylov.cg_batched(A, dot, b, x0, tol=tol, maxiter=maxiter)
            return krylov.cg(
                A, dot, b, x0, tol=tol, maxiter=maxiter, M=M, dot2=dot2
            )
        if method == "pipecg":
            if batch > 1:
                return krylov.pipecg_batched(
                    A, dot2, b, x0, tol=tol, maxiter=maxiter
                )
            return krylov.pipecg(A, dot2, b, x0, tol=tol, maxiter=maxiter)
        if method == "bicgstab":
            if batch > 1:
                return krylov.bicgstab_batched(
                    A, dot, b, x0, tol=tol, maxiter=maxiter
                )
            return krylov.bicgstab(A, dot, b, x0, tol=tol, maxiter=maxiter, M=M)
        if method == "chebyshev":
            return krylov.chebyshev(
                A, b, x0, bounds[0], bounds[1], iters=maxiter, dot=dot, tol=tol
            )
        D = _jacobi_diag(group, name, envc)
        mask = jacobi_mask()
        jstep = lambda x: jnp.where(mask, x + (b - A(x)) / D, b)
        # one extra operator application per solve reports + classifies the
        # true end-of-run residual (jacobi is otherwise reduction-free)
        return krylov.jacobi(
            jstep,
            x0,
            iters=maxiter,
            rnorm2=lambda x: dot(b - A(x), b - A(x)),
            tol=tol,
        )

    def run(x0, *coef_args):
        envc = dict(zip(coef_names, coef_args))

        def A(v):
            env = dict(envc)
            env[name] = v
            return op_step(env)[name]

        def one(x, _):
            if rhs_step is not None:
                env = dict(envc)
                env[name] = x
                b = rhs_step(env)[name]
            else:
                b = x
            x2, i, res, outcome = run_method(A, b, x, envc)
            if batch > 1:
                # fixed-count methods report one shared scalar; make every
                # method's (iters, res, outcome) per-member so SolveInfo is
                # uniform
                i = jnp.broadcast_to(jnp.asarray(i, jnp.int32), (batch,))
                res = jnp.broadcast_to(jnp.asarray(res, jnp.float32), (batch,))
                outcome = jnp.broadcast_to(
                    jnp.asarray(outcome, jnp.int32), (batch,)
                )
            return x2, (i, res, outcome)

        x2, aux = jax.lax.scan(one, x0, None, length=steps)
        return x2, aux

    return run


def _build_step(
    ops,
    loop,
    program: Program,
    backend: str,
    mesh_ctx=None,
    resident: int = 0,
    batch: int = 1,
) -> Callable:
    """One body application ``env -> env`` through the engine's single
    dispatch point (:func:`repro.engine.compile_body`): fused Pallas kernel
    when ``backend="pallas"`` (interpreter fallback on LoweringError,
    counted in ``repro.compiler.stats``), the shared roll interpreter
    otherwise; sharded when ``mesh_ctx`` is given.

    ``resident=K`` compiles the application against the engine's
    halo-resident layout (standing margin-``K`` buffers, in-place refresh +
    aliased outputs — :mod:`repro.engine.layout`).  The Krylov drivers keep
    their vectors unpadded — each operator application is a single launch,
    so the pad it saves is bought back by interior re-slicing in every dot
    product — but the parameter keeps the solver on the same codegen
    surface as the explicit executors; the solve-loop allocations are
    instead eliminated by donating the jitted run's entry buffers
    (``donate_argnums``) and XLA's in-place ``while_loop`` carries."""
    from repro.engine import compile_body

    if backend not in ("jit", "pallas"):
        raise ValueError(f"unknown solver backend {backend!r}")
    shapes = {n: f.shape for n, f in program.fields.items()}
    dtypes = {n: f.dtype for n, f in program.fields.items()}
    step, _ = compile_body(
        ops,
        loop,
        shapes,
        dtypes,
        backend,
        mesh_ctx=mesh_ctx,
        resident=resident,
        batch=batch,
    )
    return step


def operator_fns(program: Program, answer, backend: str = "jit"):
    """Compiled single-device ``(A, rhs)`` applications for a recorded system.

    ``A(v)`` applies the operator body with the unknown bound to ``v``
    (coefficient fields are closed over from their init data); ``rhs(T)``
    produces ``b`` from the state — the identity when no ``Rhs()`` group was
    recorded.  Both are jit-traceable.
    """
    name = _answer_name(program, answer)
    release_program(program)
    (op_loop, op_ops), rhs_group = _split(program, name)
    _lower_operator(op_ops, name)
    op_step = _build_step(op_ops, op_loop, program, backend)
    consts = {
        n: jnp.asarray(f.init_data)
        for n, f in program.fields.items()
        if n != name
    }

    def A(v):
        env = dict(consts)
        env[name] = v
        return op_step(env)[name]

    if rhs_group is None:
        return A, (lambda T: T)
    rhs_step = _build_step(rhs_group[1], rhs_group[0], program, backend)

    def rhs(T):
        env = dict(consts)
        env[name] = T
        return rhs_step(env)[name]

    return A, rhs


# ---------------------------------------------------------------------------
# single-device solver
# ---------------------------------------------------------------------------


def make_solver(
    program: Program,
    answer,
    *,
    method: str = "cg",
    backend: str = "pallas",
    tol: float = 1e-6,
    maxiter: int = 500,
    steps: int = 1,
    lambda_bounds: Optional[Tuple[float, float]] = None,
    precondition: Optional[str] = None,
    mg_opts=None,
    batch: int = 1,
    member_env=None,
    differentiable: bool = False,
) -> Callable:
    """Build a reusable jitted solver ``step_fn(x0) -> (x, (iters, res,
    outcomes))``.

    Each call advances ``steps`` implicit time steps: per step the ``Rhs()``
    body produces ``b`` from the state (identity if none was recorded) and
    the iteration solves ``A x = b`` warm-started at the state.
    ``method="mg"`` iterates geometric V/W-cycles; ``precondition="mg"``
    wraps one cycle from a zero guess around CG/BiCGSTAB (see
    :mod:`repro.solver.multigrid`; tune with ``mg_opts=MGOptions(...)``).

    ``batch=B`` builds an *ensemble* solver: ``step_fn`` takes and returns a
    ``(B, X, Y, Z)`` stack, the operator applies batch-aware (one compiled
    kernel launch per application for all members), dots reduce per member,
    and the Krylov loops freeze converged members while running to the
    slowest (see :mod:`repro.solver.krylov`).  ``member_env`` supplies
    per-member ``(B, X, Y, Z)`` stacks for coefficient fields (others
    broadcast from their init data); multigrid is not batch-aware, so
    ``method="mg"`` / ``precondition=`` require ``batch=1``.

    ``differentiable=True`` returns a solver that is reverse-mode
    differentiable via the implicit-function-theorem adjoint
    (:mod:`repro.solver.adjoint`): same ``step_fn(x0) -> (x, (iters, res,
    outcomes))`` contract, but traceable under ``jax.grad``/``jax.jit``,
    with nothing
    donated and dots accumulated in the field dtype.  Requires ``batch=1``
    and a Krylov/mg method; non-affine operator bodies raise instead of
    falling back to the interpreter.
    """
    if differentiable:
        if batch > 1:
            raise ValueError(
                "differentiable solves need batch=1 (vmap the returned "
                "solver for ensembles of gradients)"
            )
        from repro.solver.adjoint import make_differentiable_solver

        member_env = member_env or {}
        solve_fn = make_differentiable_solver(
            program,
            answer,
            method=method,
            backend="pallas" if backend is None else backend,
            tol=tol,
            maxiter=maxiter,
            steps=steps,
            precondition=precondition,
            mg_opts=mg_opts,
            return_info=True,
        )

        def step_fn(x0):
            coef = {
                n: member_env[n] for n in solve_fn.coef_names if n in member_env
            }
            return solve_fn(x0, coef)

        step_fn.symmetric_adjoint = solve_fn.symmetric_adjoint
        return step_fn
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    _check_precondition(method, precondition)
    if batch > 1 and (method == "mg" or precondition is not None):
        raise ValueError(
            "batched solves support the pointwise/Krylov methods only; "
            "method='mg' and precondition= need batch=1 (the multigrid "
            "hierarchy is not batch-aware)"
        )
    name = _answer_name(program, answer)
    release_program(program)
    (op_loop, op_ops), rhs_group = _split(program, name)
    group = _lower_operator(op_ops, name)
    bounds = _resolve_bounds(method, lambda_bounds, group, name)
    _check_jacobi(method, group)
    field = program.fields[name]
    mg = _build_mg(
        method,
        precondition,
        group,
        name,
        field.shape,
        field.dtype,
        backend,
        mg_opts,
    )
    op_step = _build_step(op_ops, op_loop, program, backend, batch=batch)
    rhs_step = (
        _build_step(rhs_group[1], rhs_group[0], program, backend, batch=batch)
        if rhs_group is not None
        else None
    )
    member_env = member_env or {}
    coef_names = [n for n in program.fields if n != name]

    def _coef(n):
        v = jnp.asarray(member_env.get(n, program.fields[n].init_data))
        if batch > 1 and v.ndim == 3:
            v = jnp.broadcast_to(v, (batch,) + v.shape)
        return v

    coefs = [_coef(n) for n in coef_names]
    shape = program.fields[name].shape
    mask = jnp.asarray(_written_mask(group, shape)) if method == "jacobi" else None

    # fp32 accumulation matches the wafer reductions; the fp64 safe-mode
    # rung widens the operands, and its dots must widen with them or the
    # re-solve inherits the very overflow it is escaping
    if batch > 1:

        def dot(a, b):
            # per-member reduction over the trailing (X, Y, Z) axes
            return jnp.sum(a * b, axis=(1, 2, 3), dtype=jnp.promote_types(a.dtype, jnp.float32))

    else:

        def dot(a, b):
            return jnp.sum(a * b, dtype=jnp.promote_types(a.dtype, jnp.float32))

    def dot2(a, b, c, d):
        from repro.kernels import ops as kops

        # the fused dual-dot kernel is a Mosaic win (one operand sweep); in
        # interpret mode (this CPU container) a pallas launch per reduction
        # only adds overhead — the BENCH_resident run caught PCG paying it
        # per iteration — so the correctness path keeps the jnp reductions
        if batch == 1 and backend == "pallas" and not kops._interpret():
            part = kops.dual_dot(a, b, c, d)  # one fused operand sweep
            return part[0], part[1]
        return dot(a, b), dot(c, d)

    run = _make_runner(
        method=method,
        name=name,
        coef_names=coef_names,
        op_step=op_step,
        rhs_step=rhs_step,
        dot=dot,
        dot2=dot2,
        tol=tol,
        maxiter=maxiter,
        steps=steps,
        bounds=bounds,
        group=group,
        jacobi_mask=lambda: mask,
        mg=mg,
        M=mg.apply if (mg is not None and precondition == "mg") else None,
        batch=batch,
    )
    # donate the state: its buffer seeds the while_loop carry in place (the
    # rest of the iteration is already allocation-free — XLA aliases the
    # carry); step_fn hands in a buffer the caller never owned.
    jitted = jax.jit(run, donate_argnums=0)

    def step_fn(x0):
        from repro.engine.executor import fresh_buffer

        return jitted(fresh_buffer(x0), *coefs)

    return step_fn


# ---------------------------------------------------------------------------
# sharded solver (shard_map + halo exchange + fused psum reductions)
# ---------------------------------------------------------------------------


def make_sharded_solver(
    program: Program,
    answer,
    mesh,
    *,
    method: str = "cg",
    backend: str = "pallas",
    tol: float = 1e-6,
    maxiter: int = 500,
    steps: int = 1,
    lambda_bounds: Optional[Tuple[float, float]] = None,
    precondition: Optional[str] = None,
    mg_opts=None,
):
    """Brick-sharded solver over ``mesh``; returns ``(step_fn, sharding)``.

    ``step_fn(x_global) -> (x, (iters, res, outcomes))`` runs the whole
    Krylov loop
    inside one ``shard_map``: operator applications halo-pad the brick
    (ICI ppermute) and run the fused kernel (``backend="pallas"``) or the
    roll interpreter per brick; dot products are one local pass plus ONE
    fused ``psum`` over both mesh axes.  Reduction-free methods (chebyshev,
    jacobi) run with zero collectives per iteration beyond the halo
    exchange.

    Multigrid coarsening halves extents, so below the fine level the grids
    stop dividing the mesh; the hierarchy therefore runs *gathered* — the
    classic all-coarse-levels-on-one-tile strategy, here one ``all_gather``
    per cycle and every device redundantly computing the (cheap) coarse
    work.  With ``precondition="mg"`` the fine-grid Krylov work (operator
    applications, fused-psum reductions) stays brick-sharded and only the
    preconditioner action gathers; with ``method="mg"`` the whole cycle
    iteration runs on the gathered field.
    """
    from repro.core.halo import local_moat_mask

    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    _check_precondition(method, precondition)
    name = _answer_name(program, answer)
    release_program(program)
    (op_loop, op_ops), rhs_group = _split(program, name)
    group = _lower_operator(op_ops, name)
    bounds = _resolve_bounds(method, lambda_bounds, group, name)
    _check_jacobi(method, group)

    ax_x, ax_y = mesh.axis_names[-2], mesh.axis_names[-1]
    mx, my = mesh.shape[ax_x], mesh.shape[ax_y]
    shapes = {n: f.shape for n, f in program.fields.items()}
    for n, (nx, ny, _) in shapes.items():
        if nx % mx or ny % my:
            raise ValueError(
                f"field {n} shape ({nx},{ny}) not divisible by mesh ({mx},{my})"
            )
    nx, ny, nz = shapes[name]
    bx, by = nx // mx, ny // my

    field = program.fields[name]
    mg = _build_mg(
        method,
        precondition,
        group,
        name,
        field.shape,
        field.dtype,
        backend,
        mg_opts,
    )

    def _gather(v):
        g = jax.lax.all_gather(v, ax_x, axis=0, tiled=True)
        return jax.lax.all_gather(g, ax_y, axis=1, tiled=True)

    def _brick(g):
        cx = jax.lax.axis_index(ax_x) * bx
        cy = jax.lax.axis_index(ax_y) * by
        sizes = (bx, by) + tuple(g.shape[2:])
        return jax.lax.dynamic_slice(g, (cx, cy) + (0,) * (g.ndim - 2), sizes)

    mesh_ctx = None if method == "mg" else (mx, my, ax_x, ax_y)
    op_step = _build_step(op_ops, op_loop, program, backend, mesh_ctx=mesh_ctx)
    rhs_step = (
        _build_step(rhs_group[1], rhs_group[0], program, backend, mesh_ctx=mesh_ctx)
        if rhs_group is not None
        else None
    )
    zwin = _z_window(group, nz) if method == "jacobi" else None

    spec = jax.sharding.PartitionSpec(ax_x, ax_y, None)
    rspec = jax.sharding.PartitionSpec()
    sharding = jax.sharding.NamedSharding(mesh, spec)
    coef_names = [n for n in program.fields if n != name]
    coefs = [
        jax.device_put(jnp.asarray(program.fields[n].init_data), sharding)
        for n in coef_names
    ]

    def _local_dot(a, b):
        return jnp.sum(a * b, dtype=jnp.float32)

    def _psum_dot(a, b):
        # joint-axis psum: ONE all-reduce over the whole mesh instead of two
        # chained single-axis reductions (§Perf heat-implicit iteration 1)
        return jax.lax.psum(jnp.sum(a * b, dtype=jnp.float32), (ax_x, ax_y))

    def _local_dot2(a, b, c, d):
        return _local_dot(a, b), _local_dot(c, d)

    def _psum_dot2(a, b, c, d):
        from repro.kernels import ops as kops

        # see make_solver's dot2: fused kernel on Mosaic only
        if backend == "pallas" and not kops._interpret():
            part = kops.dual_dot(a, b, c, d)  # fused local pass
        else:
            part = jnp.stack(
                [
                    jnp.sum(a * b, dtype=jnp.float32),
                    jnp.sum(c * d, dtype=jnp.float32),
                ]
            )
        part = jax.lax.psum(part, (ax_x, ax_y))  # ONE fused all-reduce
        return part[0], part[1]

    # method="mg" iterates on the gathered (replicated) field, so its
    # residual reduction is a plain local sum — identical on every device
    dot = _local_dot if method == "mg" else _psum_dot
    dot2 = _local_dot2 if method == "mg" else _psum_dot2
    M = None
    if mg is not None and precondition == "mg":
        M = lambda r: _brick(mg.apply(_gather(r)))

    run = _make_runner(
        method=method,
        name=name,
        coef_names=coef_names,
        op_step=op_step,
        rhs_step=rhs_step,
        dot=dot,
        dot2=dot2,
        tol=tol,
        maxiter=maxiter,
        steps=steps,
        bounds=bounds,
        group=group,
        jacobi_mask=lambda: (
            local_moat_mask(bx, by, ax_x, ax_y, mx, my) & jnp.asarray(zwin)
        ),
        mg=mg,
        M=M,
    )

    def _mg_local(x, *coef_args):
        out, aux = run(_gather(x), *[_gather(c) for c in coef_args])
        return _brick(out), aux

    local = _mg_local if method == "mg" else run

    from repro.core.jaxcompat import shard_map

    mapped = jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(spec,) * (1 + len(coef_names)),
            out_specs=(spec, (rspec, rspec, rspec)),
            check=False,
        ),
        donate_argnums=0,  # the state buffer seeds the Krylov carry in place
    )

    def step_fn(x_global):
        from repro.engine.executor import fresh_buffer

        return mapped(jax.device_put(fresh_buffer(x_global), sharding), *coefs)

    return step_fn, sharding


# ---------------------------------------------------------------------------
# recovery ladder (bounded, logged escalation on failed solves)
# ---------------------------------------------------------------------------


def _cast_program(program: Program, dtype) -> Program:
    """Shallow dtype-cast view of a recorded program (fp64 safe mode).

    Ops reference fields by name, so sharing the op list with replica
    ``Field`` objects (same names/shapes, cast dtype + init data) is enough
    to rebuild every solver at the new precision.
    """
    import copy

    clone = Program.__new__(Program)
    clone.fields = {}
    clone.ops = program.ops
    clone._loop_stack = []
    for n, f in program.fields.items():
        f2 = copy.copy(f)
        f2.init_data = np.asarray(f.init_data, dtype)
        f2.dtype = f2.init_data.dtype
        clone.fields[n] = f2
    return clone


def _fetch4(step_fn, x0):
    """Run one solver attempt and land its 4 outputs on the host."""
    x, (iters, res, outs) = step_fn(x0)
    return (
        np.asarray(jax.device_get(x)),
        np.asarray(jax.device_get(iters)),
        np.asarray(jax.device_get(res)),
        np.asarray(jax.device_get(outs)),
    )


def _record_attempt(trace, method, dtype, outs, iters, res, reason):
    trace.record(
        method,
        np.dtype(dtype).name,
        health.outcome_name(health.worst(outs)),
        int(np.sum(iters)),
        float(np.asarray(res).ravel()[-1]),
        reason,
    )


def _recover_solve(program, name, first, x0, policy, kwargs, member_env):
    """Drive the escalation ladder after a failed first attempt.

    Rungs (each at most once, every attempt logged): same-method restart
    from the current iterate on BREAKDOWN (a fresh BiCGSTAB shadow residual
    is the textbook cure), cg/pipecg → bicgstab escalation, one fp64
    safe-mode re-solve.  Returns ``((x, iters, res, outs), trace)`` on
    success; raises :class:`~repro.solver.health.NumericalFault` carrying
    the populated trace when the ladder is exhausted.
    """
    from repro.engine.stats import stats as engine_stats

    method = kwargs["method"]
    dtype = program.fields[name].dtype
    trace = health.RecoveryTrace()
    x, iters, res, outs = first
    _record_attempt(trace, method, dtype, outs, iters, res, "initial")

    def failed(o):
        return health.any_failure(o, on_maxiter=policy.on_maxiter)

    def _attempt(kw, prog, start, reason, env=None, cast=None):
        nonlocal x, iters, res, outs
        engine_stats.recovery_attempts += 1
        solver = make_solver(
            prog, name, member_env=member_env if env is None else env, **kw
        )
        x, iters, res, outs = _fetch4(solver, start)
        if cast is not None:
            x = x.astype(cast)
        _record_attempt(
            trace, kw["method"], prog.fields[name].dtype, outs, iters, res, reason
        )
        log.warning("solve recovery: %s", trace.summary()[-1])
        return not failed(outs)

    # rung 1: restart from the current iterate (BREAKDOWN only)
    restarts = 0
    while (
        failed(outs)
        and health.worst(outs) == health.BREAKDOWN
        and restarts < policy.max_restarts
    ):
        restarts += 1
        if _attempt(kwargs, program, x, f"restart {restarts} after BREAKDOWN"):
            return (x, iters, res, outs), trace

    # rung 2: method escalation (symmetric methods → bicgstab)
    if failed(outs) and policy.escalate and method in ("cg", "pipecg"):
        why = health.outcome_name(health.worst(outs))
        kw2 = dict(kwargs, method="bicgstab", precondition=None)
        if _attempt(kw2, program, x0, f"escalate {method}->bicgstab after {why}"):
            return (x, iters, res, outs), trace

    # rung 3: one fp64 safe-mode re-solve of the original system (the
    # x64 context covers both build and run — tracing happens at call time)
    if failed(outs) and policy.safe_mode_fp64 and dtype != np.float64:
        from jax.experimental import enable_x64

        why = health.outcome_name(health.worst(outs))
        p64 = _cast_program(program, np.float64)
        env64 = {k: np.asarray(v, np.float64) for k, v in member_env.items()}
        with enable_x64():
            ok = _attempt(
                kwargs,
                p64,
                np.asarray(x0, np.float64),
                f"fp64 safe mode after {why}",
                env=env64,
                cast=dtype,
            )
        if ok:
            return (x, iters, res, outs), trace

    engine_stats.numerical_faults += 1
    worst_name = health.outcome_name(health.worst(outs))
    # the taxonomy lands on stats even when the ladder is exhausted — a
    # fault must leave the same forensic trail a success does
    engine_stats.solve_outcomes = tuple(
        str(v) for v in np.unique(health.outcome_names(outs))
    )
    raise health.NumericalFault(
        f"solve({method}) failed with {worst_name} after "
        f"{len(trace.attempts)} attempt(s): {'; '.join(trace.summary())}",
        outcome=worst_name,
        trace=trace,
    )


# ---------------------------------------------------------------------------
# one-shot entry point (WFAInterface.solve lands here)
# ---------------------------------------------------------------------------


def solve(
    program: Program,
    answer,
    *,
    method: str = "cg",
    backend: Optional[str] = None,
    mesh=None,
    steps: int = 1,
    tol: float = 1e-6,
    maxiter: int = 500,
    lambda_bounds: Optional[Tuple[float, float]] = None,
    precondition: Optional[str] = None,
    mg_opts=None,
    return_info: bool = False,
    options=None,
    member_env=None,
):
    """Solve the recorded implicit system for ``answer``; returns the
    solution as a NumPy array (and a :class:`SolveInfo` when
    ``return_info=True``).

    Execution policy travels as ``options=RunOptions(...)`` — the legacy
    ``backend=`` / ``mesh=`` keywords are deprecation shims that warn once
    and forward (backend defaults to ``"pallas"``).  ``options.batch=B``
    solves a B-member ensemble in one masked Krylov loop: ``member_env``
    supplies per-member ``(B, X, Y, Z)`` stacks for the initial guess and/or
    coefficient fields (anything absent broadcasts from its init data), the
    returned solution is the ``(B, X, Y, Z)`` stack, converged members
    freeze bitwise while the loop runs to the slowest, and the per-member
    iteration counts land in ``SolveInfo.iterations`` (shape ``(steps, B)``)
    and ``repro.engine.stats.member_iterations``.

    The initial guess is the unknown field's init data (its Moat must carry
    the boundary values, as in the explicit path).  With ``mesh=`` the whole
    solve runs brick-sharded inside ``shard_map``.  ``method="mg"`` iterates
    geometric multigrid V/W-cycles; ``precondition="mg"`` accelerates
    CG/BiCGSTAB with one cycle per iteration — both keep iteration counts
    flat as the grid grows (see docs/solvers.md).

    ``options.differentiable=True`` routes through the
    implicit-function-theorem adjoint (:mod:`repro.solver.adjoint`): the
    eager result is numerically the same, and the underlying solver is
    reverse-mode differentiable — build it directly with
    ``make_solver(..., differentiable=True)`` (or
    :func:`repro.solver.adjoint.make_differentiable_solver`) to put
    ``jax.grad`` through the solve (see docs/adjoint.md).

    Example — the paper's BTCS heat system, multigrid-preconditioned::

        >>> import numpy as np
        >>> from repro.solver import record_btcs
        >>> T0 = np.full((17, 17, 9), 500.0, np.float32)
        >>> T0[1:-1, 1:-1, 0] = 300.0
        >>> wse, T = record_btcs(T0, 0.1)
        >>> x, info = wse.solve(T, method="cg", precondition="mg",
        ...                     backend="jit", tol=1e-6, return_info=True)
        >>> x.shape, bool(info.iterations[0] < 10)
        ((17, 17, 9), True)
    """
    from repro.engine.options import UNSET, resolve_options

    options = resolve_options(
        options,
        "wfa.solve",
        backend=UNSET if backend is None else backend,
        mesh=UNSET if mesh is None else mesh,
    )
    backend = options.resolved_backend("pallas")
    mesh = options.mesh
    batch = options.batch
    if mesh is not None and batch > 1:
        raise ValueError(
            "batched solves are single-device; drop mesh= or set batch=1"
        )
    if options.differentiable and mesh is not None:
        raise ValueError(
            "differentiable solves are single-device; drop mesh= (shard the "
            "forward solve only, or take gradients with mesh=None)"
        )
    name = _answer_name(program, answer)
    kwargs = dict(
        method=method,
        backend=backend,
        tol=tol,
        maxiter=maxiter,
        steps=steps,
        lambda_bounds=lambda_bounds,
        precondition=precondition,
        mg_opts=mg_opts,
    )
    member_env = member_env or {}
    if mesh is not None:
        step_fn, sharding = make_sharded_solver(program, name, mesh, **kwargs)
        x0 = jax.device_put(jnp.asarray(program.fields[name].init_data), sharding)
    else:
        step_fn = make_solver(
            program,
            name,
            batch=batch,
            member_env=member_env,
            differentiable=options.differentiable,
            **kwargs,
        )
        x0 = np.asarray(member_env.get(name, program.fields[name].init_data))
        if batch > 1 and x0.ndim == 3:
            x0 = np.broadcast_to(x0, (batch,) + x0.shape)
    x, iters, res, outs = _fetch4(step_fn, x0)
    trace = None
    recovery = options.recovery
    if recovery is not None and health.any_failure(
        outs, on_maxiter=recovery.on_maxiter
    ):
        if mesh is not None or batch > 1 or options.differentiable:
            # no escalation ladder off the plain path — still fail loud
            from repro.engine.stats import stats as engine_stats

            engine_stats.numerical_faults += 1
            trace = health.RecoveryTrace()
            _record_attempt(
                trace, method, program.fields[name].dtype, outs, iters, res,
                "initial",
            )
            worst_name = health.outcome_name(health.worst(outs))
            engine_stats.solve_outcomes = tuple(
                str(v) for v in np.unique(health.outcome_names(outs))
            )
            raise health.NumericalFault(
                f"solve({method}) failed with {worst_name} (no recovery "
                "ladder for sharded/batched/differentiable solves)",
                outcome=worst_name,
                trace=trace,
            )
        (x, iters, res, outs), trace = _recover_solve(
            program, name, (x, iters, res, outs), x0, recovery, kwargs, member_env
        )
    from repro.engine.stats import stats as engine_stats

    engine_stats.solve_outcomes = tuple(
        str(v) for v in np.unique(health.outcome_names(outs))
    )
    if batch > 1:
        engine_stats.ensemble_runs += 1
        engine_stats.ensemble_members += batch
        engine_stats.member_iterations = tuple(
            int(v) for v in iters.sum(axis=0)
        )
    if return_info:
        info = SolveInfo(
            method=method,
            backend=backend,
            iterations=iters,
            residual=res,
            outcomes=health.outcome_names(outs),
            recovery=trace,
        )
        return x, info
    return x

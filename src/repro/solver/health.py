"""Numerical-health taxonomy and guarded-iteration helpers.

Every iterative method in :mod:`repro.solver.krylov` carries a small
*health word* through its ``while_loop`` so failures are classified — and
stopped — instead of silently mislabelled.  The historic bug this layer
retires: a NaN residual makes ``rr > tol*tol`` evaluate False, so an
unguarded loop exits on its *first* poisoned iteration and reports the
garbage iterate as converged.  The guard costs **zero extra reductions**:
it only inspects scalars the iteration already computed (``rr``, the
BiCGSTAB recurrence coefficients).

Outcome taxonomy (int32 words inside jit, names at the Python boundary):

=============  =============================================================
``CONVERGED``  residual is finite and ``‖r‖ ≤ tol`` — the only success word
``MAXITER``    iteration budget exhausted with a finite residual
``NAN_RESIDUAL``  the residual norm became NaN/Inf (poisoned state or rhs)
``BREAKDOWN``  a Krylov recurrence denominator collapsed (BiCGSTAB ρ/ω)
``STAGNATED``  no new best residual for ``stagnation_window`` iterations
``DIVERGED``   residual grew ≥ ``divergence_factor`` × its best-so-far
=============  =============================================================

:class:`RecoveryPolicy` + :class:`RecoveryTrace` drive the bounded,
logged escalation ladder (restart → method escalation → fp64 safe mode)
run by :func:`repro.solver.api.solve`; :class:`NumericalFault` is the
terminal signal — the service tier fails such requests fast and never
retries them (a deterministic re-run would repoison).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

# -- outcome codes (int32 words carried through jitted loops) ---------------

RUNNING = -1  # internal: loop still iterating (never escapes classify())
CONVERGED = 0
MAXITER = 1
NAN_RESIDUAL = 2
BREAKDOWN = 3
STAGNATED = 4
DIVERGED = 5

OUTCOME_NAMES = (
    "CONVERGED",
    "MAXITER",
    "NAN_RESIDUAL",
    "BREAKDOWN",
    "STAGNATED",
    "DIVERGED",
)

#: hard numerical failures — anything here means the iterate is not to be
#: trusted; MAXITER is "ran out of budget" and only escalates when the
#: policy opts in (``RecoveryPolicy.on_maxiter``)
FAILURES = (NAN_RESIDUAL, BREAKDOWN, STAGNATED, DIVERGED)

#: below this magnitude a BiCGSTAB recurrence scalar (ρ, (r0, v)) counts as
#: a serious breakdown: legit fp32 solves keep these ≥ ‖r‖²-scale (≫ 1e-25)
#: right up to the tolerance exit
BREAKDOWN_TINY = 1e-25


def outcome_name(code) -> str:
    """Python-side name for one outcome word."""
    code = int(code)
    if code == RUNNING:
        return "RUNNING"
    return OUTCOME_NAMES[code]


def outcome_names(codes) -> np.ndarray:
    """Vectorized :func:`outcome_name` — (steps,) or (steps, B) arrays."""
    arr = np.asarray(codes)
    return np.vectorize(outcome_name, otypes=["U12"])(arr)


def is_failure(code, *, on_maxiter: bool = False) -> bool:
    """True when this outcome word needs recovery (host-side, scalar)."""
    code = int(code)
    return code in FAILURES or (on_maxiter and code == MAXITER)


def any_failure(codes, *, on_maxiter: bool = False) -> bool:
    """True when any outcome in an array needs recovery (host-side)."""
    return any(
        is_failure(c, on_maxiter=on_maxiter) for c in np.asarray(codes).ravel()
    )


def worst(codes) -> int:
    """Most severe outcome in an array (severity = taxonomy order)."""
    severity = (MAXITER, STAGNATED, DIVERGED, BREAKDOWN, NAN_RESIDUAL)
    flat = [int(c) for c in np.asarray(codes).ravel()]
    for code in reversed(severity):
        if code in flat:
            return code
    return CONVERGED


# -- in-loop guard ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Thresholds for the in-loop divergence/stagnation windows.

    Defaults are deliberately loose — a legitimate Krylov solve riding an
    fp32 rounding floor must never trip them (BiCGSTAB residuals oscillate,
    CG plateaus near tolerance); they exist to stop *hopeless* iterations
    from burning the full ``maxiter`` budget.
    """

    divergence_factor: float = 1e4  # rr > factor × best-so-far ⇒ DIVERGED
    stagnation_window: int = 200  # iterations without a new best ⇒ STAGNATED


DEFAULT_GUARD = GuardConfig()


def guard_init(rr):
    """Initial guard carry for a loop observing residual scalar(s) ``rr``.

    Works elementwise: a batched loop passes its (B,) per-member ``rr`` and
    gets (B,) guard state.  Returns ``(status, best_rr, since_best)``.
    """
    shape = jnp.shape(rr)
    status = jnp.full(shape, RUNNING, jnp.int32)
    # a non-finite *entry* residual is classified at exit (the loop never
    # runs); seed best with +inf so the comparisons below stay meaningful
    best = jnp.where(jnp.isfinite(rr), rr, jnp.inf)
    since = jnp.zeros(shape, jnp.int32)
    return (status, best, since)


def running(g):
    """Loop-condition term: True while no lane has tripped."""
    return jnp.all(g[0] == RUNNING)


def guard_update(g, rr_new, *, breakdown=None, where=None, config=None):
    """Advance the guard with this iteration's residual scalar(s).

    Zero extra reductions: ``rr_new`` (and the optional ``breakdown``
    predicate) are values the iteration already computed.  ``where`` masks
    the update for batched loops — frozen members keep their word bitwise.
    First failure wins: a tripped status never changes.
    """
    config = config or DEFAULT_GUARD
    status, best, since = g
    finite = jnp.isfinite(rr_new)
    improved = finite & (rr_new < best)
    since_new = jnp.where(improved, 0, since + 1).astype(jnp.int32)
    diverged = finite & (rr_new > config.divergence_factor * best)
    if config.stagnation_window > 0:
        stagnated = since_new >= config.stagnation_window
    else:
        stagnated = jnp.zeros_like(finite)
    # BREAKDOWN outranks the NaN it typically causes in the same iteration
    # (the collapsed denominator is the diagnosis, the NaN the symptom)
    cand = jnp.where(
        breakdown if breakdown is not None else False,
        BREAKDOWN,
        jnp.where(
            ~finite,
            NAN_RESIDUAL,
            jnp.where(diverged, DIVERGED, jnp.where(stagnated, STAGNATED, RUNNING)),
        ),
    ).astype(jnp.int32)
    status_new = jnp.where(status == RUNNING, cand, status)
    best_new = jnp.where(improved, rr_new, best)
    if where is not None:
        status_new = jnp.where(where, status_new, status)
        best_new = jnp.where(where, best_new, best)
        since_new = jnp.where(where, since_new, since)
    return (status_new, best_new, since_new)


def classify(g, rr, tol2):
    """Final outcome word(s) at loop exit (elementwise over (B,) lanes).

    Ordering is the safety contract: CONVERGED requires a *finite*
    residual at or below tolerance — no path can label a non-finite answer
    CONVERGED — then a tripped in-loop status (its diagnosis outranks the
    generic NaN label it may have caused), then NAN_RESIDUAL for an
    unclassified non-finite exit (e.g. poisoned entry state, where the
    loop never ran), then MAXITER.
    """
    status = g[0]
    finite = jnp.isfinite(rr)
    converged = finite & (rr <= tol2)
    return jnp.where(
        converged,
        CONVERGED,
        jnp.where(
            status != RUNNING,
            status,
            jnp.where(~finite, NAN_RESIDUAL, MAXITER),
        ),
    ).astype(jnp.int32)


def classify_fixed(rr, tol2):
    """Outcome word for a fixed-iteration method's end-of-run residual."""
    finite = jnp.isfinite(rr)
    return jnp.where(
        ~finite, NAN_RESIDUAL, jnp.where(rr <= tol2, CONVERGED, MAXITER)
    ).astype(jnp.int32)


# -- recovery policies ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Bounded escalation ladder for failed solves.

    Rungs run in order, each at most once (``max_restarts`` bounds the
    same-method restart), every attempt logged in a :class:`RecoveryTrace`;
    an exhausted ladder raises :class:`NumericalFault`.
    """

    max_restarts: int = 1  # same-method restart from the last iterate
    escalate: bool = True  # cg/pipecg → bicgstab (handles asymmetry)
    safe_mode_fp64: bool = True  # one fp64 re-solve as the last rung
    detile_explicit: bool = True  # explicit plans: retry k=1, overlap off
    on_maxiter: bool = False  # also escalate plain MAXITER exits


@dataclasses.dataclass
class RecoveryAttempt:
    """One rung of the ladder: what ran and how it ended."""

    method: str
    dtype: str
    outcome: str
    iterations: int
    residual: float
    reason: str  # why this attempt ran ("initial", "restart after …", …)


@dataclasses.dataclass
class RecoveryTrace:
    """Ordered log of every attempt a recovering solve made."""

    attempts: List[RecoveryAttempt] = dataclasses.field(default_factory=list)

    def record(self, method, dtype, outcome, iterations, residual, reason):
        self.attempts.append(
            RecoveryAttempt(
                method=str(method),
                dtype=str(dtype),
                outcome=str(outcome),
                iterations=int(iterations),
                residual=float(residual),
                reason=str(reason),
            )
        )

    @property
    def succeeded(self) -> bool:
        return bool(self.attempts) and self.attempts[-1].outcome == "CONVERGED"

    def summary(self) -> tuple:
        """Compact per-attempt strings for stats/ticket surfaces."""
        return tuple(
            f"{a.reason}: {a.method}/{a.dtype} -> {a.outcome} "
            f"({a.iterations} it, r={a.residual:.3e})"
            for a in self.attempts
        )


class NumericalFault(RuntimeError):
    """A solve or explicit run produced numerically untrustworthy state.

    Raised when the recovery ladder is exhausted (implicit path) or an
    ``isfinite`` sentinel trips (explicit path).  Deterministic re-execution
    would repoison, so the service tier fails these fast and never retries.

    Attributes: ``outcome`` (taxonomy name), ``step`` (time-step index for
    explicit sentinels, else None), ``trace`` (:class:`RecoveryTrace` or
    None), ``last_good`` (the last finite state, explicit path only).
    """

    def __init__(
        self,
        message: str,
        *,
        outcome: Optional[str] = None,
        step: Optional[int] = None,
        trace: Optional[RecoveryTrace] = None,
        last_good=None,
    ):
        super().__init__(message)
        self.outcome = outcome
        self.step = step
        self.trace = trace
        self.last_good = last_good

"""Recording markers for implicit systems: ``Operator`` and ``Rhs``.

An implicit field equation ``A(x) = b`` enters the WFA frontend exactly like
an explicit update: inside ``with Operator():`` the user records the operator
stencil as a masked self-update of the unknown field, and inside
``with Rhs():`` the update that produces the right-hand side from the
current state.  The BTCS heat system (paper Eq. 3) reads::

    wse = WFAInterface()
    T = Field("T", init_data=T0)
    with Operator():                       # A = I − ωψ·S, identity Moat rows
        T[1:-1, 0, 0] = T[1:-1, 0, 0] - wpsi * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0] + T[1:-1, -1, 0]
            + T[1:-1, 0, 1] + T[1:-1, 0, -1])
    with Rhs():                            # b = ψ·Tⁿ (Moat rows carry Tⁿ)
        T[1:-1, 0, 0] = psi * T[1:-1, 0, 0]
    x = wse.solve(answer=T, method="cg", backend="pallas")

The masked-update semantics give the operator its identity rows for free:
cells outside the target z-slice or on the (X, Y) Moat keep the input value,
so ``A(v) = v`` there — exactly the boundary block of the paper's Eq. 3
matrix.  ``repro.solver.api`` compiles the recorded body through the same
IR → fused-Pallas pipeline as explicit programs and runs matrix-free Krylov
iterations (:mod:`repro.solver.krylov`) on top of it.

The markers subclass :class:`~repro.core.program.ForLoop` (with ``n = 1``)
so recording, grouping and compilation reuse the explicit-path machinery
unchanged; the ``role`` attribute is how the solver (and the ``make`` guard)
recognise them.
"""

from __future__ import annotations

from typing import Optional

from repro.core.program import ForLoop


class SolverMarker(ForLoop):
    """Base class for solver recording contexts (``role`` set by subclass)."""

    role: Optional[str] = None

    def __init__(self, name: Optional[str] = None):
        super().__init__(name or type(self).__name__.lower(), 1)


class Operator(SolverMarker):
    """Record the matrix-free operator body ``x ↦ A(x)`` (self-updates of
    the unknown field; linear in the unknown, identity on unwritten cells).

    Example — a damped-diffusion operator, solved with compiled CG:

    >>> import numpy as np
    >>> from repro.core import Field, WFAInterface
    >>> from repro.solver import Operator, Rhs
    >>> with WFAInterface() as wse:
    ...     T = Field("T", init_data=np.full((8, 8, 8), 1.0, np.float32))
    ...     with Operator():
    ...         T[1:-1, 0, 0] = T[1:-1, 0, 0] - 0.05 * (
    ...             T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
    ...             + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1])
    ...     with Rhs():
    ...         T[1:-1, 0, 0] = 0.625 * T[1:-1, 0, 0]
    >>> x = wse.solve(T, method="cg", backend="jit", tol=1e-6)
    >>> x.shape, bool(np.isfinite(x).all())
    ((8, 8, 8), True)
    """

    role = "operator"


class Rhs(SolverMarker):
    """Record the right-hand-side body ``state ↦ b`` (updates of the unknown
    field; unwritten cells carry the state value — the identity-row RHS).
    See :class:`Operator` for a complete recorded system."""

    role = "rhs"

"""Matrix-free Krylov and relaxation iterations, generic over ``(A, dot)``.

One implementation serves every operator-compilation path: the legacy BTCS
drivers in :mod:`repro.core.implicit` and the ``wfa.solve`` frontend both
dispatch here, on one chip or inside ``shard_map`` (the ``dot`` callable owns
the ``psum``), with the operator ``A`` supplied as a plain function — a
compiled fused Pallas kernel, the roll interpreter, or anything else.

Methods and their per-iteration reduction count (the paper's Eq. 16/17
latency term):

* :func:`cg`        — classic CG, 2 reductions (SPD operators);
* :func:`pipecg`    — Ghysels–Vanroose pipelined CG, 1 fused reduction
  overlapped with the next SpMV;
* :func:`bicgstab`  — van der Vorst BiCGSTAB, 4 reductions, 2 operator
  applications (the workhorse for non-symmetric systems, e.g.
  variable-coefficient implicit diffusion);
* :func:`chebyshev` — reduction-free Chebyshev iteration (needs eigenvalue
  bounds of ``A``);
* :func:`jacobi`    — reduction-free Jacobi relaxation (needs the diagonal);
* :func:`stationary` — generic fixed-point iteration with a residual-norm
  stop — the driver behind ``method="mg"`` (one step = one V/W-cycle).

:func:`cg` and :func:`bicgstab` accept a preconditioner ``M`` (a linear
callable approximating ``A⁻¹`` — ``wfa.solve(precondition="mg")`` passes a
multigrid cycle from a zero guess); CG needs ``M`` symmetric positive
definite, BiCGSTAB is preconditioned from the right so any fixed linear
``M`` works.

Every method returns ``(x, iterations, ‖r‖, outcome)`` — the outcome is an
int32 word from the :mod:`repro.solver.health` taxonomy (``CONVERGED`` /
``MAXITER`` / ``NAN_RESIDUAL`` / ``BREAKDOWN`` / ``STAGNATED`` /
``DIVERGED``), per member (shape ``(B,)``) for the batched variants.  The
guard lives *inside* the ``while_loop`` carry at zero extra reductions: a
NaN residual used to make ``rr > tol*tol`` False, silently exiting the
loop and reporting the poisoned iterate as converged — now every exit is
classified, and hopeless iterations (divergence, stagnation, BiCGSTAB
breakdown) stop early instead of burning the ``maxiter`` budget.  Pass
``guard=GuardConfig(...)`` to tune the windows.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.solver import health

_TINY = 1e-30


def _nonzero(d):
    """Clamp a denominator away from zero, keeping its sign (fp32 guard)."""
    return jnp.where(jnp.abs(d) < _TINY, jnp.where(d < 0, -_TINY, _TINY), d)


def cg(
    A: Callable,
    dot: Callable,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 500,
    M: Callable = None,
    dot2: Callable = None,
    guard: health.GuardConfig = None,
):
    """Classic CG.  Two reductions per iteration: (p, Ap) and (r, r) — the
    paper's benchmarked bottleneck.

    With a preconditioner ``M`` (symmetric positive definite, e.g. one
    multigrid cycle from a zero guess) this is standard PCG, stopping still
    on the *true* residual norm so iteration counts stay comparable to the
    plain method.  The two M-side reductions (r, z) and (r, r) are fused
    through ``dot2(a, b, c, d) -> (a·b, c·d)`` when the caller provides it
    (sharded backends: ONE ``psum`` instead of two — the Eq. 16 latency
    term), falling back to two ``dot`` calls otherwise.  All loop state
    lives in the ``while_loop`` carry, which XLA buffer-aliases in place —
    callers donate their entry buffers (``jax.jit(...,
    donate_argnums=...)``) so the whole iteration is allocation-free.
    """
    guard = guard or health.DEFAULT_GUARD
    if M is None:
        r = b - A(x0)
        p = r
        rr = dot(r, r)
        g0 = health.guard_init(rr)

        def cond(s):
            x, r, p, rr, i, g = s
            return health.running(g) & (rr > tol * tol) & (i < maxiter)

        def body(s):
            x, r, p, rr, i, g = s
            Ap = A(p)
            pAp = dot(p, Ap)  # reduction 1
            alpha = rr / pAp
            x = x + alpha * p
            r = r - alpha * Ap
            rr_new = dot(r, r)  # reduction 2 (overlaps x-update)
            beta = rr_new / rr
            p = r + beta * p
            g = health.guard_update(g, rr_new, config=guard)
            return (x, r, p, rr_new, i + 1, g)

        x, r, p, rr, i, g = jax.lax.while_loop(cond, body, (x0, r, p, rr, 0, g0))
        return x, i, jnp.sqrt(rr), health.classify(g, rr, tol * tol)

    if dot2 is None:
        dot2 = lambda a, b_, c, d: (dot(a, b_), dot(c, d))  # noqa: E731
    r = b - A(x0)
    z = M(r)
    p = z
    rz, rr = dot2(r, z, r, r)
    g0 = health.guard_init(rr)

    def pcond(s):
        x, r, p, rz, rr, i, g = s
        return health.running(g) & (rr > tol * tol) & (i < maxiter)

    def pbody(s):
        x, r, p, rz, rr, i, g = s
        Ap = A(p)
        alpha = rz / _nonzero(dot(p, Ap))
        x = x + alpha * p
        r = r - alpha * Ap
        z = M(r)
        rz_new, rr_new = dot2(r, z, r, r)  # ONE fused reduction
        beta = rz_new / _nonzero(rz)
        p = z + beta * p
        g = health.guard_update(g, rr_new, config=guard)
        return (x, r, p, rz_new, rr_new, i + 1, g)

    x, r, p, rz, rr, i, g = jax.lax.while_loop(
        pcond, pbody, (x0, r, p, rz, rr, 0, g0)
    )
    return x, i, jnp.sqrt(rr), health.classify(g, rr, tol * tol)


def pipecg(
    A: Callable,
    dot2: Callable,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 500,
    guard: health.GuardConfig = None,
):
    """Ghysels–Vanroose pipelined CG: ONE fused reduction per iteration,
    overlapped with the next SpMV.

    ``dot2(a, b, c, d)`` returns (a·b, c·d) in a single reduction — sharded
    backends implement it as one ``psum`` of a length-2 vector, halving the
    Eq. 16 latency term; XLA then schedules ``n = A w`` while it completes.
    """
    guard = guard or health.DEFAULT_GUARD
    r = b - A(x0)
    w_ = A(r)
    zero = jnp.zeros_like(b)
    rr0 = dot2(r, r, r, r)[0]  # true entry residual (warm-start guard)
    replace_every = 25  # periodic residual replacement (fp32 drift)

    def body2(s):
        x, r, w_, z, p, sv, gamma_prev, alpha_prev, i, fresh, g = s
        gamma, delta = dot2(r, r, w_, r)  # fused reduction
        n = A(w_)  # overlapped SpMV
        beta = jnp.where(fresh, 0.0, gamma / gamma_prev)
        denom = delta - beta * gamma / jnp.where(fresh, 1.0, alpha_prev)
        # fp32 pipelined recurrences can hit a vanishing denominator near
        # convergence; clamp to keep the iterate finite (cond exits next).
        denom = _nonzero(denom)
        alpha = gamma / denom
        z = n + beta * z
        p = r + beta * p
        sv = w_ + beta * sv
        x = x + alpha * p
        r = r - alpha * sv
        w_ = w_ - alpha * z
        # residual replacement: resync the recurred r/w with the true
        # residual every k iterations (Cools & Vanroose) — two extra SpMVs,
        # amortised 2/k, restores attainable accuracy at warm starts.
        do = (i + 1) % replace_every == 0
        r, w_ = jax.lax.cond(
            do,
            lambda x, r, w_: (b - A(x), A(b - A(x))),
            lambda x, r, w_: (r, w_),
            x,
            r,
            w_,
        )
        g = health.guard_update(g, gamma, config=guard)
        return (x, r, w_, z, p, sv, gamma, alpha, i + 1, do, g)

    def cond2(s):
        gamma_prev, i, g = s[6], s[8], s[10]
        # gamma_prev is ‖r‖² of the previous iterate (true rr0 at entry)
        return health.running(g) & (gamma_prev > tol * tol) & (i < maxiter)

    s0 = (
        x0,
        r,
        w_,
        zero,
        zero,
        zero,
        rr0,
        jnp.asarray(1.0, rr0.dtype),  # alpha carries the dot's dtype
        jnp.asarray(0, jnp.int32),
        jnp.asarray(True),
        health.guard_init(rr0),
    )
    out = jax.lax.while_loop(cond2, body2, s0)
    x, i, g = out[0], out[8], out[10]
    # one extra reduction per *solve* (not per iteration): the recurred
    # residual drifts, so classify on the recomputed true norm
    rr = dot2(out[1], out[1], out[1], out[1])[0]
    return x, i, jnp.sqrt(rr), health.classify(g, rr, tol * tol)


def bicgstab(
    A: Callable,
    dot: Callable,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 500,
    M: Callable = None,
    guard: health.GuardConfig = None,
):
    """van der Vorst BiCGSTAB — matrix-free, no transpose applications.

    The paper's workhorse for non-symmetric systems (upwind advection,
    variable-coefficient implicit diffusion).  Two operator applications and
    four reductions per iteration; the ``dot`` callable owns the all-reduce,
    so the same code runs on 1 chip or a full mesh.  An optional ``M``
    preconditions from the *right* (``A M y = b``, ``x = M y``), so the
    recurrence sees ``A∘M`` while the residual — and the stopping test —
    stay those of the original system; with ``M = None`` the applications
    reduce to the textbook method exactly.

    Breakdown detection rides the scalars the recurrence already computes:
    ``|ρ| ≤ tiny`` or ``|(r0, v)| ≤ tiny`` (the Lanczos/pivot breakdowns)
    or a zero ω with an unconverged residual (the stabilizer stall) trips
    ``BREAKDOWN`` — the standard cure is a restart from the current
    iterate, which the recovery ladder applies.
    """
    guard = guard or health.DEFAULT_GUARD
    if M is None:
        M = lambda v: v
    r = b - A(x0)
    r0 = r
    zero_v = jnp.zeros_like(b)
    rr = dot(r, r)
    # scalar recurrences carry the dot's accumulation dtype (fp64 adjoint
    # solves pass full-precision dots; the fp32 default is unchanged)
    one = jnp.asarray(1.0, rr.dtype)

    def cond(s):
        rr, i, g = s[7], s[8], s[9]
        return health.running(g) & (rr > tol * tol) & (i < maxiter)

    def body(s):
        x, r, p, v, rho, alpha, omega, rr, i, g = s
        rho_new = dot(r0, r)
        beta = (rho_new / _nonzero(rho)) * (alpha / _nonzero(omega))
        p = r + beta * (p - omega * v)
        ph = M(p)
        v = A(ph)
        r0v = dot(r0, v)
        alpha = rho_new / _nonzero(r0v)
        sv = r - alpha * v
        sh = M(sv)
        t = A(sh)
        tt = dot(t, t)
        # t == 0 means sv == 0 (converged mid-iteration): take omega = 0 so
        # the update degenerates to the stable half-step.
        omega = jnp.where(tt > 0.0, dot(t, sv) / _nonzero(tt), 0.0)
        x = x + alpha * ph + omega * sh
        r = sv - omega * t
        rr_new = dot(r, r)
        breakdown = (
            (jnp.abs(rho_new) <= health.BREAKDOWN_TINY)
            | (jnp.abs(r0v) <= health.BREAKDOWN_TINY)
            | ((omega == 0.0) & (rr_new > tol * tol))
        )
        g = health.guard_update(g, rr_new, breakdown=breakdown, config=guard)
        return (x, r, p, v, rho_new, alpha, omega, rr_new, i + 1, g)

    s0 = (x0, r, zero_v, zero_v, one, one, one, rr, 0, health.guard_init(rr))
    out = jax.lax.while_loop(cond, body, s0)
    x, rr, i, g = out[0], out[7], out[8], out[9]
    return x, i, jnp.sqrt(rr), health.classify(g, rr, tol * tol)


def stationary(
    step: Callable,
    rnorm2: Callable,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 100,
    ref2=None,
    guard: health.GuardConfig = None,
):
    """Fixed-point iteration ``x ← step(x)`` with a residual-norm stop.

    The outer driver for ``method="mg"``: ``step`` is one V/W-cycle and
    ``rnorm2(x)`` the squared fine-level residual norm (whose ``dot`` owns
    the all-reduce when sharded).  Returns ``(x, iterations, ‖r‖,
    outcome)`` like the Krylov methods, so ``SolveInfo`` reporting is
    uniform.

    The stop is *relative* — ``‖r‖ ≤ tol·√ref2`` with ``ref2`` the squared
    norm of the right-hand side (falling back to the entry residual) —
    because ``rnorm2`` is the true residual recomputed each cycle: an
    absolute fp32 criterion would stagnate at the rounding floor that
    Krylov methods sail past on their recurred (drifting) residuals, and a
    reference to the entry residual would over-demand at warm starts.  A
    zero reference (all-zero RHS) also falls back to the entry residual so
    the loop cannot spin to ``maxiter`` on a solved system.
    """
    guard = guard or health.DEFAULT_GUARD
    rr0 = rnorm2(x0)
    if ref2 is None:
        ref2 = rr0
    else:
        ref2 = jnp.where(ref2 > 0.0, ref2, rr0)

    def cond(s):
        x, rr, i, g = s
        return health.running(g) & (rr > tol * tol * ref2) & (i < maxiter)

    def body(s):
        x, rr, i, g = s
        x = step(x)
        rr = rnorm2(x)
        g = health.guard_update(g, rr, config=guard)
        return (x, rr, i + 1, g)

    x, rr, i, g = jax.lax.while_loop(
        cond, body, (x0, rr0, 0, health.guard_init(rr0))
    )
    return x, i, jnp.sqrt(rr), health.classify(g, rr, tol * tol * ref2)


def chebyshev(
    A: Callable,
    b,
    x0,
    lmin: float,
    lmax: float,
    *,
    iters: int = 500,
    dot: Callable = None,
    tol: float = 0.0,
):
    """Reduction-free Chebyshev iteration — zero collectives per iteration.

    ``lmin``/``lmax`` must bracket the spectrum of ``A`` (Gershgorin bounds
    from the lowered tap form, or user-supplied ``lambda_bounds``).  The
    optional ``dot`` is used ONLY for the final residual report (one
    reduction per solve, not per iteration) — sharded callers pass their
    ``psum``-owning dot so the reported norm is global, not one brick's.
    That same end-of-run residual classifies the outcome against ``tol``
    (with the default ``tol=0.0`` a finite completion reports MAXITER —
    "ran the budget" — which is the honest word for a fixed-count method).
    """
    theta = 0.5 * (lmax + lmin)
    delta = 0.5 * (lmax - lmin)
    sigma1 = theta / delta

    r = b - A(x0)
    d = r / theta
    x = x0 + d
    rho = 1.0 / sigma1

    def body(k, s):
        x, r, d, rho = s
        r = r - A(d)
        rho_new = 1.0 / (2.0 * sigma1 - rho)
        d = rho_new * rho * d + (2.0 * rho_new / delta) * r
        x = x + d
        return (x, r, d, rho_new)

    x, r, d, rho = jax.lax.fori_loop(0, iters, body, (x, r, d, rho))
    rr = jnp.sum(r * r, dtype=jnp.float32) if dot is None else dot(r, r)
    return x, iters, jnp.sqrt(rr), health.classify_fixed(rr, tol * tol)


# ---------------------------------------------------------------------------
# batched ensembles: per-member convergence masking
# ---------------------------------------------------------------------------
#
# The batched variants solve B independent systems stacked on a leading
# axis in ONE masked loop: ``A`` applies the operator to the whole
# (B, X, Y, Z) stack (the engine's batch-aware compiled step), ``dot``
# reduces per member to a (B,) vector, and every scalar recurrence runs
# elementwise over the batch.  The loop runs until the *slowest* member
# converges; members that finish early are **frozen bitwise** — all of
# their carried state is held with ``jnp.where(active, new, old)`` (never
# an arithmetic no-op like ``x + 0*p``, which is not bitwise-stable for
# signed zeros / inf lanes) — and each member's iteration count stops
# advancing the moment its own residual passes the tolerance.


def _bc(s, like):
    """Broadcast a (B,) per-member scalar over ``like``'s trailing axes."""
    return s[(...,) + (None,) * (like.ndim - 1)]


def cg_batched(
    A, dot, b, x0, *, tol: float = 1e-6, maxiter: int = 500,
    guard: health.GuardConfig = None,
):
    """Classic CG over a (B, ...) stack; ``dot`` must reduce to (B,).

    Returns ``(x, iterations, ‖r‖, outcomes)`` with per-member (B,)
    iteration counts, residual norms and outcome words.  A poisoned member
    (NaN residual) freezes immediately and reports ``NAN_RESIDUAL`` — it
    can no longer masquerade as converged — while healthy members run on
    bitwise-unperturbed (members never mix: dots reduce per member and the
    operator does not couple the batch axis).  No preconditioner: the only
    M the frontend builds (multigrid) is not batch-aware.
    """
    guard = guard or health.DEFAULT_GUARD
    r = b - A(x0)
    p = r
    rr = dot(r, r)
    it0 = jnp.zeros(rr.shape, jnp.int32)

    def cond(s):
        rr, i, g = s[3], s[5], s[6]
        return jnp.any((rr > tol * tol) & (g[0] == health.RUNNING)) & (i < maxiter)

    def body(s):
        x, r, p, rr, it, i, g = s
        active = (rr > tol * tol) & (g[0] == health.RUNNING)
        a4 = _bc(active, x)
        Ap = A(p)
        alpha = rr / _nonzero(dot(p, Ap))
        x = jnp.where(a4, x + _bc(alpha, x) * p, x)
        r_new = r - _bc(alpha, r) * Ap
        rr_new = dot(r_new, r_new)
        beta = rr_new / _nonzero(rr)
        p = jnp.where(a4, r_new + _bc(beta, p) * p, p)
        r = jnp.where(a4, r_new, r)
        g = health.guard_update(g, rr_new, where=active, config=guard)
        rr = jnp.where(active, rr_new, rr)
        return (x, r, p, rr, it + active.astype(jnp.int32), i + 1, g)

    s0 = (x0, r, p, rr, it0, jnp.asarray(0, jnp.int32), health.guard_init(rr))
    x, r, p, rr, it, _, g = jax.lax.while_loop(cond, body, s0)
    return x, it, jnp.sqrt(rr), health.classify(g, rr, tol * tol)


def pipecg_batched(
    A, dot2, b, x0, *, tol: float = 1e-6, maxiter: int = 500,
    guard: health.GuardConfig = None,
):
    """Pipelined CG over a (B, ...) stack; ``dot2`` reduces to two (B,)s.

    Same Ghysels–Vanroose recurrences as :func:`pipecg` run elementwise
    over the batch, including the periodic residual replacement (applied on
    the shared iteration clock, then masked so frozen members keep their
    converged state bitwise).  Per-member outcome words as in
    :func:`cg_batched`.
    """
    guard = guard or health.DEFAULT_GUARD
    r = b - A(x0)
    w_ = A(r)
    zero = jnp.zeros_like(b)
    rr0 = dot2(r, r, r, r)[0]  # (B,) true entry residuals
    replace_every = 25

    def body(s):
        x, r, w_, z, p, sv, rr, alpha_prev, it, i, fresh, g = s
        active = (rr > tol * tol) & (g[0] == health.RUNNING)
        a4 = _bc(active, x)
        gamma, delta = dot2(r, r, w_, r)
        n = A(w_)  # overlapped SpMV
        beta = jnp.where(fresh, 0.0, gamma / _nonzero(rr))
        denom = _nonzero(delta - beta * gamma / jnp.where(fresh, 1.0, alpha_prev))
        alpha = gamma / denom
        z_new = n + _bc(beta, z) * z
        p_new = r + _bc(beta, p) * p
        sv_new = w_ + _bc(beta, sv) * sv
        x = jnp.where(a4, x + _bc(alpha, x) * p_new, x)
        r_new = r - _bc(alpha, r) * sv_new
        w_new = w_ - _bc(alpha, w_) * z_new
        do = (i + 1) % replace_every == 0
        r_new, w_new = jax.lax.cond(
            do,
            lambda x, r_, w: (b - A(x), A(b - A(x))),
            lambda x, r_, w: (r_, w),
            x,
            r_new,
            w_new,
        )
        r = jnp.where(a4, r_new, r)
        w_ = jnp.where(a4, w_new, w_)
        z = jnp.where(a4, z_new, z)
        p = jnp.where(a4, p_new, p)
        sv = jnp.where(a4, sv_new, sv)
        # gamma is ‖r‖² *before* this update — the same one-iteration lag the
        # unbatched cond() has — so a member freezes one step after crossing
        g = health.guard_update(g, gamma, where=active, config=guard)
        rr = jnp.where(active, gamma, rr)
        alpha_prev = jnp.where(active, alpha, alpha_prev)
        return (x, r, w_, z, p, sv, rr, alpha_prev,
                it + active.astype(jnp.int32), i + 1, do, g)

    def cond(s):
        rr, i, g = s[6], s[9], s[11]
        return jnp.any((rr > tol * tol) & (g[0] == health.RUNNING)) & (i < maxiter)

    s0 = (
        x0,
        r,
        w_,
        zero,
        zero,
        zero,
        rr0,
        jnp.ones(rr0.shape, jnp.float32),
        jnp.zeros(rr0.shape, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(True),
        health.guard_init(rr0),
    )
    out = jax.lax.while_loop(cond, body, s0)
    x, it, g = out[0], out[8], out[11]
    rr = dot2(out[1], out[1], out[1], out[1])[0]
    return x, it, jnp.sqrt(rr), health.classify(g, rr, tol * tol)


def bicgstab_batched(
    A, dot, b, x0, *, tol: float = 1e-6, maxiter: int = 500,
    guard: health.GuardConfig = None,
):
    """BiCGSTAB over a (B, ...) stack; ``dot`` must reduce to (B,).

    The ensemble workhorse: members may carry *different coefficients* (the
    operator reads per-member coefficient stacks), so each lane converges at
    its own rate and freezes independently.  Per-member outcome words as in
    :func:`cg_batched`, including per-member ρ/ω breakdown flags.
    """
    guard = guard or health.DEFAULT_GUARD
    r = b - A(x0)
    r0 = r
    rr = dot(r, r)
    ones = jnp.ones(rr.shape, jnp.float32)
    zero_v = jnp.zeros_like(b)

    def cond(s):
        rr, i, g = s[7], s[9], s[10]
        return jnp.any((rr > tol * tol) & (g[0] == health.RUNNING)) & (i < maxiter)

    def body(s):
        x, r, p, v, rho, alpha, omega, rr, it, i, g = s
        active = (rr > tol * tol) & (g[0] == health.RUNNING)
        a4 = _bc(active, x)
        rho_new = dot(r0, r)
        beta = (rho_new / _nonzero(rho)) * (alpha / _nonzero(omega))
        p_new = r + _bc(beta, p) * (p - _bc(omega, v) * v)
        v_new = A(p_new)
        r0v = dot(r0, v_new)
        alpha_new = rho_new / _nonzero(r0v)
        sv = r - _bc(alpha_new, r) * v_new
        t = A(sv)
        tt = dot(t, t)
        omega_new = jnp.where(tt > 0.0, dot(t, sv) / _nonzero(tt), 0.0)
        x = jnp.where(
            a4, x + _bc(alpha_new, x) * p_new + _bc(omega_new, x) * sv, x
        )
        r_new = sv - _bc(omega_new, sv) * t
        rr_new = dot(r_new, r_new)
        breakdown = (
            (jnp.abs(rho_new) <= health.BREAKDOWN_TINY)
            | (jnp.abs(r0v) <= health.BREAKDOWN_TINY)
            | ((omega_new == 0.0) & (rr_new > tol * tol))
        )
        r = jnp.where(a4, r_new, r)
        p = jnp.where(a4, p_new, p)
        v = jnp.where(a4, v_new, v)
        rho = jnp.where(active, rho_new, rho)
        alpha = jnp.where(active, alpha_new, alpha)
        omega = jnp.where(active, omega_new, omega)
        g = health.guard_update(
            g, rr_new, breakdown=breakdown, where=active, config=guard
        )
        rr = jnp.where(active, rr_new, rr)
        return (x, r, p, v, rho, alpha, omega, rr,
                it + active.astype(jnp.int32), i + 1, g)

    s0 = (x0, r, zero_v, zero_v, ones, ones, ones, rr,
          jnp.zeros(rr.shape, jnp.int32), jnp.asarray(0, jnp.int32),
          health.guard_init(rr))
    out = jax.lax.while_loop(cond, body, s0)
    g = out[10]
    return out[0], out[8], jnp.sqrt(out[7]), health.classify(g, out[7], tol * tol)


def jacobi(
    step: Callable,
    x0,
    *,
    iters: int = 500,
    rnorm2: Callable = None,
    tol: float = 0.0,
):
    """Reduction-free Jacobi relaxation: ``x ← step(x)`` for ``iters`` steps.

    ``step`` is the damped update ``x + D⁻¹(b − A x)`` (with the Moat pinned
    to ``b`` by the caller); for diagonally dominant operators it always
    converges — zero collectives per iteration and only one neighbour
    exchange, the cheapest member of the paper's "reduction-free implicit
    methods" family (Chebyshev converges faster per iteration).

    With ``rnorm2`` (squared true-residual norm, e.g. ``‖b − A x‖²`` with a
    ``psum``-owning dot when sharded) the end-of-run residual is reported
    and classified — one extra operator application per *solve*, not per
    iteration.  Without it the legacy contract holds (residual 0) and the
    outcome falls back to a finiteness check on the iterate itself, so a
    poisoned run still cannot masquerade as CONVERGED.
    """
    x = jax.lax.fori_loop(0, iters, lambda k, x: step(x), x0)
    if rnorm2 is not None:
        rr = rnorm2(x)
        return x, iters, jnp.sqrt(rr), health.classify_fixed(rr, tol * tol)
    finite = jnp.all(jnp.isfinite(x))
    outcome = jnp.where(
        finite, health.MAXITER, health.NAN_RESIDUAL
    ).astype(jnp.int32)
    return x, iters, jnp.zeros(()), outcome

"""Canonical implicit programs, recorded through the WFA frontend.

These are the systems the paper benchmarks, spelled as recorded programs so
every solver path (legacy ``btcs_solve``, ``wfa.solve``, sharded bricks)
compiles the *same* operator body through the *same* IR → codegen pipeline —
one operator-compilation path instead of two hand-wired ones.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.field import Field
from repro.core.program import Program, WFAInterface, scoped_program
from repro.solver.frontend import Operator, Rhs


def psi(w: float) -> float:
    """The BTCS diagonal normalization ψ = 1/(1 + 6ω) (paper Eq. 3)."""
    return 1.0 / (1.0 + 6.0 * w)


def _record_btcs_body(T, w: float) -> None:
    """Record A = I − ωψ·S (identity Moat rows) and b = ψ·Tⁿ onto ``T``."""
    wpsi = w * psi(w)
    with Operator():
        T[1:-1, 0, 0] = T[1:-1, 0, 0] - wpsi * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
            + T[1:-1, 0, -1]
        )
    with Rhs():
        T[1:-1, 0, 0] = psi(w) * T[1:-1, 0, 0]


def btcs_program(
    shape: Tuple[int, int, int],
    w: float,
    init_data: Optional[np.ndarray] = None,
    name: str = "T",
) -> Program:
    """The BTCS heat system (paper Eq. 3) as a recorded :class:`Program`.

    Safe to call while another program is active (uses a scoped recording
    context) — this is how ``repro.core.implicit`` builds its operator.
    """
    with scoped_program() as program:
        T = Field(name, init_data=init_data, shape=shape)
        _record_btcs_body(T, w)
    return program


def record_btcs(T0: np.ndarray, w: float, name: str = "T"):
    """User-facing variant: records the BTCS system into a fresh
    :class:`WFAInterface`; returns ``(wse, field)`` ready for
    ``wse.solve(answer=field, ...)``."""
    wse = WFAInterface()
    T = Field(name, init_data=T0)
    _record_btcs_body(T, w)
    return wse, T


def _record_poisson_body(T, F) -> None:
    """Record A = 6I − S (unit-spacing Dirichlet Laplacian) and b = F."""
    with Operator():
        T[1:-1, 0, 0] = 6.0 * T[1:-1, 0, 0] - (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
            + T[1:-1, 0, -1]
        )
    with Rhs():
        T[1:-1, 0, 0] = F[1:-1, 0, 0]


def poisson_program(
    shape: Tuple[int, int, int],
    rhs: Optional[np.ndarray] = None,
    init_data: Optional[np.ndarray] = None,
    name: str = "T",
) -> Program:
    """The Dirichlet Poisson system ``−∇²u = f`` (unit spacing) as a
    recorded :class:`Program` — the canonical stiff elliptic workload for
    the multigrid solver (``method="mg"`` / ``precondition="mg"``).

    ``init_data``'s Moat carries the boundary values (zero by default);
    ``rhs`` is the source term ``f`` on the interior.
    """
    with scoped_program() as program:
        T = Field(name, init_data=init_data, shape=shape)
        F = Field(name + "_rhs", init_data=rhs, shape=shape)
        _record_poisson_body(T, F)
    return program


def record_poisson(F0: np.ndarray, T0: Optional[np.ndarray] = None, name: str = "T"):
    """User-facing variant: records the Poisson system into a fresh
    :class:`WFAInterface`; returns ``(wse, field)`` ready for
    ``wse.solve(answer=field, method="mg", ...)``."""
    wse = WFAInterface()
    T = Field(name, init_data=T0, shape=F0.shape)
    F = Field(name + "_rhs", init_data=F0)
    _record_poisson_body(T, F)
    return wse, T


def record_varcoef_btcs(T0: np.ndarray, C0: np.ndarray, w: float, name: str = "T"):
    """Variable-coefficient implicit diffusion: A = I + ωC·(6I − S).

    ``C`` is a per-cell diffusivity field, so the operator row-scales the
    graph Laplacian and is **non-symmetric** — the BiCGSTAB use case.  The
    lowering pass turns the ``C·T`` products into two-tap terms, so
    ``backend="pallas"`` still fuses the whole application into one kernel.
    Returns ``(wse, T_field, C_field)``.
    """
    wse = WFAInterface()
    T = Field(name, init_data=T0)
    C = Field(name + "_coef", init_data=C0)
    with Operator():
        T[1:-1, 0, 0] = T[1:-1, 0, 0] + w * C[1:-1, 0, 0] * (
            6.0 * T[1:-1, 0, 0]
            - (
                T[2:, 0, 0]
                + T[:-2, 0, 0]
                + T[1:-1, 1, 0]
                + T[1:-1, -1, 0]
                + T[1:-1, 0, 1]
                + T[1:-1, 0, -1]
            )
        )
    return wse, T, C

"""repro.runtime — fault tolerance, stragglers, elastic scaling."""
from repro.runtime.fault import HeartbeatMonitor, ResilientLoop
from repro.runtime.elastic import remesh

__all__ = ["HeartbeatMonitor", "ResilientLoop", "remesh"]

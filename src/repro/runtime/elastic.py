"""Elastic scaling: reshard live state onto a different mesh.

The restart path after losing (or gaining) a slice: rebuild the mesh from
the surviving device set, re-derive shardings from the same logical-axis
rules, and ``device_put`` every leaf.  Works across any device-count change
as long as the new mesh axes still divide the sharded dims (the rules table
falls back to replication otherwise — see ShardingRules.mesh_axes).

Global-batch invariance on shrink is the caller's policy: either raise
``num_microbatches`` (keep tokens/step constant) or keep per-chip batch and
rescale LR; ``shrink_plan`` computes both options.
"""
from __future__ import annotations

import jax
import numpy as np


def remesh(tree, specs_tree, new_mesh):
    """Reshard every leaf of ``tree`` to ``specs_tree`` on ``new_mesh``."""
    def place(leaf, spec):
        arr = np.asarray(jax.device_get(leaf))
        return jax.device_put(
            arr, jax.sharding.NamedSharding(new_mesh, spec))
    return jax.tree.map(place, tree, specs_tree)


def shrink_plan(old_dp: int, new_dp: int, global_batch: int,
                num_microbatches: int):
    """Options for keeping training semantics across a DP-width change."""
    per_chip = global_batch // (old_dp * num_microbatches)
    # option A: same global batch, more microbatches
    mb_needed = -(-global_batch // (new_dp * per_chip))
    # option B: same microbatches, smaller global batch (+ LR rescale)
    new_global = new_dp * num_microbatches * per_chip
    return {
        "keep_global_batch": {"num_microbatches": mb_needed},
        "keep_microbatches": {"global_batch": new_global,
                              "lr_scale": new_global / global_batch},
    }

"""Fault tolerance: checkpoint/restart loop + straggler detection.

``ResilientLoop`` is the production driver contract: run steps; on any
device/runtime failure, restore the last checkpoint (params, optimizer,
data-stream position) and continue; give up after ``max_failures``
consecutive failures.  On real pods the failure signal is an XlaRuntimeError
from a dead host; here it is any exception from the step callable (tests
inject them).

``HeartbeatMonitor`` watches wall-clock step durations on a background
thread and calls ``on_straggler`` when a step exceeds
``threshold × trailing-median`` — at 1000-node scale this is the hook that
triggers hot-spare swap / re-slicing.  The monitor only observes; policy
lives with the caller.

``FaultInjector`` arms the engine's instrumentation hooks
(:mod:`repro.engine.hooks`) so tests, the service smoke run and chaos
drills can trigger the *real* failure paths: a raised exception at step N
(fires the service's restore-and-continue), an injected slowdown (fires
the straggler monitor), and a forced ``LoweringError`` during kernel
compilation (fires the logged interpreter degraded mode).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence


class HeartbeatMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 16,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.clock = clock  # injectable for deterministic tests
        self.durations: List[float] = []
        self.flagged: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._t0 = self.clock()
        self._step = step

    def end_step(self) -> None:
        if self._t0 is None:
            return
        dt = self.clock() - self._t0
        hist = self.durations[-self.window:]
        if hist:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.flagged.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, dt / med)
        self.durations.append(dt)
        self._t0 = None


class ResilientLoop:
    """Checkpoint/restart training driver.

    ``step_fn(state, batch) -> (state, metrics)`` must be functional;
    ``save_fn(step, state)`` / ``restore_fn() -> (state, step)`` bind the
    CheckpointManager; ``dataset`` must be seekable (``state()/restore()``).
    """

    def __init__(self, step_fn, save_fn, restore_fn, dataset, *,
                 ckpt_every: int = 100, max_failures: int = 3,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.dataset = dataset
        self.ckpt_every = ckpt_every
        self.max_failures = max_failures
        self.monitor = monitor or HeartbeatMonitor()
        self.failures = 0

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        metrics = None
        while step < start_step + num_steps:
            try:
                self.monitor.start_step(step)
                batch = self.dataset.next_batch()
                state, metrics = self.step_fn(state, batch)
                self.monitor.end_step()
                step += 1
                self.failures = 0
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except Exception:
                self.failures += 1
                if self.failures > self.max_failures:
                    raise
                state, step = self.restore_fn()
        return state, step, metrics


class InjectedFault(RuntimeError):
    """The exception :class:`FaultInjector` raises at an armed step."""


class FaultInjector:
    """Arm the engine's hooks with deterministic faults (a chaos drill).

    * ``fail_at`` — step numbers at which the step hook raises
      ``exc_type`` (each armed step fires **once**, so the service's
      restore-and-continue makes progress on retry — the semantics of a
      node dying and being replaced);
    * ``slow_at`` — ``{step: seconds}`` sleeps injected at the step hook
      (feeds the :class:`HeartbeatMonitor` straggler path);
    * ``fail_compile`` — loop names (or ``"*"`` for any) whose pallas
      compile attempt raises :class:`repro.compiler.LoweringError`, which
      ``try_compile`` turns into the counted, logged interpreter fallback
      — the degraded serving mode;
    * ``match_tag`` — restrict step faults to one hook tag (the service
      tags chunks with the request id), ``None`` hits any caller.

    Use as a context manager; hooks are installed on ``__enter__`` and the
    previous hooks restored on ``__exit__``.  All mutation is lock-guarded:
    service workers fire the hooks concurrently.
    """

    def __init__(self, fail_at: Sequence[int] = (),
                 exc_type=InjectedFault,
                 slow_at: Optional[Dict[int, float]] = None,
                 fail_compile: Sequence[str] = (),
                 match_tag: Optional[str] = None):
        self.exc_type = exc_type
        self.match_tag = match_tag
        self._fail_at = set(int(s) for s in fail_at)
        self._slow_at = dict(slow_at or {})
        self._fail_compile = set(fail_compile)
        self.fired: List[tuple] = []  # ("step"|"slow"|"compile", detail)
        self._lock = threading.Lock()
        self._prev_step = None
        self._prev_compile = None

    # -- hook bodies --------------------------------------------------------
    def on_step(self, step: int, tag: str = "") -> None:
        if self.match_tag is not None and tag != self.match_tag:
            return
        with self._lock:
            slow = self._slow_at.pop(step, None)
            fail = step in self._fail_at
            if fail:
                self._fail_at.remove(step)
            if slow is not None:
                self.fired.append(("slow", step, tag))
            if fail:
                self.fired.append(("step", step, tag))
        if slow is not None:
            time.sleep(slow)
        if fail:
            raise self.exc_type(f"injected fault at step {step} ({tag!r})")

    def on_compile(self, loop_name: Optional[str]) -> None:
        from repro.compiler import LoweringError

        with self._lock:
            hit = "*" in self._fail_compile or loop_name in self._fail_compile
            if hit:
                self._fail_compile.discard(loop_name)
                self._fail_compile.discard("*")
                self.fired.append(("compile", loop_name))
        if hit:
            raise LoweringError(
                f"injected compile failure for loop {loop_name!r}")

    # -- installation -------------------------------------------------------
    def install(self) -> "FaultInjector":
        from repro.engine import hooks

        self._prev_step = hooks.set_step_hook(self.on_step)
        self._prev_compile = hooks.set_compile_hook(self.on_compile)
        return self

    def uninstall(self) -> None:
        from repro.engine import hooks

        hooks.set_step_hook(self._prev_step)
        hooks.set_compile_hook(self._prev_compile)
        self._prev_step = self._prev_compile = None

    def __enter__(self) -> "FaultInjector":
        return self.install()

    def __exit__(self, *exc) -> bool:
        self.uninstall()
        return False

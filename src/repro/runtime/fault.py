"""Fault tolerance: checkpoint/restart loop + straggler detection.

``ResilientLoop`` is the production driver contract: run steps; on any
device/runtime failure, restore the last checkpoint (params, optimizer,
data-stream position) and continue; give up after ``max_failures``
consecutive failures.  On real pods the failure signal is an XlaRuntimeError
from a dead host; here it is any exception from the step callable (tests
inject them).

``HeartbeatMonitor`` watches wall-clock step durations on a background
thread and calls ``on_straggler`` when a step exceeds
``threshold × trailing-median`` — at 1000-node scale this is the hook that
triggers hot-spare swap / re-slicing.  The monitor only observes; policy
lives with the caller.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class HeartbeatMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 16,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.durations: List[float] = []
        self.flagged: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._t0 = time.monotonic()
        self._step = step

    def end_step(self) -> None:
        if self._t0 is None:
            return
        dt = time.monotonic() - self._t0
        hist = self.durations[-self.window:]
        if hist:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.flagged.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, dt / med)
        self.durations.append(dt)
        self._t0 = None


class ResilientLoop:
    """Checkpoint/restart training driver.

    ``step_fn(state, batch) -> (state, metrics)`` must be functional;
    ``save_fn(step, state)`` / ``restore_fn() -> (state, step)`` bind the
    CheckpointManager; ``dataset`` must be seekable (``state()/restore()``).
    """

    def __init__(self, step_fn, save_fn, restore_fn, dataset, *,
                 ckpt_every: int = 100, max_failures: int = 3,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.dataset = dataset
        self.ckpt_every = ckpt_every
        self.max_failures = max_failures
        self.monitor = monitor or HeartbeatMonitor()
        self.failures = 0

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        metrics = None
        while step < start_step + num_steps:
            try:
                self.monitor.start_step(step)
                batch = self.dataset.next_batch()
                state, metrics = self.step_fn(state, batch)
                self.monitor.end_step()
                step += 1
                self.failures = 0
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except Exception:
                self.failures += 1
                if self.failures > self.max_failures:
                    raise
                state, step = self.restore_fn()
        return state, step, metrics

"""repro — the WFA field-equation interface, batched to ensemble scale.

The curated public surface of the stack (``import repro as wfa``):

* **Frontend** — :class:`Field`, :class:`ForLoop`, :class:`WFAInterface`
  record a field program (the paper's Fig. 3 API);
* **Execution** — :func:`make` runs an explicit program, :func:`solve` a
  recorded implicit system, :func:`run_sharded` a 2-D device mesh; every
  policy knob (backend, mesh, time tiling, halo residency, ensemble batch)
  travels as one frozen :class:`RunOptions`;
* **Implicit systems** — :class:`Operator` / :class:`Rhs` mark the groups
  ``solve`` consumes; :class:`SolveInfo` reports convergence;
* **Ensembles** — :class:`Ensemble` stacks B scenarios behind one program;
  ``make``/``solve`` accept it transparently and advance all members per
  kernel launch (:mod:`repro.core.ensemble`);
* **Numerical health** — every iterative solve carries a failure-taxonomy
  word (``SolveInfo.outcomes``); :class:`RecoveryPolicy` arms the bounded
  escalation ladder and :class:`NumericalFault` is the terminal signal
  (:mod:`repro.solver.health`, ``docs/robustness.md``).

>>> import numpy as np
>>> import repro as wfa
>>> wse = wfa.WFAInterface()
>>> T = wfa.Field("T", init_data=np.ones((6, 6, 4), np.float32))
>>> with wfa.ForLoop("t", 2):
...     T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
>>> out = wfa.make(wse, T, options=wfa.RunOptions(backend="numpy"))
>>> float(out[2, 2, 1])
0.25

Everything else (engine internals, kernels, service tier) stays importable
under its own module path; attributes here resolve lazily (PEP 562) so
``import repro`` is cheap and cycle-free.
"""

from __future__ import annotations

__all__ = [
    "Ensemble",
    "Field",
    "ForLoop",
    "NumericalFault",
    "Operator",
    "RecoveryPolicy",
    "Rhs",
    "RunOptions",
    "SolveInfo",
    "WFAInterface",
    "make",
    "make_differentiable_solver",
    "run_sharded",
    "solve",
]

_EXPORTS = {
    "Ensemble": ("repro.core.ensemble", "Ensemble"),
    "Field": ("repro.core.field", "Field"),
    "ForLoop": ("repro.core.program", "ForLoop"),
    "NumericalFault": ("repro.solver.health", "NumericalFault"),
    "Operator": ("repro.solver.frontend", "Operator"),
    "RecoveryPolicy": ("repro.solver.health", "RecoveryPolicy"),
    "Rhs": ("repro.solver.frontend", "Rhs"),
    "RunOptions": ("repro.engine.options", "RunOptions"),
    "SolveInfo": ("repro.solver.api", "SolveInfo"),
    "WFAInterface": ("repro.core.program", "WFAInterface"),
    "make": ("repro.core.ensemble", "make"),
    "make_differentiable_solver": ("repro.solver.adjoint", "make_differentiable_solver"),
    "run_sharded": ("repro.core.halo", "run_sharded"),
    "solve": ("repro.core.ensemble", "solve"),
}


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache: next access skips this hook
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))

"""The WFA program compiler: backend="pallas" vs the interpreter backends.

Covers the acceptance surface: agreement with backend="numpy" on the Fig. 3
heat program, the variable-coefficient diffusion program, and the
advection–diffusion example (off-axis taps); exactly one fused pallas_call
per ForLoop body (via the kernel cache counters); interpreter fallback for
non-affine bodies; and the normalized negative-start z slices.
"""
import os
import sys

import numpy as np
import pytest

from conftest import ftcs_oracle, heat_init
from repro.compiler import (LoweringError, Tap, clear_cache, lower_group,
                            lower_update, reset_stats, stats)
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples"))
from advection_diffusion import build_advection_diffusion  # noqa: E402


def build_heat(T0, steps, c=0.1, name="T_n"):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    T = WSE_Array(name, init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0] + T[1:-1, 0, -1]
            + T[1:-1, -1, 0] + T[1:-1, 0, 1])
    return wse, T


def build_varcoef(T0, C0, steps):
    wse = WSE_Interface()
    T = WSE_Array("T_n", init_data=T0)
    C = WSE_Array("C_f", init_data=C0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] + C[1:-1, 0, 0] * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0] + T[1:-1, 0, -1]
            + T[1:-1, -1, 0] + T[1:-1, 0, 1] - 6.0 * T[1:-1, 0, 0])
    return wse, T


def unit_heat_init(shape=(10, 12, 14)):
    """Fig. 3 profile rescaled to O(1) so atol=1e-4 is meaningful."""
    return heat_init(shape) / 500.0


# -- backend agreement (acceptance: pallas == numpy to 1e-4) -----------------

def test_pallas_matches_numpy_fig3_heat():
    T0 = unit_heat_init()
    wse, T = build_heat(T0, steps=7)
    a = wse.make(answer=T, backend="pallas")
    wse, T = build_heat(T0, steps=7)
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-4)
    np.testing.assert_allclose(a, ftcs_oracle(T0, 0.1, 7), atol=1e-4)


def test_pallas_matches_numpy_fig3_heat_kelvin_scale():
    # the paper's 300-500 K field; 2e-4 matches the seed's jit-vs-numpy bound
    T0 = heat_init()
    wse, T = build_heat(T0, steps=7)
    a = wse.make(answer=T, backend="pallas")
    wse, T = build_heat(T0, steps=7)
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_pallas_matches_numpy_variable_coefficient(rng):
    T0 = unit_heat_init((8, 9, 10))
    C0 = rng.uniform(0.02, 0.15, size=T0.shape).astype(np.float32)
    wse, T = build_varcoef(T0, C0, steps=4)
    a = wse.make(answer=T, backend="pallas")
    wse, T = build_varcoef(T0, C0, steps=4)
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_pallas_matches_numpy_advection_diffusion(rng):
    T0 = rng.uniform(0.0, 1.0, size=(9, 11, 8)).astype(np.float32)
    wse, T = build_advection_diffusion(T0, steps=6)
    a = wse.make(answer=T, backend="pallas")
    wse, T = build_advection_diffusion(T0, steps=6)
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_pallas_boundaries_pinned():
    T0 = heat_init()
    wse, T = build_heat(T0, steps=10)
    out = wse.make(answer=T, backend="pallas")
    np.testing.assert_array_equal(out[0, :, :], T0[0, :, :])
    np.testing.assert_array_equal(out[-1, :, :], T0[-1, :, :])
    np.testing.assert_array_equal(out[:, 0, :], T0[:, 0, :])
    np.testing.assert_array_equal(out[:, :, 0], T0[:, :, 0])
    np.testing.assert_array_equal(out[:, :, -1], T0[:, :, -1])


# -- fusion accounting (acceptance: one fused pallas_call per loop body) -----

def test_fig3_compiles_to_one_fused_kernel():
    T0 = unit_heat_init()
    reset_stats()
    clear_cache()
    wse, T = build_heat(T0, steps=3)
    wse.make(answer=T, backend="pallas")
    assert stats.groups_fused == 1       # one ForLoop body → one fused step
    assert stats.kernels_built == 1      # exactly one pallas_call emitted
    assert stats.fallbacks == 0


def test_kernel_cache_reuses_compiled_program():
    T0 = unit_heat_init()
    reset_stats()
    clear_cache()
    wse, T = build_heat(T0, steps=3)
    wse.make(answer=T, backend="pallas")
    wse, T = build_heat(T0, steps=3)
    wse.make(answer=T, backend="pallas")
    assert stats.groups_fused == 2
    assert stats.kernels_built == 1      # second make served from the cache
    assert stats.cache_hits == 1


def test_multi_op_loop_body_fuses_into_one_kernel(rng):
    """Two coupled fields updated in one loop body → still one pallas_call
    (the second op reads the first's update only at dx = dy = 0)."""
    A0 = rng.uniform(0.0, 1.0, size=(8, 8, 6)).astype(np.float32)
    B0 = rng.uniform(0.0, 1.0, size=(8, 8, 6)).astype(np.float32)

    def build():
        wse = WSE_Interface()
        A = WSE_Array("A", init_data=A0)
        B = WSE_Array("B", init_data=B0)
        with WSE_For_Loop("t", 4):
            A[1:-1, 0, 0] = A[1:-1, 0, 0] + 0.1 * (
                B[1:-1, 1, 0] + B[1:-1, -1, 0] - 2.0 * B[1:-1, 0, 0])
            B[1:-1, 0, 0] = B[1:-1, 0, 0] + 0.05 * A[1:-1, 0, 0]
        return wse, A, B

    reset_stats()
    clear_cache()
    wse, A, B = build()
    a = wse.make(answer=A, backend="pallas")
    assert stats.kernels_built == 1 and stats.fallbacks == 0
    wse, A, B = build()
    b = wse.make(answer=A, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-4)


# -- interpreter fallback ----------------------------------------------------

def test_non_affine_body_falls_back_to_interpreter(rng):
    T0 = rng.uniform(0.5, 1.0, size=(8, 8, 6)).astype(np.float32)

    def build():
        wse = WSE_Interface()
        T = WSE_Array("T_nl", init_data=T0)
        with WSE_For_Loop("t", 3):
            T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 1, 0]
        return wse, T

    reset_stats()
    wse, T = build()
    a = wse.make(answer=T, backend="pallas")
    assert stats.fallbacks == 1 and stats.kernels_built == 0
    assert "non-affine" in stats.fallback_reasons[0]
    wse, T = build()
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_division_by_field_falls_back(rng):
    T0 = rng.uniform(0.5, 1.0, size=(6, 6, 5)).astype(np.float32)

    def build():
        wse = WSE_Interface()
        T = WSE_Array("T_div", init_data=T0)
        with WSE_For_Loop("t", 2):
            T[1:-1, 0, 0] = T[1:-1, 0, 0] / (T[1:-1, 1, 0] + 2.0)
        return wse, T

    reset_stats()
    wse, T = build()
    a = wse.make(answer=T, backend="pallas")
    assert stats.fallbacks == 1
    wse, T = build()
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_cross_tile_raw_hazard_falls_back(rng):
    """Second op reads the first op's written field through (dx, dy) ≠ 0 —
    unfusable read-after-write; the interpreter fallback must still agree."""
    A0 = rng.uniform(0.0, 1.0, size=(8, 8, 6)).astype(np.float32)

    def build():
        wse = WSE_Interface()
        A = WSE_Array("A", init_data=A0)
        B = WSE_Array("B", init_data=A0.copy())
        with WSE_For_Loop("t", 3):
            A[1:-1, 0, 0] = 0.5 * A[1:-1, 0, 0]
            B[1:-1, 0, 0] = B[1:-1, 0, 0] + 0.1 * A[1:-1, 1, 0]
        return wse, B

    reset_stats()
    wse, B = build()
    a = wse.make(answer=B, backend="pallas")
    assert stats.fallbacks == 1
    assert "cross-tile" in stats.fallback_reasons[0]
    wse, B = build()
    b = wse.make(answer=B, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-5)


# -- normalized z slices (negative starts) -----------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jit", "pallas"])
def test_negative_start_zslice_backends_agree(backend, rng):
    """On an n=10 column, T[-9:-1, 0, 0] IS the center slice T[1:-1, 0, 0];
    the negative-start spelling must evaluate identically on every backend —
    the record-time slice.indices normalization (the old _slice_delta took
    -9 - 1 = -10 as a z shift for this slice pair)."""
    T0 = rng.uniform(0.0, 1.0, size=(8, 9, 10)).astype(np.float32)

    def build(neg):
        center = slice(-9, -1) if neg else slice(1, -1)
        wse = WSE_Interface()
        T = WSE_Array("T_n", init_data=T0)
        with WSE_For_Loop("t", 4):
            T[1:-1, 0, 0] = 0.5 * T[center, 0, 0] + 0.25 * (
                T[2:, 0, 0] + T[:-2, 0, 0])
        return wse, T

    wse, T = build(neg=True)
    a = wse.make(answer=T, backend=backend)
    wse, T = build(neg=False)
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=2e-4)


# -- IR unit checks ----------------------------------------------------------

def _record_one(build_expr):
    wse = WSE_Interface()
    try:
        T = WSE_Array("T_ir", shape=(6, 6, 8))
        build_expr(T)
        return wse.program.ops
    finally:
        wse.__exit__()


def test_lowering_canonicalizes_fig3_to_seven_taps():
    ops = _record_one(lambda T: T.__setitem__(
        (slice(1, -1), 0, 0),
        0.4 * T[1:-1, 0, 0] + 0.1 * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0] + T[1:-1, 0, -1]
            + T[1:-1, -1, 0] + T[1:-1, 0, 1])))
    u = lower_update(ops[0])
    assert u.z0 == 1 and u.zlen == 6 and u.const == 0.0
    taps = {taps[0]: c for c, taps in u.terms}
    assert len(taps) == 7
    assert taps[Tap("T_ir", 0, 0, 0)] == pytest.approx(0.4)
    for tap in [Tap("T_ir", 1, 0, 0), Tap("T_ir", -1, 0, 0),
                Tap("T_ir", 0, 1, 0), Tap("T_ir", 0, -1, 0),
                Tap("T_ir", 0, 0, 1), Tap("T_ir", 0, 0, -1)]:
        assert taps[tap] == pytest.approx(0.1)


def test_lowering_folds_constants_and_merges_like_terms():
    ops = _record_one(lambda T: T.__setitem__(
        (slice(1, -1), 0, 0),
        (T[1:-1, 0, 0] * 0.5 + 0.5 * T[1:-1, 0, 0]) - 0.0 * T[1:-1, 1, 0]
        + (1.0 + 2.0)))
    u = lower_update(ops[0])
    assert u.const == pytest.approx(3.0)
    assert len(u.terms) == 1                    # like terms merged, 0·T dropped
    (coeff, taps), = u.terms
    assert taps == (Tap("T_ir", 0, 0, 0),) and coeff == pytest.approx(1.0)


def test_lowering_halo_radius_from_offsets():
    ops = _record_one(lambda T: T.__setitem__(
        (slice(1, -1), 0, 0), T[1:-1, 1, 1] + T[1:-1, -1, -1]))
    g = lower_group(ops)
    assert g.halo == 1
    assert g.fields_written() == ("T_ir",)


def test_lowering_rejects_degree_three():
    ops = _record_one(lambda T: T.__setitem__(
        (slice(1, -1), 0, 0),
        T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 0, 0]))
    with pytest.raises(LoweringError):
        lower_group(ops)

"""Checkpoint/restore: dtype-exact round trips + bitwise resume.

Two acceptance surfaces:

* the manager round-trips every dtype exactly — in particular bf16, which
  npz cannot store natively: it travels as its exact fp32 upcast with the
  original dtype in the sidecar metadata, so a bf16 target restores bitwise
  and a dtype-less target no longer keeps the silent fp32 widening;
* interrupted simulation equals uninterrupted simulation **bitwise**: run k
  steps with checkpointing, kill the service, restore in a fresh service
  instance and run the remaining n−k — identical to n straight steps (fp32
  in-process; fp64 and the sharded mesh in subprocesses).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from test_residency import run_py


# -- manager basics -----------------------------------------------------------


def test_save_restore_roundtrip_and_retention(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {
        "field": rng.normal(size=(6, 5, 4)).astype(np.float32),
        "nested": {"z": np.arange(10, dtype=np.int32)},
    }
    for step in (2, 4, 6):
        mgr.save(step, tree, extra={"tag": step})
    assert mgr.steps() == [4, 6]  # keep=2 dropped step 2
    assert mgr.latest_step() == 6
    out, step, extra = mgr.restore(tree)
    assert step == 6 and extra == {"tag": 6}
    assert (np.asarray(out["field"]) == tree["field"]).all()
    assert np.asarray(out["nested"]["z"]).dtype == np.int32


def test_bf16_roundtrip_is_bitwise(tmp_path, rng):
    """The satellite fix: bf16 leaves restore bit-for-bit into a bf16
    target instead of coming back as their fp32 npz encoding."""
    x = rng.normal(size=(8, 6)).astype(np.float32)
    tree = {"p": jnp.asarray(x, dtype=jnp.bfloat16)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    target = {"p": jax.ShapeDtypeStruct((8, 6), jnp.bfloat16)}
    out, _, _ = mgr.restore(target)
    assert out["p"].dtype == jnp.bfloat16
    assert (
        np.asarray(out["p"]).view(np.uint16)
        == np.asarray(tree["p"]).view(np.uint16)
    ).all()


def test_bf16_dtype_recorded_in_sidecar(tmp_path):
    tree = {"p": jnp.ones((3,), jnp.bfloat16), "q": jnp.ones((3,), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree)
    with open(os.path.join(str(tmp_path), "step-000000005", "meta.json")) as f:
        meta = json.load(f)
    assert meta["dtypes"] == {"p": "bfloat16", "q": "float32"}


def test_bf16_restore_into_fp32_target_has_no_extra_precision(tmp_path, rng):
    """A widening restore must go bf16 -> fp32 (exact), not keep the raw
    fp32 npz payload as if the checkpoint had fp32 precision."""
    x = rng.normal(size=(16,)).astype(np.float32)
    tree = {"p": jnp.asarray(x, dtype=jnp.bfloat16)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    out, _, _ = mgr.restore({"p": jax.ShapeDtypeStruct((16,), np.float32)})
    assert out["p"].dtype == np.float32
    ref = np.asarray(tree["p"]).astype(np.float32)  # exact upcast
    assert (np.asarray(out["p"]) == ref).all()


def test_async_save_then_restore(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": rng.normal(size=(32,)).astype(np.float32)}
    mgr.save(3, tree, blocking=False)
    out, step, _ = mgr.restore(tree)  # restore() waits for the writer
    assert step == 3
    assert (np.asarray(out["a"]) == tree["a"]).all()


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path)).restore({})


# -- interrupted == uninterrupted (service level) -----------------------------


def _serve_steps(svc, sig, steps, **kw):
    from repro.service import StepRequest

    return svc.submit(StepRequest(sig, steps=steps, **kw)).result(timeout=300)


def test_kill_restore_continue_is_bitwise_fp32(tmp_path):
    """k steps + checkpoint + service death + restore + (n-k) steps must
    equal n uninterrupted steps exactly — chunking, checkpointing and the
    restore path may not perturb a single bit."""
    from repro.service import PlanSignature, SimulationService, StepRequest

    sig = PlanSignature("heat3d", (12, 10, 6))
    n, k = 11, 4

    svc = SimulationService(
        workers=1, ckpt_root=str(tmp_path), default_chunk=3
    ).start()
    try:
        ref = _serve_steps(svc, sig, n)  # uninterrupted
        # phase 1: run only k steps, checkpointing under a stable key
        t = svc.submit(
            StepRequest(sig, steps=k, ckpt_every=2, ckpt_key="run")
        )
        t.result(timeout=300)
        assert t.stats.checkpoints == 2
    finally:
        svc.stop()  # the "kill": worker pool and plan cache are gone

    svc2 = SimulationService(
        workers=1, ckpt_root=str(tmp_path), default_chunk=3
    ).start()
    try:
        t = svc2.submit(
            StepRequest(
                sig, steps=n, ckpt_every=2, ckpt_key="run", resume=True
            )
        )
        out = t.result(timeout=300)
        assert t.stats.restores == 1
        assert t.stats.steps == n - k  # only the remainder was re-run
    finally:
        svc2.stop()
    assert out.dtype == ref.dtype
    assert (out == ref).all()


def test_restore_rejects_signature_mismatch(tmp_path):
    from repro.service import PlanSignature, SimulationService, StepRequest

    sig_a = PlanSignature("heat3d", (10, 10, 4))
    sig_b = PlanSignature("advdiff", (10, 10, 4))
    svc = SimulationService(workers=1, ckpt_root=str(tmp_path)).start()
    try:
        svc.submit(
            StepRequest(sig_a, steps=2, ckpt_every=2, ckpt_key="shared")
        ).result(timeout=300)
        t = svc.submit(
            StepRequest(
                sig_b, steps=4, ckpt_every=2, ckpt_key="shared", resume=True
            )
        )
        with pytest.raises(ValueError, match="checkpoint belongs to"):
            t.result(timeout=300)
    finally:
        svc.stop()


# -- fp64 + sharded variants (subprocesses) -----------------------------------

SERVICE_HELPERS = """
import numpy as np
from repro.service import PlanSignature, SimulationService, StepRequest

def serve(svc, sig, steps, **kw):
    t = svc.submit(StepRequest(sig, steps=steps, **kw))
    out = t.result(timeout=300)
    return out, t.stats
"""


def test_kill_restore_continue_is_bitwise_fp64(tmp_path):
    out = run_py(SERVICE_HELPERS + f"""
root = {str(tmp_path)!r}
# time_tile=2: the service snaps chunk/checkpoint boundaries to tile
# multiples, so the kill point (6) sits on a tile boundary and the launch
# sequence matches the uninterrupted run exactly
sig = PlanSignature("advdiff", (10, 12, 6), dtype="float64", time_tile=2)
n, k = 13, 6

svc = SimulationService(workers=1, ckpt_root=root, default_chunk=4).start()
ref, _ = serve(svc, sig, n)
assert ref.dtype == np.float64, ref.dtype
serve(svc, sig, k, ckpt_every=3, ckpt_key="run")  # granule snaps 3 -> 2
svc.stop()

svc = SimulationService(workers=1, ckpt_root=root, default_chunk=4).start()
out, st = serve(svc, sig, n, ckpt_every=3, ckpt_key="run", resume=True)
svc.stop()
assert st.restores == 1 and st.steps == n - k, vars(st)
assert (out == ref).all()
print("OK")
""", x64=True)
    assert "OK" in out


def test_kill_restore_continue_is_bitwise_sharded(tmp_path):
    out = run_py(SERVICE_HELPERS + f"""
from repro.core.jaxcompat import make_mesh

root = {str(tmp_path)!r}
mesh = make_mesh((2, 2), ("x", "y"))
sig = PlanSignature("heat3d", (12, 12, 6), dtype="float64")
n, k = 10, 4

svc = SimulationService(workers=1, ckpt_root=root, mesh=mesh).start()
ref, _ = serve(svc, sig, n)
serve(svc, sig, k, ckpt_every=2, ckpt_key="run")
svc.stop()

svc = SimulationService(workers=1, ckpt_root=root, mesh=mesh).start()
out, st = serve(svc, sig, n, ckpt_every=2, ckpt_key="run", resume=True)
svc.stop()
assert st.restores == 1 and st.steps == n - k, vars(st)
assert (out == ref).all()

# and the sharded stream equals the single-device stream bitwise
svc = SimulationService(workers=1, ckpt_root=root).start()
single, _ = serve(svc, sig, n)
svc.stop()
assert (single == ref).all()
print("OK")
""", devices=4, x64=True)
    assert "OK" in out

"""Differentiable WFA: adjoint solves + checkpointed reverse stepping.

The acceptance surface of the adjoint PR:

* ``transpose_taps`` is an involution on lowered operators, maps symmetric
  tap sets to themselves (``==`` — same kernel-cache key), and refuses
  nonlinear bodies;
* ``jax.grad`` through ``make_differentiable_solver`` matches central
  finite differences at fp64 for every adjoint method (CG / PipeCG /
  BiCGSTAB / mg / mg-preconditioned CG), with **zero new kernels** built
  during the backward pass for symmetric operators (the adjoint solve hits
  the forward kernel's cache entry) and zero interpreter fallbacks;
* non-affine operator bodies raise a clear ``ValueError`` under the
  differentiable path instead of silently falling back;
* the checkpointed reverse stepper (``differentiable_runner`` /
  ``ftcs_solve_checkpointed``) reproduces the non-checkpointed gradient to
  ~ulp across time-tile factors and remainder steps (hypothesis property +
  fixed cases);
* under AD the jitted runners stop donating (no donation markers in the
  HLO, caller arrays stay alive), and the sharded-mesh gradient matches
  single-device to a few ulps (fp64 subprocesses, as in test_residency).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import heat_init
from gradcheck import assert_gradcheck, gradcheck, probe_points
from repro.compiler import (
    LoweringError,
    Tap,
    lower_group,
    transpose_taps,
)
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.core.explicit import ftcs_solve, ftcs_solve_checkpointed
from repro.core.field import Field
from repro.core.program import ForLoop, scoped_program
from repro.engine import RunOptions, differentiable_runner, plan, single_runner
from repro.solver import ADJOINT_METHODS, make_differentiable_solver, make_solver
from repro.solver.api import _answer_name, _lower_operator, _split
from repro.solver.presets import btcs_program, poisson_program

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 1, x64: bool = False, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _lowered(program, answer="T"):
    name = _answer_name(program, answer)
    (_, op_ops), _ = _split(program, name)
    return _lower_operator(op_ops, name), name


# -- transpose_taps -----------------------------------------------------------


def test_transpose_taps_symmetric_fixed_point():
    """A symmetric operator's transpose is the *same* LoweredGroup — the
    equality the kernel cache keys on."""
    group, name = _lowered(btcs_program((8, 8, 6), 0.2))
    t = transpose_taps(group, name)
    assert t == group


def test_transpose_taps_involution_nonsymmetric():
    """transpose ∘ transpose == identity on an asymmetric tap set."""
    wse = WSE_Interface()
    T = WSE_Array("T", shape=(8, 8, 6))
    with WSE_For_Loop("t", 1):
        T[1:-1, 0, 0] = (
            T[1:-1, 0, 0]
            - 0.1 * (T[1:-1, 0, 0] - T[1:-1, -1, 0])
            + 0.05 * (T[2:, 1, 1] - T[1:-1, 0, 0])
        )
    ops = list(wse.program.ops)
    wse.__exit__()
    group, name = lower_group(ops), "T"
    t = transpose_taps(group, name)
    assert t != group
    assert transpose_taps(t, name) == group
    # the answer taps are mirrored, coefficient-free here
    fwd = sorted(tap for u in group.updates for _, taps in u.terms for tap in taps)
    bwd = sorted(
        Tap(tap.field, -tap.dz, -tap.dx, -tap.dy)
        for u in t.updates
        for _, taps in u.terms
        for tap in taps
    )
    assert fwd == bwd


def test_transpose_taps_shifts_coefficient_taps():
    """c·C[p]·x[p+o] transposes to c·C[p−o]·x[p−o] (coefficient taps move
    by −o_x relative to the row)."""
    wse = WSE_Interface()
    T = WSE_Array("T", shape=(8, 8, 6))
    C = WSE_Array("C", shape=(8, 8, 6))
    with WSE_For_Loop("t", 1):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] - 0.5 * C[1:-1, 0, 0] * T[2:, 0, 0]
    ops = list(wse.program.ops)
    wse.__exit__()
    group, name = lower_group(ops), "T"
    t = transpose_taps(group, name)
    assert transpose_taps(t, name) == group
    terms = [term for u in t.updates for term in u.terms if len(term[1]) == 2]
    (coeff, taps) = terms[0]
    by_field = {tap.field: tap for tap in taps}
    # the frontend's first index is the z-slice: T[2:, 0, 0] is a dz=+1 tap
    assert by_field["T"] == Tap("T", -1, 0, 0)
    assert by_field["C"] == Tap("C", -1, 0, 0)
    assert coeff == -0.5


def test_transpose_taps_rejects_nonlinear():
    wse = WSE_Interface()
    T = WSE_Array("T", shape=(8, 8, 6))
    with WSE_For_Loop("t", 1):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[2:, 0, 0]
    ops = list(wse.program.ops)
    wse.__exit__()
    group = lower_group(ops)
    with pytest.raises(LoweringError, match="not linear in the unknown"):
        transpose_taps(group, "T")


# -- differentiable-path validation errors ------------------------------------


def test_nonaffine_operator_raises_under_grad():
    """A body the lowering pass cannot canonicalize (degree three — would
    run on the interpreter fallback) must raise, not silently mis-gradient."""
    from repro.solver.frontend import Operator

    with scoped_program() as prog:
        T = Field("T", shape=(8, 8, 6), dtype=np.float32)
        with Operator():
            T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 0, 0]
    with pytest.raises(ValueError, match="affine"):
        make_differentiable_solver(prog, "T")


def test_nonlinear_operator_raises_under_grad():
    from repro.solver.frontend import Operator

    with scoped_program() as prog:
        T = Field("T", shape=(8, 8, 6), dtype=np.float32)
        with Operator():
            T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[2:, 0, 0]
    with pytest.raises(ValueError, match="nonlinear"):
        make_differentiable_solver(prog, "T")


def test_fixed_iteration_methods_rejected():
    prog = btcs_program((8, 8, 6), 0.2)
    with pytest.raises(ValueError, match="chebyshev"):
        make_differentiable_solver(prog, "T", method="chebyshev")
    assert "chebyshev" not in ADJOINT_METHODS


def test_make_solver_differentiable_rejects_batch():
    prog = btcs_program((8, 8, 6), 0.2)
    with pytest.raises(ValueError, match="batch=1"):
        make_solver(prog, "T", batch=2, differentiable=True)


def test_solve_differentiable_rejects_mesh():
    from repro.solver import solve

    prog = btcs_program((8, 8, 6), 0.2)
    with pytest.raises(ValueError, match="single-device"):
        solve(
            prog,
            "T",
            options=RunOptions(differentiable=True, mesh=object()),
        )


def test_solve_differentiable_route_matches_default():
    """options.differentiable=True must not change eager solve() numerics."""
    from repro.solver import record_btcs, solve

    T0 = heat_init((10, 10, 6))
    wse, T = record_btcs(T0, 0.2)
    x_ref = solve(wse.program, T, method="cg", tol=1e-6)
    wse2, T2 = record_btcs(T0, 0.2)
    x_diff = solve(
        wse2.program, T2, method="cg", tol=1e-6,
        options=RunOptions(differentiable=True),
    )
    assert (x_ref == x_diff).all()


# -- gradient checks (fp64 subprocesses) --------------------------------------

GRADCHECK_PREAMBLE = f"""
import sys
sys.path.insert(0, {os.path.join(ROOT, "tests")!r})
import jax
import jax.numpy as jnp
import numpy as np
from gradcheck import gradcheck
from repro.compiler import clear_cache, reset_stats, stats
from repro.core.field import Field
from repro.core.program import scoped_program
from repro.solver import make_differentiable_solver
from repro.solver.frontend import Operator, Rhs
from repro.solver.presets import _record_btcs_body, _record_poisson_body

rng = np.random.default_rng(0)
"""


def test_gradcheck_symmetric_methods_reuse_forward_kernel():
    """CG and PipeCG VJPs match FD at fp64; the backward solve builds ZERO
    new kernels (symmetric transpose == forward group) and hits the cache."""
    out = run_py(GRADCHECK_PREAMBLE + """
shape = (10, 12, 6)
w = jnp.asarray(rng.normal(size=shape))
x0 = jnp.asarray(rng.normal(size=shape))
for method in ("cg", "pipecg"):
    with scoped_program() as prog:
        T = Field("T", shape=shape, dtype=np.float64)
        _record_btcs_body(T, 0.3)
    clear_cache(); reset_stats()
    s = make_differentiable_solver(prog, "T", method=method, tol=1e-12, maxiter=400)
    assert s.symmetric_adjoint
    # ONE kernel serves forward and adjoint: the transposed group re-
    # canonicalized to the same cache key (the build's second compile hit)
    assert stats.kernels_built == 1, (method, stats.kernels_built)
    assert stats.cache_hits >= 1, method
    loss = jax.jit(lambda v, s=s: jnp.sum(w * s(v)))
    g = jax.grad(loss)(x0)
    jax.block_until_ready(g)
    assert stats.kernels_built == 1, (method, stats.kernels_built)
    assert stats.fallbacks == 0
    r = gradcheck(loss, x0, g, n_probes=8)
    assert r.ok, (method, str(r))
    print(method, "max scaled err", r.max_scaled_err)
print("PASS")
""", x64=True)
    assert "PASS" in out


def test_gradcheck_bicgstab_coefficient_and_state():
    """Non-symmetric variable-coefficient diffusion: the adjoint lowers the
    transposed tap set into ONE extra kernel, and both the coefficient-field
    and state gradients match FD at fp64."""
    out = run_py(GRADCHECK_PREAMBLE + """
shape = (10, 12, 6)
w = jnp.asarray(rng.normal(size=shape))
x0 = jnp.asarray(rng.normal(size=shape))
C0 = jnp.asarray(0.4 + 0.2 * rng.random(shape))
with scoped_program() as prog:
    T = Field("T", shape=shape, dtype=np.float64)
    C = Field("C", shape=shape, dtype=np.float64, init_data=np.asarray(C0))
    with Operator():
        T[1:-1, 0, 0] = T[1:-1, 0, 0] + 0.2 * C[1:-1, 0, 0] * (
            6.0 * T[1:-1, 0, 0]
            - (T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
               + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1]))
clear_cache(); reset_stats()
s = make_differentiable_solver(prog, "T", method="bicgstab", tol=1e-13, maxiter=600)
assert not s.symmetric_adjoint
assert stats.kernels_built == 2  # forward + transposed, both at build time
built = stats.kernels_built
loss_C = jax.jit(lambda c: jnp.sum(w * s(x0, {"C": c})))
g_C = jax.grad(loss_C)(C0)
assert stats.kernels_built == built  # grad reuses both cached kernels
r = gradcheck(loss_C, C0, g_C, n_probes=8)
assert r.ok, str(r)
loss_x = jax.jit(lambda v: jnp.sum(w * s(v, {"C": C0})))
g_x = jax.grad(loss_x)(x0)
r2 = gradcheck(loss_x, x0, g_x, n_probes=8)
assert r2.ok, str(r2)
assert stats.fallbacks == 0
print("PASS", r.max_scaled_err, r2.max_scaled_err)
""", x64=True)
    assert "PASS" in out


def test_gradcheck_multigrid_methods():
    """method='mg' and mg-preconditioned CG differentiate through the same
    cycle machinery (symmetric — reused verbatim in the backward solve)."""
    out = run_py(GRADCHECK_PREAMBLE + """
shape = (12, 12, 8)
F0 = rng.normal(size=shape)
w = jnp.asarray(rng.normal(size=shape))
x0 = jnp.asarray(rng.normal(size=shape))
for method, precond in (("mg", None), ("cg", "mg")):
    with scoped_program() as prog:
        T = Field("T", shape=shape, dtype=np.float64)
        Ff = Field("T_rhs", shape=shape, dtype=np.float64, init_data=F0)
        _record_poisson_body(T, Ff)
    clear_cache(); reset_stats()
    s = make_differentiable_solver(prog, "T", method=method,
                                   precondition=precond, tol=1e-13, maxiter=400)
    assert s.symmetric_adjoint
    built_after_build = stats.kernels_built
    loss = jax.jit(lambda f, s=s: jnp.sum(w * s(x0, {"T_rhs": f})))
    g = jax.grad(loss)(jnp.asarray(F0))
    jax.block_until_ready(g)
    assert stats.kernels_built == built_after_build, method
    r = gradcheck(loss, np.asarray(F0), g, n_probes=6)
    assert r.ok, (method, precond, str(r))
    assert stats.fallbacks == 0
    print(method, precond, "max scaled err", r.max_scaled_err)
print("PASS")
""", x64=True)
    assert "PASS" in out


# -- checkpointed reverse stepping --------------------------------------------


def _build_heat_program(T0, steps):
    with scoped_program() as prog:
        T = Field("T", init_data=T0, dtype=T0.dtype)
        with ForLoop("t", steps):
            T[1:-1, 0, 0] = 0.4 * T[1:-1, 0, 0] + 0.1 * (
                T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
                + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1]
            )
    return prog


def _runner_grad(T0, w, steps, time_tile, checkpoint, chunk_steps=None):
    p = plan(
        _build_heat_program(T0, steps),
        options=RunOptions(
            backend="pallas", differentiable=True, time_tile=time_tile
        ),
    )
    run = differentiable_runner(p, checkpoint=checkpoint, chunk_steps=chunk_steps)
    loss = lambda env: jnp.sum(jnp.asarray(w) * run(env)["T"])
    return np.asarray(jax.grad(loss)({"T": jnp.asarray(T0)})["T"])


def _assert_ulp_close(a, b, ulps=4.0):
    scale = max(np.abs(a).max(), np.abs(b).max())
    tol = ulps * scale * np.finfo(a.dtype).eps
    assert np.abs(a - b).max() <= tol, np.abs(a - b).max() / (scale * np.finfo(a.dtype).eps)


@pytest.mark.parametrize("time_tile,steps", [(1, 9), (2, 13), (4, 13), (4, 16)])
def test_checkpointed_runner_grad_matches_reference(rng, time_tile, steps):
    """Checkpointed reverse stepping == all-residuals reference to ~ulp,
    across time-tile factors (13 = remainder steps for k∈{2,4}).  fp32
    in-process; the fp64 variant runs in the sharded subprocess test."""
    T0 = rng.normal(size=(10, 8, 6)).astype(np.float32)
    w = rng.normal(size=(10, 8, 6)).astype(np.float32)
    ref = _runner_grad(T0, w, steps, 1, checkpoint=False)
    got = _runner_grad(T0, w, steps, time_tile, checkpoint=True)
    _assert_ulp_close(got, ref, ulps=8.0)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional test extra
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(
        steps=st.integers(1, 18),
        time_tile=st.sampled_from([1, 2, 4]),
        chunk_steps=st.sampled_from([None, 2, 5]),
        seed=st.integers(0, 10**6),
    )
    @settings(deadline=None, max_examples=15)
    def test_checkpointed_runner_grad_property(steps, time_tile, chunk_steps, seed):
        r = np.random.default_rng(seed)
        T0 = r.normal(size=(8, 8, 5)).astype(np.float32)
        w = r.normal(size=(8, 8, 5)).astype(np.float32)
        ref = _runner_grad(T0, w, steps, 1, checkpoint=False)
        got = _runner_grad(T0, w, steps, time_tile, True, chunk_steps)
        _assert_ulp_close(got, ref, ulps=8.0)


def test_ftcs_checkpointed_matches_plain(rng):
    T0 = jnp.asarray(rng.normal(size=(10, 10, 6)))
    w = jnp.asarray(rng.normal(size=(10, 10, 6)))
    for steps in (1, 5, 12, 16):
        a = np.asarray(ftcs_solve(T0, 0.1, steps))
        b = np.asarray(ftcs_solve_checkpointed(T0, 0.1, steps))
        _assert_ulp_close(a, b, ulps=2.0)
    g_ck = jax.grad(lambda t: jnp.sum(w * ftcs_solve_checkpointed(t, 0.1, 13)))(T0)
    g_nc = jax.grad(lambda t: jnp.sum(w * ftcs_solve(t, 0.1, 13)))(T0)
    _assert_ulp_close(np.asarray(g_ck), np.asarray(g_nc))


def test_gradcheck_harness_on_explicit_stepper(rng):
    """The FD harness itself, exercised end-to-end on the explicit path."""
    T0 = rng.normal(size=(8, 8, 5))
    w = jnp.asarray(rng.normal(size=(8, 8, 5)))
    loss = lambda t: float(jnp.sum(w * ftcs_solve_checkpointed(jnp.asarray(t), 0.1, 7)))
    g = jax.grad(lambda t: jnp.sum(w * ftcs_solve_checkpointed(t, 0.1, 7)))(
        jnp.asarray(T0)
    )
    # fp32 in-process: loosen to the fp32 FD noise floor (the tight fp64
    # tolerances are exercised by the subprocess gradchecks above)
    assert_gradcheck(loss, T0, np.asarray(g), eps=1e-2, atol=1e-2, rtol=5e-2)


def test_probe_points_mix_boundary_and_interior():
    pts = probe_points((6, 7, 5), 10, seed=1)
    assert len(pts) == 10
    assert any(0 in p or p[0] == 5 or p[1] == 6 or p[2] == 4 for p in pts)
    assert any(all(0 < c for c in p) for p in pts[5:])


# -- donation under AD --------------------------------------------------------


def test_donation_suppressed_under_differentiable_plan():
    """differentiable=True plans must not donate: no donation markers in the
    compiled HLO and the caller's entry buffers stay alive."""
    T0 = heat_init()
    wse = WSE_Interface()
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", 4):
        T[1:-1, 0, 0] = 0.4 * T[1:-1, 0, 0] + 0.1 * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
            + T[1:-1, 0, -1] + T[1:-1, -1, 0] + T[1:-1, 0, 1]
        )
    try:
        p = plan(wse.program, options=RunOptions(backend="pallas", differentiable=True))
        p_ref = plan(wse.program, options=RunOptions(backend="pallas"))
    finally:
        wse.__exit__()
    assert p.differentiable and not p_ref.differentiable
    runner = single_runner(p)
    env = {"T_n": jnp.asarray(T0)}
    lowered = runner.lower(env).as_text()
    assert "jax.buffer_donor" not in lowered
    assert "tf.aliasing_output" not in lowered
    out = runner(env)
    jax.block_until_ready(out["T_n"])
    assert not env["T_n"].is_deleted()
    # and the same program WITHOUT differentiable still donates
    ref_lowered = single_runner(p_ref).lower({"T_n": jnp.asarray(T0)}).as_text()
    assert "jax.buffer_donor" in ref_lowered or "tf.aliasing_output" in ref_lowered


def test_differentiable_runner_requires_flag():
    T0 = heat_init((8, 8, 6))
    p = plan(
        _build_heat_program(T0, 4),
        options=RunOptions(backend="pallas"),
    )
    with pytest.raises(ValueError, match="differentiable"):
        differentiable_runner(p)


# -- sharded gradient parity (fp64 subprocess) --------------------------------


def test_sharded_gradient_matches_single_device_fp64():
    """2×2-mesh gradient of the differentiable runner vs single device:
    forward bitwise, gradient within a few ulps (sharded VJP reduction
    order), donation nowhere in sight."""
    out = run_py("""
import jax
import jax.numpy as jnp
import numpy as np
import repro as wfa
from repro.core.field import Field
from repro.core.program import ForLoop, scoped_program
from repro.engine import differentiable_runner, plan

rng = np.random.default_rng(0)
T0 = rng.normal(size=(12, 8, 6))
w = jnp.asarray(rng.normal(size=(12, 8, 6)))

def build():
    with scoped_program() as prog:
        T = Field("T", init_data=T0, dtype=np.float64)
        with ForLoop("t", 9):
            T[1:-1, 0, 0] = 0.4 * T[1:-1, 0, 0] + 0.1 * (
                T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
                + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1])
    return prog

mesh = jax.make_mesh((2, 2), ("x", "y"))
opts = wfa.RunOptions(backend="pallas", differentiable=True)
r1 = differentiable_runner(plan(build(), options=opts))
r2 = differentiable_runner(plan(build(), options=opts.replace(mesh=mesh)))
env0 = {"T": jnp.asarray(T0)}
o1, o2 = r1(env0)["T"], r2(env0)["T"]
assert (np.asarray(o1) == np.asarray(o2)).all()
g1 = jax.grad(lambda e: jnp.sum(w * r1(e)["T"]))(env0)["T"]
g2 = jax.grad(lambda e: jnp.sum(w * r2(e)["T"]))(env0)["T"]
scale = float(jnp.abs(g1).max())
assert float(jnp.abs(g1 - g2).max()) <= 4 * scale * np.finfo(np.float64).eps
assert not env0["T"].is_deleted()
print("PASS")
""", devices=4, x64=True)
    assert "PASS" in out


def test_checkpointed_vjp_spill_matches_in_memory_fp64(tmp_path):
    """Out-of-core reverse sweep: disk-spilled chunk snapshots give the
    same gradient as host-memory snapshots and as plain jax.vjp."""
    out = run_py(f"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.explicit import ftcs_step
from repro.engine import checkpointed_vjp

rng = np.random.default_rng(0)
env0 = {{"T": jnp.asarray(rng.normal(size=(10, 10, 5)))}}
w = jnp.asarray(rng.normal(size=(10, 10, 5)))
chunk = lambda env: {{"T": ftcs_step(ftcs_step(env["T"], 0.1), 0.1)}}
final, vjp = checkpointed_vjp(chunk, env0, 6)
ct = jax.tree.map(jnp.zeros_like, final); ct["T"] = w
g_mem = vjp(ct)
final2, vjp2 = checkpointed_vjp(chunk, env0, 6, spill_dir={str(tmp_path)!r})
g_disk = vjp2(ct)

def f(env):
    for _ in range(6):
        env = chunk(env)
    return env

ref, pb = jax.vjp(f, env0)
(g_ref,) = pb(ct)
assert (np.asarray(final["T"]) == np.asarray(ref["T"])).all()
assert (np.asarray(g_mem["T"]) == np.asarray(g_ref["T"])).all()
assert (np.asarray(g_disk["T"]) == np.asarray(g_ref["T"])).all()
print("PASS")
""", x64=True)
    assert "PASS" in out

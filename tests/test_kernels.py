"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(4, 4, 4), (8, 8, 16), (16, 128, 8), (6, 10, 5), (8, 256, 32),
          (3, 7, 9)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32])
def test_stencil7_sweep(rng, shape, dtype):
    bx, by, nz = shape
    P = jnp.asarray(rng.normal(size=(bx + 2, by + 2, nz)).astype(dtype))
    out = ops.stencil7(P, 0.4, 0.1)
    expect = ref.affine_stencil_ref(P, 0.4, 0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("coeffs", [(1.0, -0.0625), (0.4, 0.1), (1.0, 0.0)])
def test_stencil7_coeffs(rng, coeffs):
    P = jnp.asarray(rng.normal(size=(10, 14, 12)).astype(np.float32))
    out = ops.stencil7(P, *coeffs)
    expect = ref.affine_stencil_ref(P, *coeffs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES[:4])
def test_spmv_dot_sweep(rng, shape):
    bx, by, nz = shape
    P = jnp.asarray(rng.normal(size=(bx + 2, by + 2, nz)).astype(np.float32))
    av, d = ops.spmv_hex_dot(P, 1.0, -0.0625)
    rav, rd = ref.spmv_dot_ref(P, 1.0, -0.0625)
    np.testing.assert_allclose(np.asarray(av), np.asarray(rav), atol=1e-5)
    np.testing.assert_allclose(float(d), float(rd), rtol=1e-4)


def test_spmv_matches_stencil(rng):
    P = jnp.asarray(rng.normal(size=(10, 130, 12)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ops.spmv_hex(P, 1.0, -0.05)),
        np.asarray(ops.stencil7(P, 1.0, -0.05)), atol=1e-6)


# -- non-divisible grids × block shapes (block picker must fall back to a
#    divisor; coverage for the generalized fused path too) -------------------

ODD_SHAPES = [(5, 7, 3), (9, 13, 6), (7, 130, 12)]
BLOCKS = [(8, 128), (4, 32), (3, 5)]


@pytest.mark.parametrize("shape", ODD_SHAPES)
@pytest.mark.parametrize("block", BLOCKS)
def test_stencil7_odd_shapes_blocks(rng, shape, block):
    bx, by, nz = shape
    P = jnp.asarray(rng.normal(size=(bx + 2, by + 2, nz)).astype(np.float32))
    out = ops.stencil7(P, 0.4, 0.1, block=block)
    expect = ref.affine_stencil_ref(P, 0.4, 0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", ODD_SHAPES[:2])
@pytest.mark.parametrize("block", BLOCKS)
def test_spmv_dot_odd_shapes_blocks(rng, shape, block):
    bx, by, nz = shape
    P = jnp.asarray(rng.normal(size=(bx + 2, by + 2, nz)).astype(np.float32))
    av, d = ops.spmv_hex_dot(P, 1.0, -0.0625, block=block)
    rav, rd = ref.spmv_dot_ref(P, 1.0, -0.0625)
    np.testing.assert_allclose(np.asarray(av), np.asarray(rav), atol=1e-5)
    np.testing.assert_allclose(float(d), float(rd), rtol=1e-4)


@pytest.mark.parametrize("block", [(256, 128), (64, 32), (16, 8)])
def test_dual_dot_blocks(rng, block):
    a, b, c, d = [jnp.asarray(rng.normal(size=(12, 64, 4)).astype(np.float32))
                  for _ in range(4)]
    out = ops.dual_dot(a, b, c, d, block=block)
    expect = ref.dual_dot_ref(a, b, c, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4)


@pytest.mark.parametrize("shape", [(16, 64, 8), (4, 4, 4), (32, 128, 2)])
def test_dual_dot_sweep(rng, shape):
    a, b, c, d = [jnp.asarray(rng.normal(size=shape).astype(np.float32))
                  for _ in range(4)]
    out = ops.dual_dot(a, b, c, d)
    expect = ref.dual_dot_ref(a, b, c, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4)


def test_dual_dot_zero():
    z = jnp.zeros((8, 128, 4), jnp.float32)
    out = ops.dual_dot(z, z, z, z)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(2))


@pytest.mark.parametrize("shape,coords,meshdim", [
    ((8, 8, 8), (0, 0), (1, 1)),        # single brick = whole domain
    ((8, 16, 8), (1, 0), (2, 2)),       # interior-ish brick
    ((6, 10, 5), (1, 1), (2, 2)),       # bottom-right brick
])
def test_stencil_planes_sweep(rng, shape, coords, meshdim):
    """The fully-fused halo-plane kernel vs the padded-assembly oracle."""
    bx, by, nz = shape
    T = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    xlo = jnp.asarray(rng.normal(size=(1, by, nz)).astype(np.float32))
    xhi = jnp.asarray(rng.normal(size=(1, by, nz)).astype(np.float32))
    ylo = jnp.asarray(rng.normal(size=(bx, 1, nz)).astype(np.float32))
    yhi = jnp.asarray(rng.normal(size=(bx, 1, nz)).astype(np.float32))
    carr = jnp.asarray([[coords[0], coords[1]]], jnp.int32)
    nx, ny = meshdim[0] * bx, meshdim[1] * by
    out = ops.stencil7_planes(T, xlo, xhi, ylo, yhi, carr, 0.4, 0.1, nx, ny)
    expect = ref.stencil_planes_ref(T, xlo, xhi, ylo, yhi, carr, 0.4, 0.1,
                                    nx, ny)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)

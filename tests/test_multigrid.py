"""Geometric multigrid through the WFA compiler (``method="mg"``,
``precondition="mg"``).

Acceptance surface: V-cycle vs a dense direct solve on small Poisson grids,
mg-preconditioned CG strictly below plain CG in iterations, iteration counts
that do NOT grow across three grid sizes (the property Krylov methods lack),
sharded-vs-single-device agreement, per-level kernel-cache accounting, and
the level-legality errors (grid not coarsenable / non-affine or asymmetric
operator → clear message, logged fallback for ``precondition="mg"``).
"""

import logging

import numpy as np
import pytest

from repro.compiler import clear_cache, reset_stats, stats
from repro.core import WSE_Array, WSE_Interface
from repro.engine import reset_stats as engine_reset
from repro.engine import stats as engine_stats
from repro.solver import (
    MGOptions,
    Operator,
    Rhs,
    btcs_program,
    poisson_program,
    record_varcoef_btcs,
    solve,
)
from test_sharded import run_py


def _poisson_rhs(shape, seed=0):
    rng = np.random.default_rng(seed)
    F = np.zeros(shape, np.float32)
    F[1:-1, 1:-1, 1:-1] = rng.normal(size=tuple(n - 2 for n in shape)).astype(
        np.float32
    )
    return F


def _dense_poisson(F):
    """Dense A = 6I − S with identity boundary rows; b = F on the interior."""
    shape = F.shape
    n = F.size

    def idx(x, y, z):
        return (x * shape[1] + y) * shape[2] + z

    A = np.eye(n)
    b = np.zeros(n)
    for x in range(shape[0]):
        for y in range(shape[1]):
            for z in range(shape[2]):
                i = idx(x, y, z)
                interior = (
                    0 < x < shape[0] - 1
                    and 0 < y < shape[1] - 1
                    and 0 < z < shape[2] - 1
                )
                if interior:
                    A[i, i] = 6.0
                    for dx, dy, dz in [
                        (1, 0, 0),
                        (-1, 0, 0),
                        (0, 1, 0),
                        (0, -1, 0),
                        (0, 0, 1),
                        (0, 0, -1),
                    ]:
                        A[i, idx(x + dx, y + dy, z + dz)] = -1.0
                    b[i] = F[x, y, z]
    return np.linalg.solve(A, b).reshape(shape)


# -- correctness: V-cycle vs dense direct solve ------------------------------


@pytest.mark.parametrize("shape", [(9, 9, 9), (9, 8, 7)])
def test_vcycle_vs_dense_poisson(shape):
    F = _poisson_rhs(shape)
    dense = _dense_poisson(F)
    prog = poisson_program(shape, rhs=F)
    x = solve(prog, "T", method="mg", backend="pallas", tol=1e-6, maxiter=60)
    scale = np.abs(dense).max()
    np.testing.assert_allclose(x, dense, atol=2e-5 * max(1.0, scale))


@pytest.mark.parametrize(
    "opts",
    [MGOptions(smoother="rb"), MGOptions(cycle="w"), MGOptions(nu1=1, nu2=1)],
)
def test_cycle_variants_vs_dense(opts):
    shape = (9, 9, 9)
    F = _poisson_rhs(shape)
    dense = _dense_poisson(F)
    prog = poisson_program(shape, rhs=F)
    x = solve(
        prog,
        "T",
        method="mg",
        backend="jit",
        tol=1e-6,
        maxiter=60,
        mg_opts=opts,
    )
    scale = np.abs(dense).max()
    np.testing.assert_allclose(x, dense, atol=2e-5 * max(1.0, scale))


def test_mg_preconditioned_cg_vs_dense():
    shape = (9, 9, 9)
    F = _poisson_rhs(shape)
    dense = _dense_poisson(F)
    prog = poisson_program(shape, rhs=F)
    x = solve(
        prog,
        "T",
        method="cg",
        precondition="mg",
        backend="pallas",
        tol=1e-7,
        maxiter=100,
    )
    scale = np.abs(dense).max()
    np.testing.assert_allclose(x, dense, atol=2e-5 * max(1.0, scale))


# -- convergence: fewer iterations than CG, flat across grid sizes -----------


def _iters(method, n, precondition=None, maxiter=3000):
    prog = poisson_program((n, n, n), rhs=_poisson_rhs((n, n, n)))
    _, info = solve(
        prog,
        "T",
        method=method,
        precondition=precondition,
        backend="jit",
        tol=1e-5,
        maxiter=maxiter,
        return_info=True,
    )
    return int(info.iterations[0])


def test_mg_pcg_iterations_strictly_below_plain_cg():
    n = 17
    plain = _iters("cg", n)
    pcg = _iters("cg", n, precondition="mg")
    assert pcg < plain, (pcg, plain)


def test_iteration_counts_grid_independent():
    """The acceptance property: mg counts stay flat over >= 3 sizes while
    plain CG grows with the grid."""
    sizes = (9, 17, 33)
    mg = [_iters("mg", n, maxiter=60) for n in sizes]
    pcg = [_iters("cg", n, precondition="mg") for n in sizes]
    cg = [_iters("cg", n) for n in sizes]
    assert max(mg) <= min(mg) + 1, mg
    assert max(pcg) <= min(pcg) + 2, pcg
    assert max(mg) <= 15 and max(pcg) <= 15, (mg, pcg)
    assert cg[-1] > cg[0], cg  # Krylov alone DOES grow — the gap mg closes
    assert cg[-1] > 3 * max(pcg), (cg, pcg)


def test_heat_implicit_mg_grid_independent():
    counts = []
    for n in (9, 17, 33):
        T0 = np.full((n, n, n), 500.0, np.float32)
        T0[1:-1, 1:-1, 0] = 300.0
        T0[1:-1, 1:-1, -1] = 400.0
        prog = btcs_program(T0.shape, 0.1, init_data=T0)
        x, info = solve(
            prog,
            "T",
            method="mg",
            backend="jit",
            tol=1e-6,
            maxiter=60,
            return_info=True,
        )
        assert np.isfinite(x).all()
        counts.append(int(info.iterations[0]))
    assert max(counts) <= min(counts) + 1, counts
    assert max(counts) <= 10, counts


# -- accounting: one kernel cache entry per level ----------------------------


def test_pallas_kernels_cached_per_level():
    shape = (17, 17, 17)
    clear_cache()
    reset_stats()
    engine_reset()
    prog = poisson_program(shape, rhs=_poisson_rhs(shape))
    solve(prog, "T", method="mg", backend="pallas", tol=1e-5, maxiter=30)
    levels = engine_stats.mg_levels_built
    assert engine_stats.mg_hierarchies == 1
    assert levels == 4  # 17 -> 9 -> 5 -> 3
    assert all(sf and rf for _, sf, rf in engine_stats.mg_level_log)
    assert stats.fallbacks == 0
    # smoother + residual per level, restrict + prolong per level pair,
    # operator + rhs bodies of the solve itself
    assert stats.kernels_built == 2 * levels + 2 * (levels - 1) + 2
    # a second identical hierarchy is served from the cache
    built = stats.kernels_built
    prog = poisson_program(shape, rhs=_poisson_rhs(shape))
    solve(prog, "T", method="mg", backend="pallas", tol=1e-5, maxiter=30)
    assert stats.kernels_built == built


# -- legality: clear errors + logged fallback --------------------------------


def test_uncoarsenable_grid_raises():
    prog = poisson_program((4, 9, 9))
    with pytest.raises(ValueError, match="coarsenable"):
        solve(prog, "T", method="mg", backend="jit")


def test_varcoef_operator_rejected_for_mg(rng):
    T0 = np.full((9, 9, 9), 500.0, np.float32)
    C0 = rng.uniform(0.05, 0.3, size=T0.shape).astype(np.float32)
    wse, T, C = record_varcoef_btcs(T0, C0, 0.1)
    with pytest.raises(ValueError, match="constant-coefficient"):
        wse.solve(T, method="mg", backend="jit")


def test_asymmetric_operator_rejected_for_mg():
    wse = WSE_Interface()
    T = WSE_Array("T", shape=(9, 9, 9))
    with Operator():  # upwind-style one-sided tap: not re-discretizable
        T[1:-1, 0, 0] = T[1:-1, 0, 0] - 0.25 * T[1:-1, -1, 0]
    with Rhs():
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
    with pytest.raises(ValueError, match="symmetric"):
        wse.solve(T, method="mg", backend="jit")


def test_precondition_fallback_logged_and_converges(rng, caplog):
    T0 = np.full((9, 9, 9), 500.0, np.float32)
    C0 = rng.uniform(0.05, 0.3, size=T0.shape).astype(np.float32)
    wse, T, C = record_varcoef_btcs(T0, C0, 0.1)
    with caplog.at_level(logging.WARNING, logger="repro.solver"):
        x = wse.solve(
            T,
            method="bicgstab",
            precondition="mg",
            backend="jit",
            tol=1e-6,
            maxiter=300,
        )
    assert np.isfinite(x).all()
    assert any("falling back" in r.message for r in caplog.records)


def test_precondition_requires_cg_or_bicgstab():
    prog = poisson_program((9, 9, 9))
    with pytest.raises(ValueError, match="precondition"):
        solve(prog, "T", method="chebyshev", precondition="mg")
    prog = poisson_program((9, 9, 9))
    with pytest.raises(ValueError, match="precondition"):
        solve(prog, "T", method="mg", precondition="mg")


# -- sharded (mesh=) vs single device ----------------------------------------


def test_sharded_mg_matches_single_device():
    out = run_py(
        """
import numpy as np
from repro.core.jaxcompat import make_mesh
from repro.solver import poisson_program, solve

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
shape = (16, 16, 12)
F = np.zeros(shape, np.float32)
F[1:-1, 1:-1, 1:-1] = rng.normal(size=(14, 14, 10)).astype(np.float32)

prog = poisson_program(shape, rhs=F)
a, ia = solve(prog, "T", method="mg", backend="pallas", tol=1e-5,
              maxiter=50, return_info=True)
prog = poisson_program(shape, rhs=F)
b, ib = solve(prog, "T", method="mg", backend="pallas", mesh=mesh,
              tol=1e-5, maxiter=50, return_info=True)
err = np.abs(a - b).max()
assert err < 1e-5, err
assert ia.iterations[0] == ib.iterations[0], (ia.iterations, ib.iterations)

prog = poisson_program(shape, rhs=F)
c, ic = solve(prog, "T", method="cg", precondition="mg", backend="pallas",
              tol=1e-6, maxiter=100, return_info=True)
prog = poisson_program(shape, rhs=F)
d, idd = solve(prog, "T", method="cg", precondition="mg", backend="pallas",
               mesh=mesh, tol=1e-6, maxiter=100, return_info=True)
err = np.abs(c - d).max()
assert err < 1e-4, err
assert abs(int(ic.iterations[0]) - int(idd.iterations[0])) <= 1
print("OK", ia.iterations[0], ic.iterations[0])
"""
    )
    assert "OK" in out

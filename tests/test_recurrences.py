"""Chunked recurrences vs naive sequential references.

The chunked WKV6 / SSD formulations are the perf-critical training paths;
these tests pin them against direct per-step recurrences (the definitional
form), across chunk sizes that do and don't divide the sequence.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv import wkv_chunked
from repro.models.ssm import ssd_chunked


def wkv_sequential(r, k, v, logw, u, n_heads):
    """S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ ; y_t = r_tᵀ(S_{t-1} + diag(u) k_t v_tᵀ)."""
    b, s, d = r.shape
    hk = d // n_heads
    rr = np.asarray(r, np.float64).reshape(b, s, n_heads, hk)
    kk = np.asarray(k, np.float64).reshape(b, s, n_heads, hk)
    vv = np.asarray(v, np.float64).reshape(b, s, n_heads, hk)
    ww = np.exp(np.asarray(logw, np.float64).reshape(b, s, n_heads, hk))
    uu = np.asarray(u, np.float64).reshape(n_heads, hk)
    S = np.zeros((b, n_heads, hk, hk))
    ys = []
    for t in range(s):
        kv = np.einsum("bhk,bhv->bhkv", kk[:, t], vv[:, t])
        y = np.einsum("bhk,bhkv->bhv", rr[:, t], S + uu[None, :, :, None] * kv)
        ys.append(y)
        S = S * ww[:, t][..., None] + kv
    return np.stack(ys, axis=1).reshape(b, s, d)


@pytest.mark.parametrize("s,chunk", [(16, 4), (12, 5), (8, 8), (24, 6)])
def test_wkv_chunked_matches_sequential(rng, s, chunk):
    b, h, hk = 2, 2, 4
    d = h * hk
    r, k, v = [jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
               for _ in range(3)]
    logw = jnp.asarray(-np.exp(
        rng.normal(size=(b, s, d))).astype(np.float32) * 0.3)
    u = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = np.asarray(wkv_chunked(r, k, v, logw, u, h, chunk=chunk))
    want = wkv_sequential(r, k, v, logw, u, h)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def ssd_sequential(x, dt, a_log, B, C):
    """S_t = exp(dt_t A)·S_{t-1} + dt_t·x_t⊗B_t ; y_t = C_t·S_t."""
    bsz, s, h, p = x.shape
    n = B.shape[-1]
    A = -np.exp(np.asarray(a_log, np.float64))
    xx = np.asarray(x, np.float64)
    dd = np.asarray(dt, np.float64)
    BB = np.asarray(B, np.float64)
    CC = np.asarray(C, np.float64)
    S = np.zeros((bsz, h, n, p))
    ys = []
    for t in range(s):
        a = np.exp(dd[:, t] * A[None, :])                  # (B,H)
        xd = xx[:, t] * dd[:, t][..., None]                # (B,H,P)
        S = S * a[..., None, None] + np.einsum(
            "bn,bhp->bhnp", BB[:, t], xd)
        ys.append(np.einsum("bn,bhnp->bhp", CC[:, t], S))
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("s,chunk", [(16, 4), (12, 5), (8, 8)])
def test_ssd_chunked_matches_sequential(rng, s, chunk):
    bsz, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(bsz, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 0.9,
                                 size=(bsz, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.normal(size=(h,)).astype(np.float32) * 0.2)
    B = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    got = np.asarray(ssd_chunked(x, dt, a_log, B, C, chunk=chunk))
    want = ssd_sequential(x, dt, a_log, B, C)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_wkv_decode_consistency(rng):
    """One-step decode recurrence matches the chunked result at each t."""
    from repro.models.rwkv import RWKVState
    b, h, hk, s = 1, 2, 4, 6
    d = h * hk
    r, k, v = [jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
               for _ in range(3)]
    logw = jnp.asarray(-np.exp(
        rng.normal(size=(b, s, d))).astype(np.float32) * 0.3)
    u = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    full = np.asarray(wkv_chunked(r, k, v, logw, u, h, chunk=4))
    # manual sequential decode with the same math as rwkv_time_mix_decode
    S = np.zeros((b, h, hk, hk), np.float64)
    uu = np.asarray(u).reshape(h, hk)
    for t in range(s):
        rh = np.asarray(r[:, t], np.float64).reshape(b, h, hk)
        kh = np.asarray(k[:, t], np.float64).reshape(b, h, hk)
        vh = np.asarray(v[:, t], np.float64).reshape(b, h, hk)
        wh = np.exp(np.asarray(logw[:, t], np.float64)).reshape(b, h, hk)
        kv = np.einsum("bhk,bhv->bhkv", kh, vh)
        y = np.einsum("bhk,bhkv->bhv", rh, S + uu[None, ..., None] * kv)
        np.testing.assert_allclose(y.reshape(b, d), full[:, t], atol=2e-4)
        S = S * wh[..., None] + kv

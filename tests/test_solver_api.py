"""``wfa.solve`` — the solver subsystem vs the legacy drivers + dense refs.

Acceptance surface: agreement with ``btcs_solve`` and a dense reference for
every method, zero interpreter fallbacks (with real pallas launches) for
affine operators, variable-coefficient BiCGSTAB vs dense, the sharded
(``mesh=``) result vs single-device, and the recording-validation errors.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import heat_init
from repro.compiler import reset_stats, stats
from repro.core import WSE_Array, WSE_Interface
from repro.core.implicit import btcs_solve
from repro.solver import Operator, Rhs, record_btcs, record_varcoef_btcs
from test_solvers import _dense_btcs
from test_sharded import run_py

OMEGA = 0.1


def _dense_varcoef(T0, C, w):
    """Dense A = I + ωC·(6I − S) with identity boundary rows; b = Tⁿ."""
    shape = T0.shape
    n = T0.size

    def idx(x, y, z):
        return (x * shape[1] + y) * shape[2] + z

    A = np.eye(n)
    b = np.zeros(n)
    for x in range(shape[0]):
        for y in range(shape[1]):
            for z in range(shape[2]):
                i = idx(x, y, z)
                interior = (
                    0 < x < shape[0] - 1
                    and 0 < y < shape[1] - 1
                    and 0 < z < shape[2] - 1
                )
                if interior:
                    c = C[x, y, z]
                    A[i, i] = 1.0 + 6.0 * w * c
                    for dx, dy, dz in [
                        (1, 0, 0),
                        (-1, 0, 0),
                        (0, 1, 0),
                        (0, -1, 0),
                        (0, 0, 1),
                        (0, 0, -1),
                    ]:
                        A[i, idx(x + dx, y + dy, z + dz)] = -w * c
                b[i] = T0[x, y, z]
    return np.linalg.solve(A, b).reshape(shape)


# -- agreement: wfa.solve vs legacy btcs_solve vs dense ----------------------


@pytest.mark.parametrize(
    "method,maxiter,atol",
    [
        ("cg", 400, 2e-4),
        ("bicgstab", 400, 2e-4),
        ("pipecg", 400, 5e-3),
        ("chebyshev", 80, 2e-4),
        ("jacobi", 80, 5e-4),
    ],
)
def test_solve_matches_legacy_and_dense(method, maxiter, atol):
    T0 = heat_init((7, 8, 9))
    dense = _dense_btcs(T0, OMEGA)
    legacy, _ = btcs_solve(
        jnp.asarray(T0), OMEGA, 1, method="cg", tol=1e-7, maxiter=400
    )
    wse, T = record_btcs(T0, OMEGA)
    x = wse.solve(T, method=method, backend="pallas", tol=1e-7, maxiter=maxiter)
    np.testing.assert_allclose(x, dense, atol=atol)
    np.testing.assert_allclose(x, np.asarray(legacy), atol=1e-5 + atol)


def test_solve_acceptance_tolerance_1e5():
    """The headline acceptance bound: compiled CG vs dense to 1e-5."""
    T0 = heat_init((6, 7, 5))
    dense = _dense_btcs(T0, OMEGA)
    wse, T = record_btcs(T0, OMEGA)
    x = wse.solve(T, method="cg", backend="pallas", tol=1e-8, maxiter=600)
    np.testing.assert_allclose(x, dense, atol=1e-5)


def test_backend_jit_agrees_with_pallas():
    T0 = heat_init((7, 8, 9))
    wse, T = record_btcs(T0, OMEGA)
    a = wse.solve(T, method="cg", backend="pallas", tol=1e-7, maxiter=400)
    wse, T = record_btcs(T0, OMEGA)
    b = wse.solve(T, method="cg", backend="jit", tol=1e-7, maxiter=400)
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_multistep_matches_legacy():
    T0 = heat_init((7, 8, 9))
    legacy, _ = btcs_solve(
        jnp.asarray(T0), OMEGA, 3, method="cg", tol=1e-7, maxiter=400
    )
    wse, T = record_btcs(T0, OMEGA)
    x, info = wse.solve(
        T,
        method="cg",
        backend="pallas",
        steps=3,
        tol=1e-7,
        maxiter=400,
        return_info=True,
    )
    # fused-kernel vs interpreter rounding accumulates over steps on the
    # 300–500 K scale; 5e-4 is ~1e-6 relative
    np.testing.assert_allclose(x, np.asarray(legacy), atol=5e-4)
    assert info.iterations.shape == (3,)
    assert (info.iterations > 0).all()


# -- fusion accounting: affine operators never fall back ---------------------


def test_affine_operator_zero_fallbacks_with_pallas_launches():
    T0 = heat_init((7, 8, 9))
    reset_stats()
    wse, T = record_btcs(T0, OMEGA)
    wse.solve(T, method="cg", backend="pallas", tol=1e-7, maxiter=400)
    assert stats.fallbacks == 0
    assert stats.groups_fused == 2  # operator body + rhs body
    assert stats.kernels_built + stats.cache_hits == 2


def test_varcoef_bicgstab_vs_dense_zero_fallbacks(rng):
    T0 = heat_init((6, 7, 5))
    C0 = rng.uniform(0.05, 0.3, size=T0.shape).astype(np.float32)
    dense = _dense_varcoef(T0, C0, OMEGA)
    reset_stats()
    wse, T, C = record_varcoef_btcs(T0, C0, OMEGA)
    x = wse.solve(T, method="bicgstab", backend="pallas", tol=1e-7, maxiter=400)
    np.testing.assert_allclose(x, dense, atol=2e-4)
    assert stats.fallbacks == 0  # two-tap products fuse (variable coeff)
    assert stats.groups_fused == 1


def test_chebyshev_needs_bounds_for_varcoef(rng):
    """No Gershgorin bracket for variable coefficients: explicit
    lambda_bounds are required — and make it converge."""
    T0 = heat_init((6, 7, 5))
    C0 = rng.uniform(0.05, 0.3, size=T0.shape).astype(np.float32)
    wse, T, C = record_varcoef_btcs(T0, C0, OMEGA)
    with pytest.raises(ValueError, match="lambda_bounds"):
        wse.solve(T, method="chebyshev", backend="pallas", maxiter=50)
    dense = _dense_varcoef(T0, C0, OMEGA)
    wse, T, C = record_varcoef_btcs(T0, C0, OMEGA)
    x = wse.solve(
        T,
        method="chebyshev",
        backend="pallas",
        maxiter=120,
        lambda_bounds=(1.0 - 6 * OMEGA * 0.3, 1.0 + 6 * OMEGA * 0.3 + 0.2),
    )
    np.testing.assert_allclose(x, dense, atol=5e-4)


# -- recording validation ----------------------------------------------------


def test_nonlinear_operator_rejected():
    T0 = heat_init((6, 6, 6))
    wse = WSE_Interface()
    T = WSE_Array("T", init_data=T0)
    with Operator():
        T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0]
    with pytest.raises(ValueError, match="nonlinear"):
        wse.solve(T, method="cg")


def test_constant_term_rejected():
    T0 = heat_init((6, 6, 6))
    wse = WSE_Interface()
    T = WSE_Array("T", init_data=T0)
    with Operator():
        T[1:-1, 0, 0] = T[1:-1, 0, 0] + 1.0
    with pytest.raises(ValueError, match="constant term"):
        wse.solve(T, method="cg")


def test_solve_requires_exactly_one_operator_group():
    T0 = heat_init((6, 6, 6))
    wse = WSE_Interface()
    T = WSE_Array("T", init_data=T0)
    with Rhs():
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]
    with pytest.raises(ValueError, match="Operator"):
        wse.solve(T, method="cg")


def test_make_rejects_solver_programs():
    from repro.core.program import current_program

    T0 = heat_init((6, 6, 6))
    wse, T = record_btcs(T0, OMEGA)
    with pytest.raises(ValueError, match="implicit"):
        wse.make(answer=T)
    # the failed make deactivates the program (no stuck thread-local state)
    # but leaves it attached to wse, so solve still works afterwards
    assert current_program() is None
    x = wse.solve(T, method="cg", backend="jit", tol=1e-6, maxiter=100)
    assert np.isfinite(x).all()


def test_unlooped_updates_rejected_by_solve():
    T0 = heat_init((6, 6, 6))
    wse = WSE_Interface()
    T = WSE_Array("T", init_data=T0)
    T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0]  # not inside Operator()/Rhs()
    with pytest.raises(ValueError, match="Operator"):
        wse.solve(T, method="cg")


# -- sharded (mesh=) vs single device ----------------------------------------


def test_sharded_solve_matches_single_device():
    out = run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core.jaxcompat import make_mesh
from repro.solver import btcs_program, solve
from repro.compiler import stats

mesh = make_mesh((2, 2), ("data", "model"))
T0 = np.ones((8, 12, 10), np.float32) * 500.0
T0[1:-1, 1:-1, 0] = 300.0
T0[1:-1, 1:-1, -1] = 400.0

prog = btcs_program(T0.shape, 0.1, init_data=T0)
single = solve(prog, "T", method="cg", backend="pallas", steps=2,
               tol=1e-7, maxiter=400)
prog = btcs_program(T0.shape, 0.1, init_data=T0)
sharded = solve(prog, "T", method="cg", backend="pallas", mesh=mesh,
                steps=2, tol=1e-7, maxiter=400)
err = np.abs(sharded - single).max()
assert err < 2e-4, err
assert stats.fallbacks == 0, stats
print("OK", err)
"""
    )
    assert "OK" in out

"""The unified execution engine: planner, executor, temporal blocking.

Covers the PR-3 acceptance surface: every ``make`` backend routes through
``engine.plan``/``engine.execute`` (one dispatch point), a k=4 time-tiled
heat3d run ftol-matches the untiled run while the engine's communication
accounting shows one wrap pad / halo exchange per k steps, the remainder
path (``n % k``), clamping of illegal tile factors with a logged reason,
the untiled interpreter fallback for non-affine bodies, and — property-based
— that k-step tiled execution matches k single steps for random affine
programs.  (The sharded k-tiled run lives in tests/test_sharded.py: it
needs the 4-device subprocess.)
"""

import numpy as np
import pytest

from conftest import ftcs_oracle, heat_init
from repro.compiler import reset_stats as compiler_reset
from repro.compiler import stats as compiler_stats
from repro.configs.heat3d import HeatConfig, make_field
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.engine import BACKENDS, plan, reset_stats, stats


def build_heat(T0, steps, c=0.1):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    return wse, T


# -- planner routing (acceptance: no per-layer backend ladders) ---------------


@pytest.mark.parametrize("backend", ["numpy", "jit", "pallas"])
def test_every_backend_routes_through_the_planner(backend):
    T0 = heat_init()
    reset_stats()
    wse, T = build_heat(T0, steps=3)
    out = wse.make(answer=T, backend=backend)
    assert stats.plans_built == 1
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 3), atol=2e-4)


def test_plan_schedules_fused_vs_interp_segments():
    T0 = heat_init()
    reset_stats()
    wse, T = build_heat(T0, steps=4)
    try:
        p = plan(wse.program, backend="pallas")
    finally:
        wse.__exit__()
    assert [s.kind for s in p.segments] == ["fused"]
    assert stats.segments_fused == 1 and stats.segments_interp == 0
    reset_stats()
    wse, T = build_heat(T0, steps=4)
    try:
        p = plan(wse.program, backend="jit")
    finally:
        wse.__exit__()
    assert [s.kind for s in p.segments] == ["interp"]


def test_unknown_backend_rejected():
    T0 = heat_init()
    wse, T = build_heat(T0, steps=2)
    with pytest.raises(ValueError, match="unknown backend"):
        wse.make(answer=T, backend="cerebras")
    assert "cerebras" not in BACKENDS


def test_solver_operator_application_dispatches_through_engine():
    from repro.solver import record_btcs

    reset_stats()
    wse, T = record_btcs(heat_init(), 0.1)
    x = wse.solve(T, method="cg", backend="pallas", tol=1e-6)
    # operator + rhs bodies both obtained from engine.compile_body
    assert stats.bodies_compiled >= 2
    assert np.isfinite(x).all()


# -- temporal blocking (acceptance: one exchange per k steps, ftol match) -----


def test_heat3d_k4_tiled_matches_untiled_one_pad_per_4_steps():
    cfg = HeatConfig().smoke()  # 16 x 16 x 12 heat3d grid
    T0 = make_field(cfg)
    steps = 8

    reset_stats()
    wse, T = build_heat(T0, steps, c=cfg.omega)
    base = wse.make(answer=T, backend="pallas", time_tile=1)
    assert stats.exchanges_per_step == 1.0 and stats.tiles_fused == 0

    reset_stats()
    wse, T = build_heat(T0, steps, c=cfg.omega)
    tiled = wse.make(answer=T, backend="pallas", time_tile=4)
    # one wrap pad (the single-device exchange analogue) per 4 steps
    assert stats.exchanges_per_step == pytest.approx(0.25)
    assert stats.tiles_fused == 2 and stats.max_time_tile == 4
    assert stats.steps_run == steps and stats.steps_per_sec > 0
    # ftol match: identical arithmetic per sub-step; XLA FMA fusion may
    # round differently at the last ulp (on the ~500 K field that is ~6e-5)
    np.testing.assert_allclose(tiled, base, atol=1e-3)
    np.testing.assert_allclose(tiled, ftcs_oracle(T0, cfg.omega, steps), atol=2e-3)


def test_remainder_steps_run_untiled():
    T0 = heat_init()
    reset_stats()
    wse, T = build_heat(T0, steps=7)
    out = wse.make(answer=T, backend="pallas", time_tile=4)
    # 7 = 1 tile of 4 + 3 untiled remainder launches -> 4 pads, not 7
    assert stats.tiles_fused == 1 and stats.launches == 4
    assert stats.exchanges == 4 and stats.steps_run == 7
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 7), atol=2e-4)


def test_illegal_tile_factor_clamped_with_logged_reason():
    T0 = heat_init()  # trip count 6 < requested 64
    reset_stats()
    wse, T = build_heat(T0, steps=6)
    out = wse.make(answer=T, backend="pallas", time_tile=64)
    assert stats.tile_reasons and "clamped" in stats.tile_reasons[0]
    assert stats.max_time_tile <= 6
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 6), atol=2e-4)


def test_time_tile_on_interpreter_backend_noted_not_silent():
    T0 = heat_init()
    reset_stats()
    wse, T = build_heat(T0, steps=4)
    out = wse.make(answer=T, backend="jit", time_tile=4)
    assert stats.tile_reasons and "ignored" in stats.tile_reasons[0]
    assert stats.max_time_tile == 1
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 4), atol=2e-4)


def test_auto_tile_prefers_divisors_of_the_trip_count():
    T0 = np.asarray(heat_init((24, 24, 8)))
    reset_stats()
    wse, T = build_heat(T0, steps=8)
    # auto: 8 divides 8 but 4*8*h > 24 (halo-vs-brick bound) -> k = 4
    wse.make(answer=T, backend="pallas")
    assert stats.max_time_tile == 4
    reset_stats()
    wse, T = build_heat(T0, steps=7)
    wse.make(answer=T, backend="pallas")  # auto: no power-of-2 divisor of 7
    assert stats.max_time_tile == 1


def test_non_affine_body_falls_back_untiled(rng):
    T0 = rng.uniform(0.5, 1.0, size=(8, 8, 6)).astype(np.float32)

    def build():
        wse = WSE_Interface()
        T = WSE_Array("T_nl", init_data=T0)
        with WSE_For_Loop("t", 4):
            T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 1, 0]
        return wse, T

    reset_stats()
    compiler_reset()
    wse, T = build()
    a = wse.make(answer=T, backend="pallas", time_tile=4)
    assert stats.segments_interp == 1 and stats.max_time_tile == 1
    assert compiler_stats.fallbacks == 1
    wse, T = build()
    b = wse.make(answer=T, backend="numpy")
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_tile_group_legality_bounds():
    from repro.compiler import LoweringError, lower_group, tile_group

    wse, T = build_heat(heat_init(), steps=4)
    try:
        group = lower_group(wse.program.ops)
    finally:
        wse.__exit__()
    assert tile_group(group, 3).halo == 3 * group.halo
    with pytest.raises(LoweringError):
        tile_group(group, 0)
    with pytest.raises(LoweringError):
        tile_group(group, 9, n_steps=4)
    with pytest.raises(LoweringError):
        tile_group(group, 5, brick_xy=(4, 4))  # halo 5 > brick 4


# -- property: k tiled steps == k single steps (random affine programs) -------


def check_tiled_matches_k_single_steps(shape, seed, n_taps, steps, k, varcoef):
    """k-step tiled pallas execution == k single interpreter steps, and the
    engine's pad/exchange count drops k× — for one random affine program."""
    rng = np.random.default_rng(seed)
    T0 = rng.uniform(0.0, 1.0, size=shape).astype(np.float32)
    C0 = rng.uniform(0.5, 1.5, size=shape).astype(np.float32)
    offsets = [
        (dz, dx, dy)
        for dz in (-1, 0, 1)
        for dx in (-1, 0, 1)
        for dy in (-1, 0, 1)
    ]
    picks = rng.choice(len(offsets), size=n_taps, replace=False)
    taps = [offsets[i] for i in picks]
    coeffs = rng.uniform(-0.15, 0.15, size=n_taps)
    zs = {-1: slice(None, -2), 0: slice(1, -1), 1: slice(2, None)}

    def build():
        wse = WSE_Interface()
        T = WSE_Array("T_p", init_data=T0)
        C = WSE_Array("C_p", init_data=C0)
        expr = 0.5 * T[1:-1, 0, 0]
        for (dz, dx, dy), c in zip(taps, coeffs):
            term = float(c) * T[zs[dz], dx, dy]
            if varcoef:
                term = C[1:-1, 0, 0] * term
            expr = expr + term
        with WSE_For_Loop("t", steps):
            T[1:-1, 0, 0] = expr
        return wse, T

    wse, T = build()
    ref = wse.make(answer=T, backend="jit")  # k single interpreter steps
    reset_stats()
    wse, T = build()
    out = wse.make(answer=T, backend="pallas", time_tile=k)
    np.testing.assert_allclose(out, ref, atol=1e-4)

    halo = max(max(abs(dx), abs(dy)) for _, dx, dy in taps + [(0, 0, 0)])
    k_eff = min(k, steps)
    expected = (steps // k_eff + steps % k_eff) if halo else 0
    assert stats.exchanges == expected  # one pad per tile, k x fewer
    assert stats.steps_run == steps


@pytest.mark.parametrize(
    "shape, seed, n_taps, steps, k, varcoef",
    [
        ((8, 9, 6), 0, 3, 8, 4, False),
        ((7, 10, 5), 1, 5, 6, 2, True),
        ((6, 6, 4), 2, 1, 5, 3, False),  # remainder + maybe z-only body
        ((10, 8, 7), 3, 4, 4, 4, True),
    ],
)
def test_tiled_matches_k_single_steps_fixed_cases(
    shape, seed, n_taps, steps, k, varcoef
):
    """Fixed draws of the property below — run even without hypothesis."""
    check_tiled_matches_k_single_steps(shape, seed, n_taps, steps, k, varcoef)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional test extra
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(
        shape=st.tuples(
            st.integers(6, 10), st.integers(6, 10), st.integers(4, 7)
        ),
        seed=st.integers(0, 10**6),
        n_taps=st.integers(1, 5),
        steps=st.integers(2, 8),
        k=st.integers(2, 4),
        varcoef=st.booleans(),
    )
    @settings(deadline=None, max_examples=15)
    def test_tiled_matches_k_single_steps_random_affine(
        shape, seed, n_taps, steps, k, varcoef
    ):
        check_tiled_matches_k_single_steps(shape, seed, n_taps, steps, k, varcoef)

"""Shared finite-difference gradient-check harness.

Central differences at fp64 against an analytic (VJP) gradient, with a
combined absolute + relative error criterion.  Importable both from the
test suite (``tests/test_adjoint.py``) and from the benchmark runner
(``benchmarks/adjoint_inverse.py`` smoke-checks its gradient with the same
harness before timing it).

Two deliberate choices, both learned the hard way on iterative solvers:

* **probe points, not full sweeps** — a full FD sweep over an (X, Y, Z)
  grid is O(cells) solves; a fixed-seed sample of interior + boundary
  points catches the same sign/offset/mask bugs at a tiny fraction of the
  cost;
* **``atol + rtol·scale`` denominators** — a pure relative error explodes
  wherever the true gradient is ~0 (e.g. warm-start entries whose FD
  signal is solver-tolerance noise ~1e-9 divided by a ~0 reference).  The
  criterion here is ``|fd − g| <= atol + rtol · max(|fd|, |g|)``, reported
  as the max scaled error over the probes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence, Tuple

import numpy as np


@dataclass
class GradCheckReport:
    """Outcome of one :func:`gradcheck` run (all probes, worst first)."""

    max_scaled_err: float  # max |fd − g| / (atol + rtol·scale); <= 1 passes
    worst_point: Tuple[int, ...]
    worst_fd: float
    worst_analytic: float
    probes: int

    @property
    def ok(self) -> bool:
        return self.max_scaled_err <= 1.0

    def __str__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"gradcheck: max scaled err {self.max_scaled_err:.3g} over "
            f"{self.probes} probes (worst at {self.worst_point}: "
            f"fd={self.worst_fd:.6g} vs analytic={self.worst_analytic:.6g})"
        )


def probe_points(shape, n: int, seed: int = 0) -> Sequence[Tuple[int, ...]]:
    """``n`` deterministic probe indices mixing interior and boundary cells.

    The first ``n // 2`` probes are drawn from the full index space (Moat
    faces included — the adjoint's boundary-row correction is exactly what
    they exercise); the rest from the strict interior.
    """
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        if i < n // 2 or min(shape) < 3:
            pts.append(tuple(int(rng.integers(0, s)) for s in shape))
        else:
            pts.append(tuple(int(rng.integers(1, s - 1)) for s in shape))
    return pts


def gradcheck(
    loss: Callable,
    x0,
    grad,
    *,
    eps: float = 1e-6,
    atol: float = 1e-8,
    rtol: float = 1e-5,
    n_probes: int = 8,
    seed: int = 0,
) -> GradCheckReport:
    """Compare ``grad`` (analytic, same shape as ``x0``) against central
    differences of ``loss`` at ``n_probes`` sampled entries of ``x0``.

    ``loss`` maps an array like ``x0`` to a scalar; it is called twice per
    probe at ``x0 ± eps·e_i``.  Run under fp64 (``JAX_ENABLE_X64``) — at
    fp32 the central difference itself carries ~1e-4 of cancellation noise
    and the default tolerances are unreachable.
    """
    x0 = np.asarray(x0, dtype=np.float64)
    grad = np.asarray(grad)
    worst = (0.0, (0,), 0.0, 0.0)
    pts = probe_points(x0.shape, n_probes, seed)
    for idx in pts:
        e = np.zeros_like(x0)
        e[idx] = eps
        fd = (float(loss(x0 + e)) - float(loss(x0 - e))) / (2.0 * eps)
        g = float(grad[idx])
        scaled = abs(fd - g) / (atol + rtol * max(abs(fd), abs(g)))
        if scaled > worst[0]:
            worst = (scaled, idx, fd, g)
    return GradCheckReport(
        max_scaled_err=worst[0],
        worst_point=worst[1],
        worst_fd=worst[2],
        worst_analytic=worst[3],
        probes=len(pts),
    )


def assert_gradcheck(loss, x0, grad, **kw) -> GradCheckReport:
    """:func:`gradcheck` + assert, with the full report in the message."""
    report = gradcheck(loss, x0, grad, **kw)
    assert report.ok, str(report)
    return report

"""Batched ensemble execution: the PR's acceptance surface.

The batch axis must be *semantically invisible*: a B-member batched run is
required to equal B independent single runs — bitwise for explicit stepping
(fp32 in-process, fp64 in a subprocess, since the batched step reuses the
exact same kernels on stacked operands), and to solver tolerance for the
masked Krylov loops (whose converged members freeze **bitwise** while the
loop runs to the slowest).  On top of that sit the API contracts: one
frozen :class:`repro.RunOptions` carries every policy knob (the legacy
``backend=``/``mesh=``/``time_tile=``/``resident=`` keywords warn once and
forward), :class:`repro.Ensemble` stacks members behind one program (and
rejects structurally different recordings), :class:`PlanSignature` gains a
``batch`` field whose default spelling keeps schema-1 manifests loading,
and the service coalesces same-signature requests into one batched launch.
"""

import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

import repro as wfa
from conftest import heat_init
from repro.core import Field, ForLoop, WFAInterface
from repro.engine import RunOptions, reset_stats
from repro.engine.stats import stats as estats

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def heat_member(T0, steps=5, c=0.1):
    center = 1.0 - 6.0 * c
    with WFAInterface() as wse:
        T = Field("T_e", init_data=T0)
        with ForLoop("t", steps):
            T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
                T[2:, 0, 0]
                + T[:-2, 0, 0]
                + T[1:-1, 1, 0]
                + T[1:-1, -1, 0]
                + T[1:-1, 0, 1]
                + T[1:-1, 0, -1]
            )
    return wse, T


def member_inits(b, shape=(8, 9, 6), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.uniform(250.0, 550.0, shape).astype(np.float32) for _ in range(b)]


# -- batched explicit stepping ------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jit", "pallas"])
def test_batched_make_matches_members_bitwise(backend):
    inits = member_inits(3)
    ens = wfa.Ensemble.from_programs([heat_member(T0) for T0 in inits])
    out = ens.make(options=RunOptions(backend=backend))
    assert out.shape == (3,) + inits[0].shape
    for b, T0 in enumerate(inits):
        wse, T = heat_member(T0)
        ref = wse.make(answer=T, options=RunOptions(backend=backend))
        assert (out[b] == ref).all(), f"member {b} diverges on {backend}"


def test_batched_make_tiled_remainder_bitwise():
    """time_tile with a remainder step, under a batch axis."""
    inits = member_inits(2, seed=3)
    ens = wfa.Ensemble.from_programs([heat_member(T0, steps=7) for T0 in inits])
    out = ens.make(options=RunOptions(backend="pallas", time_tile=4))
    for b, T0 in enumerate(inits):
        wse, T = heat_member(T0, steps=7)
        ref = wse.make(
            answer=T, options=RunOptions(backend="pallas", time_tile=4)
        )
        assert (out[b] == ref).all()


def test_batched_make_accounting():
    reset_stats()
    inits = member_inits(4, seed=5)
    ens = wfa.Ensemble.from_programs([heat_member(T0) for T0 in inits])
    ens.make(options=RunOptions(backend="pallas"))
    assert estats.ensemble_runs == 1
    assert estats.ensemble_members == 4


def test_batched_resident_fp64_bitwise_subprocess():
    """fp64 end-to-end: batched resident stepping == B single resident runs,
    bit for bit (x64 needs its own process)."""
    code = """
import numpy as np
import repro as wfa
from repro.core import Field, ForLoop, WFAInterface
from repro.engine import RunOptions

def member(T0, steps=6):
    with WFAInterface() as wse:
        T = Field("T64", init_data=T0, dtype=np.float64)
        with ForLoop("t", steps):
            T[1:-1, 0, 0] = 0.4 * T[1:-1, 0, 0] + 0.1 * (
                T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
                + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1])
    return wse, T

rng = np.random.default_rng(11)
inits = [rng.normal(size=(8, 8, 6)) for _ in range(3)]
ens = wfa.Ensemble.from_programs([member(T0) for T0 in inits])
out = ens.make(options=RunOptions(backend="pallas"))
assert out.dtype == np.float64
for b, T0 in enumerate(inits):
    wse, T = member(T0)
    ref = wse.make(answer=T, options=RunOptions(backend="pallas"))
    assert (out[b] == ref).all(), f"member {b} not bitwise at fp64"
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


# -- batched Krylov -----------------------------------------------------------


def varcoef_members(b=3, shape=(8, 8, 6), w=0.3):
    """Same recorded structure, per-member diffusivity → different
    conditioning → different per-member iteration counts."""
    from repro.solver.presets import record_varcoef_btcs

    T0 = heat_init(shape)
    coefs = [
        np.full(shape, 0.2 * (i + 1) ** 2, np.float32) for i in range(b)
    ]
    members = []
    for C0 in coefs:
        wse, T, C = record_varcoef_btcs(T0, C0, w)
        wse.__exit__()
        members.append((wse, T, C))
    return T0, coefs, members


@pytest.mark.parametrize("method", ["cg", "bicgstab", "pipecg"])
def test_batched_solve_matches_independent_members(method):
    """One masked loop over members with different conditioning == B
    independent solves, to solver tolerance."""
    from repro.solver.api import solve

    tol = 1e-6
    if method in ("cg", "pipecg"):
        # symmetric preset: vary the time-step weight via the init guess
        # instead — use the constant-coefficient BTCS system per member
        from repro.solver.presets import btcs_program

        shape = (8, 8, 6)
        prog = btcs_program(shape, 0.15, init_data=heat_init(shape))
        rng = np.random.default_rng(2)
        x0s = np.stack(
            [
                rng.uniform(250.0, 550.0, shape).astype(np.float32)
                for _ in range(3)
            ]
        )
        x, info = solve(
            prog, "T", method=method, tol=tol, maxiter=200,
            options=RunOptions(batch=3), member_env={"T": x0s},
            return_info=True,
        )
        refs = [
            solve(
                prog, "T", method=method, tol=tol, maxiter=200,
                member_env={"T": x0s[b]},
            )
            for b in range(3)
        ]
    else:
        T0, coefs, members = varcoef_members()
        wse, T, C = members[0]
        x, info = solve(
            wse.program, T.name, method=method, tol=tol, maxiter=200,
            options=RunOptions(batch=3),
            member_env={C.name: np.stack(coefs)},
            return_info=True,
        )
        refs = []
        for wse_b, T_b, _ in members:
            refs.append(
                solve(wse_b.program, T_b.name, method=method, tol=tol,
                      maxiter=200)
            )
    assert x.shape == (3,) + refs[0].shape
    for b, ref in enumerate(refs):
        scale = np.max(np.abs(ref))
        assert np.max(np.abs(x[b] - ref)) <= 50 * tol * scale, (
            f"member {b} off by {np.max(np.abs(x[b] - ref))}"
        )


def test_batched_solve_per_member_iterations():
    """Members with different conditioning report different iteration
    counts, recorded per member in the engine stats."""
    from repro.solver.api import solve

    reset_stats()
    T0, coefs, members = varcoef_members()
    wse, T, C = members[0]
    x, info = solve(
        wse.program, T.name, method="bicgstab", tol=1e-6, maxiter=200,
        options=RunOptions(batch=3), member_env={C.name: np.stack(coefs)},
        return_info=True,
    )
    iters = np.asarray(info.iterations)
    assert iters.shape == (1, 3)  # (steps, B)
    assert len(set(iters[0].tolist())) > 1, "members should converge apart"
    assert estats.member_iterations == tuple(int(v) for v in iters[0])
    assert estats.ensemble_runs == 1
    assert estats.ensemble_members == 3


def test_converged_members_frozen_bitwise():
    """A member that converges early must be *bitwise* identical whether the
    loop stops there or keeps running for the slowest member — the masking
    freezes its state, it does not keep iterating on it."""
    from repro.solver.api import solve

    T0, coefs, members = varcoef_members()
    wse, T, C = members[0]

    def run(maxiter):
        return solve(
            wse.program, T.name, method="bicgstab", tol=1e-6,
            maxiter=maxiter, options=RunOptions(batch=3),
            member_env={C.name: np.stack(coefs)}, return_info=True,
        )

    x_all, info = run(200)
    iters = np.asarray(info.iterations)[0]
    fast, slow = int(np.argmin(iters)), int(np.argmax(iters))
    assert iters[fast] < iters[slow]
    # stop right when the fastest member converged: its solution must be
    # exactly what the full run reports for it
    x_cut, _ = run(int(iters[fast]))
    assert (x_cut[fast] == x_all[fast]).all()


# -- Ensemble construction ----------------------------------------------------


def test_from_programs_rejects_structural_mismatch():
    T0 = member_inits(1)[0]
    a = heat_member(T0, steps=5)
    b = heat_member(T0, steps=6)  # different trip count
    with pytest.raises(ValueError, match="structurally different"):
        wfa.Ensemble.from_programs([a, b])


def test_ensemble_override_validation():
    wse, T = heat_member(member_inits(1)[0])
    with pytest.raises(ValueError, match="batch="):
        wfa.Ensemble(wse.program, T, overrides={})
    wse, T = heat_member(member_inits(1)[0])
    with pytest.raises(ValueError, match="stack"):
        wfa.Ensemble(wse.program, T, overrides={"T_e": np.zeros((8, 9, 6))})
    wse, T = heat_member(member_inits(1)[0])
    with pytest.raises(ValueError, match="not a field"):
        wfa.Ensemble(
            wse.program, T, overrides={"nope": np.zeros((2, 8, 9, 6))}
        )


def test_ensemble_infers_batch_and_broadcasts():
    inits = member_inits(4, seed=9)
    wse, T = heat_member(inits[0])
    ens = wfa.Ensemble(wse.program, T, overrides={"T_e": np.stack(inits)})
    assert ens.batch == 4
    env = ens.stacked_env()
    assert env["T_e"].shape == (4, 8, 9, 6)


# -- RunOptions ---------------------------------------------------------------


def test_runoptions_frozen_validated():
    o = RunOptions(backend="pallas", batch=8)
    with pytest.raises(Exception):
        o.backend = "jit"
    assert o.replace(batch=1).batch == 1
    assert o.batch == 8  # replace did not mutate
    with pytest.raises(ValueError):
        RunOptions(batch=0)


def test_legacy_kwargs_warn_once_then_stay_silent():
    import repro.engine.options as opts

    opts._WARNED.clear()
    T0 = member_inits(1)[0]
    wse, T = heat_member(T0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        wse.make(answer=T, backend="numpy")
    msgs = [str(x.message) for x in w if x.category is DeprecationWarning]
    assert any("RunOptions" in m and "backend" in m for m in msgs)
    wse, T = heat_member(T0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        wse.make(answer=T, backend="numpy")  # same (entry, kwarg): silent
    assert not [x for x in w if x.category is DeprecationWarning]


def test_options_and_legacy_kwarg_agree_on_result():
    T0 = member_inits(1)[0]
    wse, T = heat_member(T0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a = wse.make(answer=T, backend="jit")
    wse, T = heat_member(T0)
    b = wse.make(answer=T, options=RunOptions(backend="jit"))
    assert (a == b).all()


def test_implicit_entry_points_deprecated():
    import repro.core.implicit as implicit

    implicit._DEPRECATION_WARNED.clear()
    T0 = heat_init((8, 8, 6)).astype(np.float32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        implicit.btcs_solve(T0, 0.1, steps=1, maxiter=20)
    msgs = [str(x.message) for x in w if x.category is DeprecationWarning]
    assert any("wfa.solve" in m for m in msgs)


def test_package_surface_is_curated():
    for name in wfa.__all__:
        assert getattr(wfa, name) is not None
    assert wfa.Ensemble.__name__ == "Ensemble"
    assert "batch" in [f.name for f in __import__("dataclasses").fields(wfa.RunOptions)]


# -- service integration ------------------------------------------------------


def test_plan_signature_batch_field_and_manifest_compat(tmp_path):
    from repro.service import PlanSignature

    sig1 = PlanSignature("heat3d", (8, 8, 6))
    sigB = PlanSignature("heat3d", (8, 8, 6), batch=8)
    assert sig1.key() == "heat3d:8x8x6:float32:k1:pallas"  # unchanged
    assert sigB.key().endswith(":b8")
    assert PlanSignature.from_json(sigB.to_json()) == sigB
    # schema-1 manifest entries (no batch key) load as batch=1
    legacy = {"workload": "heat3d", "shape": [8, 8, 6]}
    assert PlanSignature.from_json(legacy).batch == 1
    with pytest.raises(ValueError):
        PlanSignature("heat3d", (8, 8, 6), batch=0)

    from repro.service.service import SimulationService

    svc = SimulationService(workers=1)
    svc._seen[sigB.key()] = sigB
    path = tmp_path / "manifest.json"
    svc.save_manifest(str(path))
    import json

    doc = json.loads(path.read_text())
    assert doc["schema"] == 2
    loaded = SimulationService._load_manifest(str(path))
    assert sigB in loaded


def test_service_micro_batch_coalesces_and_matches():
    """Queue three same-signature requests, then drive one worker turn by
    hand so the coalescing path runs deterministically (a live worker could
    legally dequeue the first request alone)."""
    from repro.runtime.fault import HeartbeatMonitor
    from repro.service import PlanSignature, SimulationService, StepRequest

    sig = PlanSignature("heat3d", (8, 8, 6))
    inits = [i.astype(np.float32) for i in member_inits(3, shape=(8, 8, 6))]
    svc = SimulationService(workers=1, capacity=16, micro_batch=4)
    svc._started = True  # accept submissions without live worker threads
    tickets = [svc.submit(StepRequest(sig, steps=6, init=T0)) for T0 in inits]
    group = svc.scheduler.get_group(timeout=1.0)
    units = svc._coalesce(group)
    assert [len(u) for u in units] == [3]
    svc._serve_batched(
        units[0], 0,
        lambda s: HeartbeatMonitor(threshold=svc.straggler_threshold),
    )
    outs = [t.result(timeout=1.0) for t in tickets]
    assert [t.stats.batch for t in tickets] == [3, 3, 3]
    with SimulationService(workers=1, capacity=16) as ref_svc:
        refs = [
            ref_svc.submit(StepRequest(sig, steps=6, init=T0)).result(
                timeout=300
            )
            for T0 in inits
        ]
    for out, ref in zip(outs, refs):
        assert (out == ref).all()


def test_service_batched_signature_direct():
    from repro.service import PlanSignature, SimulationService, StepRequest

    sig = PlanSignature("heat3d", (8, 8, 6), batch=3)
    init = np.stack(
        [i.astype(np.float32) for i in member_inits(3, shape=(8, 8, 6))]
    )
    with SimulationService(workers=1, capacity=8) as svc:
        t = svc.submit(StepRequest(sig, steps=4, init=init))
        out = t.result(timeout=300)
    assert out.shape == (3, 8, 8, 6)
    assert t.stats.batch == 3

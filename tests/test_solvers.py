"""Explicit + implicit solvers vs oracles (single device)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ftcs_oracle, heat_init
from repro.core.explicit import ftcs_solve
from repro.core.implicit import (btcs_solve, chebyshev_bounds, make_operator,
                                 psi)


def test_ftcs_matches_oracle():
    T0 = heat_init()
    out = np.asarray(ftcs_solve(jnp.asarray(T0), 0.1, 9))
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 9), atol=3e-4)


def test_ftcs_steady_state_uniform():
    """Uniform init + uniform BCs is a fixed point."""
    T0 = np.full((8, 8, 8), 100.0, np.float32)
    out = np.asarray(ftcs_solve(jnp.asarray(T0), 0.1, 50))
    np.testing.assert_allclose(out, T0, atol=1e-3)


def _dense_btcs(T0, w):
    shape = T0.shape
    n = T0.size
    psi_ = psi(w)

    def idx(x, y, z):
        return (x * shape[1] + y) * shape[2] + z

    A = np.eye(n)
    b = np.zeros(n)
    for x in range(shape[0]):
        for y in range(shape[1]):
            for z in range(shape[2]):
                i = idx(x, y, z)
                interior = (0 < x < shape[0] - 1 and 0 < y < shape[1] - 1
                            and 0 < z < shape[2] - 1)
                if interior:
                    for dx, dy, dz in [(1, 0, 0), (-1, 0, 0), (0, 1, 0),
                                       (0, -1, 0), (0, 0, 1), (0, 0, -1)]:
                        A[i, idx(x + dx, y + dy, z + dz)] = -w * psi_
                    b[i] = psi_ * T0[x, y, z]
                else:
                    b[i] = T0[x, y, z]
    return np.linalg.solve(A, b).reshape(shape)


@pytest.mark.parametrize("method,maxiter,atol", [
    ("cg", 400, 2e-4), ("pipecg", 400, 5e-3), ("chebyshev", 80, 2e-4)])
def test_btcs_one_step_vs_dense(method, maxiter, atol):
    T0 = heat_init((7, 8, 9))
    ref = _dense_btcs(T0, 0.1)
    out, aux = btcs_solve(jnp.asarray(T0), 0.1, 1, method=method,
                          tol=1e-7, maxiter=maxiter)
    np.testing.assert_allclose(np.asarray(out), ref, atol=atol)


def test_methods_agree_multistep():
    T0 = heat_init((6, 6, 6))
    a, _ = btcs_solve(jnp.asarray(T0), 0.1, 3, method="cg", tol=1e-7,
                      maxiter=300)
    b, _ = btcs_solve(jnp.asarray(T0), 0.1, 3, method="pipecg", tol=1e-7,
                      maxiter=300)
    c, _ = btcs_solve(jnp.asarray(T0), 0.1, 3, method="chebyshev",
                      maxiter=80)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-3)


def test_operator_spd_on_interior():
    """A is SPD on the interior subspace: x'Ax > 0 for interior x ≠ 0."""
    shape = (6, 7, 5)
    A, rhs, dot, mask = make_operator(0.1, shape)
    rng = np.random.default_rng(3)
    for _ in range(5):
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        x = jnp.where(mask, x, 0.0)
        val = float(dot(x, A(x)))
        assert val > 0.0


def test_chebyshev_bounds_bracket_spectrum():
    lmin, lmax = chebyshev_bounds(0.1)
    assert 0.0 < lmin < 1.0 < lmax
    np.testing.assert_allclose(lmin, 0.625)
    np.testing.assert_allclose(lmax, 1.375)


def test_jacobi_matches_cg():
    """Reduction-free Jacobi (0 collectives/iter) agrees with CG."""
    T0 = heat_init((7, 8, 9))
    ref, _ = btcs_solve(jnp.asarray(T0), 0.1, 2, method="cg", tol=1e-7,
                        maxiter=300)
    jac, _ = btcs_solve(jnp.asarray(T0), 0.1, 2, method="jacobi",
                        maxiter=40)
    np.testing.assert_allclose(np.asarray(jac), np.asarray(ref), atol=5e-4)

"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit tests run on 1 device;
multi-device tests spawn subprocesses (see tests/test_sharded.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def ftcs_oracle(T, w, steps):
    """NumPy FTCS reference used across solver tests."""
    T = T.copy()
    for _ in range(steps):
        new = T.copy()
        new[1:-1, 1:-1, 1:-1] = (
            (1 - 6 * w) * T[1:-1, 1:-1, 1:-1]
            + w * (T[2:, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1]
                   + T[1:-1, 2:, 1:-1] + T[1:-1, :-2, 1:-1]
                   + T[1:-1, 1:-1, 2:] + T[1:-1, 1:-1, :-2]))
        T = new
    return T


def heat_init(shape=(10, 12, 14)):
    T = np.full(shape, 500.0, np.float32)
    T[1:-1, 1:-1, 0] = 300.0
    T[1:-1, 1:-1, -1] = 400.0
    return T

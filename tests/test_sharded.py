"""Multi-device behaviour (4 fake CPU devices via subprocess — the main
pytest process must keep 1 device for the unit tests)."""
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


PREAMBLE = """
import jax, numpy as np, jax.numpy as jnp
from repro.core.jaxcompat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
T0 = np.ones((8, 12, 10), np.float32) * 500.0
T0[1:-1, 1:-1, 0] = 300.0
T0[1:-1, 1:-1, -1] = 400.0

def oracle(T, w, steps):
    T = T.copy()
    for _ in range(steps):
        new = T.copy()
        new[1:-1,1:-1,1:-1] = (1-6*w)*T[1:-1,1:-1,1:-1] + w*(
            T[2:,1:-1,1:-1]+T[:-2,1:-1,1:-1]+T[1:-1,2:,1:-1]
            +T[1:-1,:-2,1:-1]+T[1:-1,1:-1,2:]+T[1:-1,1:-1,:-2])
        T = new
    return T
"""


def test_sharded_ftcs_variants_match_oracle():
    out = run_py(PREAMBLE + """
from repro.core.explicit import make_sharded_ftcs
o = oracle(T0, 0.1, 6)
for kw, steps in [({}, 6), (dict(overlap=True), 6),
                  (dict(halo_depth=3), 2), (dict(use_kernel=True), 6)]:
    step, sh = make_sharded_ftcs(mesh, T0.shape, 0.1, steps_per_call=steps,
                                 **kw)
    got = np.asarray(jax.device_get(step(jax.device_put(jnp.asarray(T0),
                                                        sh))))
    err = abs(got - o).max()
    assert err < 2e-3, (kw, err)
print("OK")
""")
    assert "OK" in out


def test_sharded_implicit_all_methods():
    out = run_py(PREAMBLE + """
from repro.core.implicit import make_sharded_implicit, btcs_solve
ref, _ = btcs_solve(jnp.asarray(T0), 0.1, 2, method="cg", tol=1e-7,
                    maxiter=400)
for m in ["cg", "pipecg", "chebyshev"]:
    for kernel in [False, True]:
        step, sh = make_sharded_implicit(mesh, T0.shape, 0.1, method=m,
                                         tol=1e-6, maxiter=200, steps=2,
                                         use_kernel=kernel)
        got = np.asarray(jax.device_get(step(jax.device_put(
            jnp.asarray(T0), sh))))
        err = abs(got - np.asarray(ref)).max()
        assert err < 5e-3, (m, kernel, err)
print("OK")
""")
    assert "OK" in out


def test_wfa_frontend_sharded_backend():
    out = run_py(PREAMBLE + """
from repro.core import WSE_Interface, WSE_Array, WSE_For_Loop
o = oracle(T0, 0.1, 5)
wse = WSE_Interface()
c = 0.1; center = 1.0 - 6.0 * c
T_n = WSE_Array('T_n', init_data=T0)
with WSE_For_Loop('t', 5):
    T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] + c * (
        T_n[2:, 0, 0] + T_n[:-2, 0, 0] + T_n[1:-1, 1, 0]
        + T_n[1:-1, 0, -1] + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])
a = wse.make(answer=T_n, backend='shard_map', mesh=mesh)
assert abs(a - o).max() < 2e-3
print("OK")
""")
    assert "OK" in out


def test_wfa_frontend_sharded_pallas_backend():
    """backend='pallas' with a mesh: halo-pad brick → fused kernel inside
    shard_map, one pallas_call per loop body."""
    out = run_py(PREAMBLE + """
from repro.core import WSE_Interface, WSE_Array, WSE_For_Loop
from repro.compiler import stats
o = oracle(T0, 0.1, 5)
wse = WSE_Interface()
c = 0.1; center = 1.0 - 6.0 * c
T_n = WSE_Array('T_n', init_data=T0)
with WSE_For_Loop('t', 5):
    T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] + c * (
        T_n[2:, 0, 0] + T_n[:-2, 0, 0] + T_n[1:-1, 1, 0]
        + T_n[1:-1, 0, -1] + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])
a = wse.make(answer=T_n, backend='pallas', mesh=mesh)
assert abs(a - o).max() < 2e-3
assert stats.kernels_built == 1 and stats.fallbacks == 0, stats
print("OK")
""")
    assert "OK" in out


def test_wfa_frontend_sharded_time_tiled():
    """time_tile=k under shard_map: depth-k·h ppermute halo exchange once per
    k steps; engine stats must show exchanges-per-step dropped k× and the
    result must ftol-match the untiled oracle."""
    out = run_py(PREAMBLE + """
from repro.core import WSE_Interface, WSE_Array, WSE_For_Loop
from repro.engine import stats, reset_stats

def build(steps):
    wse = WSE_Interface()
    c = 0.1; center = 1.0 - 6.0 * c
    T_n = WSE_Array('T_n', init_data=T0)
    with WSE_For_Loop('t', steps):
        T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] + c * (
            T_n[2:, 0, 0] + T_n[:-2, 0, 0] + T_n[1:-1, 1, 0]
            + T_n[1:-1, 0, -1] + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])
    return wse, T_n

o = oracle(T0, 0.1, 8)
reset_stats()
wse, T_n = build(8)
a = wse.make(answer=T_n, backend='pallas', mesh=mesh, time_tile=4)
assert abs(a - o).max() < 2e-3, abs(a - o).max()
assert stats.exchanges_per_step == 0.25, stats   # ONE exchange per 4 steps
assert stats.tiles_fused == 2 and stats.max_time_tile == 4, stats
print("OK")
""")
    assert "OK" in out


def test_small_mesh_dryrun_and_multipod():
    """A reduced-scale production dry-run (2×2 and 2×2×2 with pod axis)."""
    out = run_py("""
import jax, json
from repro.launch.mesh import make_mesh2d
from repro.launch.dryrun import run_cell
for mesh in [make_mesh2d(2, 2), make_mesh2d(1, 2, pod=2)]:
    rec = run_cell("qwen3-0.6b", "decode_32k", mesh=mesh, verbose=False,
                   calibrate=False)
    assert rec["t_total"] > 0 and rec["bound"] in (
        "compute", "memory", "collective")
print("OK")
""", devices=8)
    assert "OK" in out


def test_train_step_sharded_loss_decreases():
    out = run_py("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.train import build
from repro.launch.mesh import make_mesh2d
from repro.data import TokenDataset, shard_batch
from repro.parallel.sharding import use_sharding

mesh = make_mesh2d(2, 2)
cfg = get_config("qwen3-0.6b").smoke()
import dataclasses
cfg = dataclasses.replace(cfg, num_microbatches=2)
params, opt, jitted, rules = build(cfg, mesh, peak_lr=5e-3, warmup=2)
ds = TokenDataset(cfg.vocab_size, 32, 8)
sh = jax.sharding.NamedSharding(mesh, rules.spec(("batch", "seq"), (8, 32)))
losses = []
with use_sharding(rules):
    for i in range(14):
        batch = shard_batch(ds.next_batch(), sh)
        params, opt, m = jitted(params, opt, batch)
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("OK", losses[0], "->", losses[-1])
""")
    assert "OK" in out


def test_elastic_remesh_roundtrip():
    out = run_py("""
import jax, numpy as np, jax.numpy as jnp
from repro.runtime.elastic import remesh
from repro.launch.mesh import make_mesh2d
from jax.sharding import PartitionSpec as P, NamedSharding

m1 = make_mesh2d(2, 2)
m2 = make_mesh2d(4, 1)
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
specs = {"w": P("data", "model")}
a = jax.device_put(tree["w"], NamedSharding(m1, specs["w"]))
out = remesh({"w": a}, specs, m2)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
assert out["w"].sharding.mesh.shape["data"] == 4
print("OK")
""")
    assert "OK" in out


def test_zero_extended_optimizer_specs():
    """ZeRO moment sharding: moments gain a data-axis dim, params don't."""
    out = run_py("""
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh2d
from repro.launch.specs import _zero_extend

mesh = make_mesh2d(2, 2)
class L:  # shape carrier
    def __init__(s, shape): s.shape = shape

# free dim divisible by dp=2 → extended
assert _zero_extend(P(None, "model"), (8, 4), mesh) == P("data", "model")
# data already used → unchanged
assert _zero_extend(P("data", None), (8, 4), mesh) == P("data", None)
# nothing divisible → unchanged
assert _zero_extend(P(None, "model"), (7, 4), mesh) == P(None, "model")
# largest free divisible dim wins
assert _zero_extend(P(None, None), (4, 16), mesh) == P(None, "data")
print("OK")
""")
    assert "OK" in out

"""Fault-tolerance primitives: heartbeat, resilient loop, injector, remesh.

Pins the behaviors the serving layer leans on: the straggler threshold is a
strict boundary (exactly ``threshold × median`` does not flag), the
resilient loop's failure budget resets on success and restores before
re-raising, the fault injector fires each armed fault exactly once (so a
retry makes progress) and restores the previous hooks on exit, and a
1-device remesh round-trips state bitwise.
"""

import numpy as np
import pytest

from repro.compiler import LoweringError
from repro.engine import hooks
from repro.runtime.elastic import remesh, shrink_plan
from repro.runtime.fault import (
    FaultInjector,
    HeartbeatMonitor,
    InjectedFault,
    ResilientLoop,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# -- HeartbeatMonitor ---------------------------------------------------------


def _run_steps(mon, clock, durations):
    for i, dt in enumerate(durations):
        mon.start_step(i)
        clock.advance(dt)
        mon.end_step()


def test_heartbeat_threshold_is_a_strict_boundary():
    clock = FakeClock()
    flags = []
    mon = HeartbeatMonitor(
        threshold=3.0, on_straggler=lambda s, r: flags.append((s, r)),
        clock=clock,
    )
    # history of 1.0s steps, then exactly 3.0x the median: NOT flagged
    _run_steps(mon, clock, [1.0, 1.0, 1.0, 3.0])
    assert mon.flagged == [] and flags == []
    # strictly above the boundary: flagged, with the ratio reported
    mon.start_step(4)
    clock.advance(3.5)
    mon.end_step()
    assert mon.flagged == [4]
    assert flags == [(4, pytest.approx(3.5))]


def test_heartbeat_first_step_never_flags():
    clock = FakeClock()
    mon = HeartbeatMonitor(threshold=1.01, clock=clock)
    _run_steps(mon, clock, [1000.0])  # no history yet -> no median to trail
    assert mon.flagged == []


def test_heartbeat_median_window_slides():
    clock = FakeClock()
    mon = HeartbeatMonitor(threshold=2.0, window=4, clock=clock)
    # slow history ages out of the window; a 1.0s step against a 0.1s
    # recent median is a straggler even though the *global* median is not
    _run_steps(mon, clock, [5.0, 5.0, 5.0, 5.0, 0.1, 0.1, 0.1, 0.1])
    assert mon.flagged == []
    mon.start_step(8)
    clock.advance(1.0)
    mon.end_step()
    assert mon.flagged == [8]


def test_heartbeat_end_without_start_is_a_noop():
    mon = HeartbeatMonitor(clock=FakeClock())
    mon.end_step()
    assert mon.durations == []


# -- ResilientLoop ------------------------------------------------------------


class _Dataset:
    def next_batch(self):
        return None


def _resilient(step_fn, max_failures=3, ckpt_every=2):
    saves = []
    restores = []

    def save_fn(step, state):
        saves.append((step, state))

    def restore_fn():
        restores.append(True)
        return (saves[-1][1], saves[-1][0]) if saves else (0, 0)

    loop = ResilientLoop(
        step_fn, save_fn, restore_fn, _Dataset(),
        ckpt_every=ckpt_every, max_failures=max_failures,
    )
    return loop, saves, restores


def test_resilient_loop_restores_and_continues():
    calls = []

    def step_fn(state, batch):
        calls.append(state)
        if state == 3 and calls.count(3) == 1:  # fail once at step 3
            raise RuntimeError("injected")
        return state + 1, {"loss": state}

    loop, saves, restores = _resilient(step_fn)
    state, step, metrics = loop.run(0, 0, 6)
    assert (state, step) == (6, 6)
    assert restores == [True]  # exactly one restore for one failure
    assert saves[0][0] == 2  # checkpointed before the failure
    assert loop.failures == 0  # success reset the consecutive-failure count


def test_resilient_loop_failure_budget_resets_on_success():
    """2 failures, success, 2 failures stays under max_failures=2 because
    the counter is *consecutive*; 3 in a row without progress raises."""
    script = iter([False, True, True, False, True, True, False])

    def step_fn(state, batch):
        if next(script, False):
            raise RuntimeError("flaky")
        return state + 1, None

    loop, _, _ = _resilient(step_fn, max_failures=2, ckpt_every=1)
    state, step, _ = loop.run(0, 0, 3)
    assert (state, step) == (3, 3)

    def always_fail(state, batch):
        raise RuntimeError("dead")

    loop, _, _ = _resilient(always_fail, max_failures=2, ckpt_every=1)
    with pytest.raises(RuntimeError, match="dead"):
        loop.run(0, 0, 1)
    assert loop.failures == 3  # max_failures consecutive, then the raise


# -- FaultInjector ------------------------------------------------------------


def test_injector_step_fault_fires_exactly_once():
    with FaultInjector(fail_at=[2]) as inj:
        hooks.fire_step_hook(0)
        hooks.fire_step_hook(1)
        with pytest.raises(InjectedFault):
            hooks.fire_step_hook(2)
        hooks.fire_step_hook(2)  # the retry: armed step already consumed
    assert inj.fired == [("step", 2, "")]


def test_injector_match_tag_scopes_the_fault():
    with FaultInjector(fail_at=[0], match_tag="victim") as inj:
        hooks.fire_step_hook(0, tag="bystander")
        with pytest.raises(InjectedFault):
            hooks.fire_step_hook(0, tag="victim")
    assert inj.fired == [("step", 0, "victim")]


def test_injector_compile_fault_raises_lowering_error_once():
    with FaultInjector(fail_compile=["body"]) as inj:
        hooks.fire_compile_hook("other")  # not armed
        with pytest.raises(LoweringError, match="injected compile failure"):
            hooks.fire_compile_hook("body")
        hooks.fire_compile_hook("body")  # consumed
    assert inj.fired == [("compile", "body")]


def test_injector_restores_previous_hooks():
    seen = []
    prev = hooks.set_step_hook(lambda step, tag="": seen.append(step))
    try:
        with FaultInjector(fail_at=[99]):
            pass
        hooks.fire_step_hook(7)
        assert seen == [7]  # the pre-injector hook is back
    finally:
        hooks.set_step_hook(prev)


def test_injector_slowdown_is_recorded():
    with FaultInjector(slow_at={1: 0.0}) as inj:
        hooks.fire_step_hook(1)
        hooks.fire_step_hook(1)  # consumed: no second record
    assert inj.fired == [("slow", 1, "")]


# -- elastic remesh -----------------------------------------------------------


def test_remesh_roundtrip_on_single_device_mesh(rng):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.core.jaxcompat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    tree = {
        "w": rng.normal(size=(4, 6)).astype(np.float32),
        "b": rng.normal(size=(6,)).astype(np.float32),
    }
    specs = {"w": P("data", "model"), "b": P(None)}
    placed = remesh(tree, specs, mesh)
    again = remesh(placed, specs, mesh)  # remesh of a remesh: still exact
    for k, v in tree.items():
        assert (np.asarray(jax.device_get(again[k])) == v).all()
        assert placed[k].sharding.mesh.shape == mesh.shape


def test_shrink_plan_preserves_global_batch_semantics():
    plan = shrink_plan(
        old_dp=8, new_dp=4, global_batch=64, num_microbatches=2
    )
    # option A: same tokens/step via more microbatches
    assert plan["keep_global_batch"]["num_microbatches"] == 4
    # option B: smaller global batch with the LR rescale factor
    assert plan["keep_microbatches"]["global_batch"] == 32
    assert plan["keep_microbatches"]["lr_scale"] == pytest.approx(0.5)


# -- numerical faults vs infrastructure faults --------------------------------


def test_numerical_fault_fails_fast_never_retried():
    """A poisoned solve fails deterministically: re-running it would only
    repoison, so the worker fails the ticket on the first
    ``NumericalFault`` with zero retries — while a transient injected
    fault on the very same service still restores and completes."""
    from repro.engine.health import NumericalFault
    from repro.service import (
        PlanSignature,
        SimulationService,
        SolveRequest,
        StepRequest,
    )

    solve_sig = PlanSignature("btcs_heat", (8, 8, 6))
    step_sig = PlanSignature("heat3d", (8, 8, 6))
    svc = SimulationService(
        workers=1, capacity=64, manifest=[solve_sig, step_sig],
        default_chunk=2,
    )
    svc.start()
    try:
        poison = np.full(solve_sig.shape, np.nan, solve_sig.dtype)
        t = svc.submit(SolveRequest(solve_sig, maxiter=40, init=poison))
        with pytest.raises(NumericalFault) as exc:
            t.result(timeout=300)
        assert exc.value.outcome == "NAN_RESIDUAL"
        assert t.stats.retries == 0  # fail fast: no retry budget burned
        assert t.stats.outcome == "NAN_RESIDUAL"

        req = StepRequest(step_sig, steps=4)
        with FaultInjector(fail_at=[2], match_tag=req.request_id):
            t2 = svc.submit(req)
            t2.result(timeout=300)
        assert t2.stats.retries == 1  # infrastructure faults still retry
    finally:
        svc.stop()

"""Docs-as-tests: every documented example must execute.

Two doctest passes keep the documentation from rotting:

* ``docs/*.md`` — each page's ``>>>`` snippets run as a doctest file (the
  equivalent of ``pytest --doctest-glob='*.md' docs/``, kept inside the
  tier-1 suite so one command verifies everything);
* module doctests — the runnable examples in the public-API docstrings
  (``make``, ``run_sharded``, ``solve``, ``Operator``/``Rhs``, engine
  stats, the multigrid options).

Examples use tiny grids so the whole pass stays in seconds; state leaking
between snippets is prevented by running each file/module in a fresh
doctest namespace (and the frontend releases its program on ``make`` /
``solve`` / context exit, which the examples exercise on purpose).
"""

import doctest
import importlib
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted((ROOT / "docs").glob("*.md"))

DOC_MODULES = [
    "repro",
    "repro.core.ensemble",
    "repro.core.halo",
    "repro.core.program",
    "repro.engine.layout",
    "repro.engine.stats",
    "repro.service.service",
    "repro.solver.adjoint",
    "repro.solver.api",
    "repro.solver.frontend",
    "repro.solver.multigrid",
]

FLAGS = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE


def test_docs_tree_exists():
    names = {p.name for p in DOCS}
    required = {
        "architecture.md",
        "solvers.md",
        "time_tiling.md",
        "benchmarks.md",
        "service.md",
        "ensembles.md",
        "adjoint.md",
        "robustness.md",
    }
    assert required <= names, f"missing docs pages: {required - names}"


@pytest.mark.parametrize("path", DOCS, ids=lambda p: p.name)
def test_docs_examples_run(path, monkeypatch):
    monkeypatch.chdir(ROOT)  # pages reference repo-root files (BENCH_*.json)
    result = doctest.testfile(
        str(path), module_relative=False, optionflags=FLAGS, verbose=False
    )
    assert result.failed == 0, f"{path.name}: {result.failed} doctest failures"
    assert result.attempted > 0, f"{path.name} has no runnable examples"


@pytest.mark.parametrize("name", DOC_MODULES)
def test_module_doctests(name):
    mod = importlib.import_module(name)
    result = doctest.testmod(mod, optionflags=FLAGS, verbose=False)
    assert result.failed == 0, f"{name}: {result.failed} doctest failures"
    assert result.attempted > 0, f"{name} has no docstring examples"

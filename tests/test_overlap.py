"""Exchange/compute overlap + measured cost model (PR 8).

The tentpole invariant: splitting a fused launch into an interior kernel
(concurrent with the margin-slab exchange) plus four boundary shells is
**bitwise** identical to the monolithic launch — fp32 in-process, fp64 and
the 2×2 sharded mesh in subprocesses, batched ensembles, and the tiled
remainder path.  On top: the cost model's manifest round-trip, the planner's
``overlap="auto"`` policy (split only when a calibrated entry predicts a
win), model-driven ``auto_tile`` never losing to k=1 by construction, and
the overlap stats counters — all with zero interpreter fallbacks.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.compiler import lower_group
from repro.compiler import reset_stats as compiler_reset
from repro.compiler import stats as compiler_stats
from repro.compiler.ir import auto_tile, split_regions
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface, perfmodel
from repro.core.perfmodel import (
    CostModel,
    MeasuredCost,
    body_signature,
    predict_step_us,
    tile_cells,
)
from repro.core.program import _group_ops
from repro.engine import RunOptions, plan, reset_stats, stats
from repro.engine.executor import run_program

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean():
    reset_stats()
    compiler_reset()
    perfmodel.cost_model.clear()
    yield
    perfmodel.cost_model.clear()


def build_heat(T0, steps, c=0.1):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    wse.__exit__()
    return wse.program


def _t0(nx=12, ny=12, nz=4):
    rng = np.random.default_rng(7)
    return rng.uniform(250.0, 500.0, size=(nx, ny, nz)).astype(np.float32)


def _heat_group(program):
    _, ops = next(g for g in _group_ops(program) if g[0] is not None)
    return lower_group(ops)


# -- bitwise equivalence (in-process fp32) ------------------------------------


@pytest.mark.parametrize("steps,k", [(6, 1), (6, 2), (7, 2)])
def test_split_matches_monolithic_bitwise(steps, k):
    """Forced overlap split == monolithic, including the n % k remainder."""
    T0 = _t0()
    base = run_program(
        build_heat(T0, steps),
        options=RunOptions(backend="pallas", time_tile=k, overlap=False),
    )
    ov = run_program(
        build_heat(T0, steps),
        options=RunOptions(backend="pallas", time_tile=k, overlap=True),
    )
    assert (base["T_n"] == ov["T_n"]).all()
    assert compiler_stats.fallbacks == 0


def test_split_matches_monolithic_batched():
    """B>1 ensemble stepping splits bitwise too (vmapped launches)."""
    T0 = _t0()
    stack = np.stack([T0, T0 + 1.0, T0 * 1.01])
    base = run_program(
        build_heat(T0, 6),
        env={"T_n": stack},
        options=RunOptions(backend="pallas", time_tile=2, batch=3, overlap=False),
    )
    ov = run_program(
        build_heat(T0, 6),
        env={"T_n": stack},
        options=RunOptions(backend="pallas", time_tile=2, batch=3, overlap=True),
    )
    assert ov["T_n"].shape[0] == 3
    assert (base["T_n"] == ov["T_n"]).all()
    # batched members match the unbatched run member-for-member
    single = run_program(
        build_heat(T0, 6),
        options=RunOptions(backend="pallas", time_tile=2, overlap=True),
    )
    assert (ov["T_n"][0] == single["T_n"]).all()
    assert compiler_stats.fallbacks == 0


def test_overlap_stats_counters():
    """Split runs count interior/boundary launches + overlapped exchanges."""
    T0 = _t0()
    run_program(
        build_heat(T0, 6),
        options=RunOptions(backend="pallas", time_tile=2, overlap=True),
    )
    # 3 tiles: one interior + 4 shells each, slabs in flight per tile
    assert stats.interior_launches == 3
    assert stats.boundary_launches == 12
    assert stats.overlapped_exchanges == 3
    reset_stats()
    run_program(
        build_heat(T0, 6),
        options=RunOptions(backend="pallas", time_tile=2, overlap=False),
    )
    assert stats.interior_launches == 0
    assert stats.boundary_launches == 0
    assert stats.overlapped_exchanges == 0


def test_split_refused_keeps_monolithic():
    """A brick too small for the interior at depth k·h silently keeps the
    monolithic launch (split=0) — and still runs correctly."""
    T0 = _t0(6, 6, 4)  # k=4 -> m=4, 6 <= 2*4: no interior
    p = plan(
        build_heat(T0, 8), RunOptions(backend="pallas", time_tile=4, overlap=True)
    )
    seg = next(s for s in p.segments if s.loop is not None)
    assert seg.split == 0
    base = run_program(
        build_heat(T0, 8),
        options=RunOptions(backend="pallas", time_tile=4, overlap=False),
    )
    ov = run_program(
        build_heat(T0, 8),
        options=RunOptions(backend="pallas", time_tile=4, overlap=True),
    )
    assert (base["T_n"] == ov["T_n"]).all()


# -- the "auto" policy --------------------------------------------------------


def _fake_entry(program, nz, dtype, **kw):
    group = _heat_group(program)
    vals = dict(cell_ns=0.001, launch_us=1.0, exchange_us=1.0, boundary_us=1.0)
    vals.update(kw)
    return MeasuredCost(
        signature=body_signature(group, nz, dtype),
        device=perfmodel.current_device(),
        **vals,
    )


def test_auto_overlap_uncalibrated_keeps_monolithic():
    # default overlap="auto" with no calibrated entry: stay monolithic
    p = plan(build_heat(_t0(), 6), RunOptions(backend="pallas", time_tile=2))
    seg = next(s for s in p.segments if s.loop is not None)
    assert seg.split == 0 and stats.cost_model_hits == 0


def test_auto_overlap_splits_when_model_predicts_win():
    program = build_heat(_t0(), 6)
    # exchange dominates and shells are free -> split predicted faster
    perfmodel.cost_model.put(
        _fake_entry(program, 4, np.float32, exchange_us=500.0, boundary_us=0.0)
    )
    p = plan(program, RunOptions(backend="pallas", time_tile=2))
    seg = next(s for s in p.segments if s.loop is not None)
    assert seg.split == 4 and stats.cost_model_hits == 1


def test_auto_overlap_keeps_monolithic_when_model_predicts_loss():
    program = build_heat(_t0(), 6)
    # boundary launches cost a fortune -> split predicted slower
    perfmodel.cost_model.put(
        _fake_entry(program, 4, np.float32, exchange_us=0.1, boundary_us=1000.0)
    )
    p = plan(program, RunOptions(backend="pallas", time_tile=2))
    seg = next(s for s in p.segments if s.loop is not None)
    assert seg.split == 0 and stats.cost_model_hits == 1


def test_run_options_validates_overlap():
    with pytest.raises(ValueError, match="overlap"):
        RunOptions(overlap="bogus")


# -- measured cost model ------------------------------------------------------


def test_calibrate_and_manifest_roundtrip(tmp_path):
    program = build_heat(_t0(), 4)
    manifest = str(tmp_path / "cost.json")
    entries = perfmodel.calibrate_program(
        program, ks=(1, 2), reps=1, inner=2, manifest=manifest
    )
    entry = entries["T_n"]
    assert stats.calibrations == 1
    assert entry.cell_ns >= 0 and entry.exchange_us >= 0
    fresh = CostModel()
    assert fresh.load_manifest(manifest) == 1
    assert fresh.entries[entry.signature] == entry
    # the planner sees the calibrated entry
    reset_stats()
    plan(program, RunOptions(backend="pallas"))
    assert stats.cost_model_hits == 1


def test_manifest_env_preload(tmp_path, monkeypatch):
    program = build_heat(_t0(), 4)
    entry = _fake_entry(program, 4, np.float32)
    boxed = CostModel()
    boxed.put(entry)
    path = str(tmp_path / "env_cost.json")
    boxed.save_manifest(path)
    monkeypatch.setenv(perfmodel.MANIFEST_ENV, path)
    fresh = CostModel()
    assert fresh.get(entry.signature) == entry  # lazy env-manifest load


def test_manifest_rejects_wrong_schema(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"schema": 99, "entries": {}}')
    with pytest.raises(ValueError, match="schema"):
        CostModel().load_manifest(str(path))


def test_signature_ignores_brick_but_not_dtype():
    program = build_heat(_t0(), 4)
    group = _heat_group(program)
    s32 = body_signature(group, 4, np.float32)
    assert body_signature(group, 4, np.float32) == s32
    assert body_signature(group, 4, np.float64) != s32
    assert body_signature(group, 8, np.float32) != s32


def test_tile_cells_trapezoid():
    assert tile_cells((8, 8), 4, 1, 1) == 8 * 8 * 4
    # k=2: 10x10 first sub-step + 8x8 second, per z plane
    assert tile_cells((8, 8), 4, 1, 2) == (100 + 64) * 4
    # split cells always cover at least the monolithic cells (redundant
    # window recompute at the region seams)
    sp = perfmodel._split_cells((16, 16), 4, 1, 2)
    assert sp is not None
    interior, shells, n_sh = sp
    assert n_sh == 4
    assert interior + shells >= tile_cells((16, 16), 4, 1, 2)


def test_auto_tile_never_loses_to_k1():
    """Model-driven auto_tile: the pick's predicted time <= k=1's, for
    adversarial cost entries (k=1 is always a candidate by construction)."""
    program = build_heat(_t0(), 8)
    group = _heat_group(program)
    cases = [
        dict(cell_ns=100.0, launch_us=0.0, exchange_us=0.0, boundary_us=0.0),
        dict(cell_ns=0.0, launch_us=500.0, exchange_us=0.0, boundary_us=0.0),
        dict(cell_ns=0.001, launch_us=1.0, exchange_us=900.0, boundary_us=0.1),
        dict(cell_ns=50.0, launch_us=50.0, exchange_us=50.0, boundary_us=50.0),
    ]
    for vals in cases:
        cost = MeasuredCost(signature="x", device="cpu", **vals)
        k = auto_tile(group, (16, 16), 8, cost=cost, nz=4)
        t_k = min(
            predict_step_us(cost, (16, 16), 4, group.halo, k),
            predict_step_us(cost, (16, 16), 4, group.halo, k, split=True),
        )
        t_1 = predict_step_us(cost, (16, 16), 4, group.halo, 1)
        assert t_k <= t_1, vals
    # illegal split scores inf, never selected
    tiny = MeasuredCost("x", "cpu", 1.0, 1.0, 1.0, 1.0)
    assert predict_step_us(tiny, (4, 4), 4, 1, 2, split=True) == float("inf")


def test_split_regions_partition():
    """Interior + shells tile the brick exactly (disjoint, full cover)."""
    program = build_heat(_t0(), 4)
    group = _heat_group(program)
    sp = split_regions(group, 2, (12, 12))
    cover = np.zeros((12, 12), int)
    for r in (sp.interior,) + sp.shells:
        cover[r.x0 : r.x0 + r.rx, r.y0 : r.y0 + r.ry] += 1
    assert (cover == 1).all()
    assert split_regions(group, 6, (12, 12)) is None  # 12 <= 2*6


# -- fp64 + sharded exactness (subprocesses) ----------------------------------


def run_py(code: str, devices: int = 1, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


SUB_PRELUDE = """
import numpy as np
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.engine import RunOptions, reset_stats, stats
from repro.engine.executor import run_program
from repro.compiler import stats as kstats

def build_heat(T0, steps, c=0.1):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    T = WSE_Array("T_n", init_data=T0, dtype=np.float64)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
            + T[1:-1, 0, -1] + T[1:-1, -1, 0] + T[1:-1, 0, 1])
    wse.__exit__()
    return wse.program

rng = np.random.default_rng(11)
T0 = rng.uniform(250.0, 500.0, size=(16, 16, 4))
"""


def test_fp64_overlap_bitwise_single_device():
    out = run_py(
        SUB_PRELUDE
        + """
for steps, k in [(6, 2), (7, 2), (8, 4)]:
    base = run_program(build_heat(T0, steps),
                       options=RunOptions(backend="pallas", time_tile=k,
                                          overlap=False))
    ov = run_program(build_heat(T0, steps),
                     options=RunOptions(backend="pallas", time_tile=k,
                                        overlap=True))
    assert base["T_n"].dtype == np.float64
    assert (base["T_n"] == ov["T_n"]).all(), (steps, k)
assert kstats.fallbacks == 0, kstats.fallback_reasons
print("OK")
"""
    )
    assert "OK" in out


def test_fp64_overlap_bitwise_sharded():
    out = run_py(
        SUB_PRELUDE
        + """
from repro.core.jaxcompat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
base = run_program(build_heat(T0, 6),
                   options=RunOptions(backend="pallas", mesh=mesh,
                                      time_tile=2, overlap=False))
reset_stats()
ov = run_program(build_heat(T0, 6),
                 options=RunOptions(backend="pallas", mesh=mesh,
                                    time_tile=2, overlap=True))
assert (base["T_n"] == ov["T_n"]).all()
assert stats.interior_launches == 3, vars(stats)
assert stats.boundary_launches == 12, vars(stats)
assert stats.overlapped_exchanges == 3, vars(stats)
single = run_program(build_heat(T0, 6),
                     options=RunOptions(backend="pallas", time_tile=2,
                                        overlap=True))
assert (ov["T_n"] == single["T_n"]).all()
assert kstats.fallbacks == 0, kstats.fallback_reasons
print("OK")
""",
        devices=4,
    )
    assert "OK" in out

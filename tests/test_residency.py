"""Halo-resident field state: no-copy guarantees + bitwise exactness.

The residency PR's acceptance surface: the layout's enter/exit conversions
round-trip exactly, the in-place wrap refresh reproduces ``jnp.pad(
mode="wrap")`` bitwise, resident stepping equals the legacy repacking path
bit-for-bit (fp32 in-process; fp64 and the sharded mesh in subprocesses,
for heat3d and the off-axis advection–diffusion body), the jitted executors
really donate their entry buffers (buffer invalidation where the backend
effects donation, compiled-HLO donation markers regardless), and the engine
accounting shows two repacking conversions per resident run instead of one
per launch.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import heat_init
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface
from repro.engine import HaloLayout, plan, reset_stats, single_runner, stats
from repro.engine.layout import wrap_refresh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def build_heat(T0, steps, c=0.1, dtype=None):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    kw = {} if dtype is None else {"dtype": dtype}
    T = WSE_Array("T_n", init_data=T0, **kw)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0]
            + T[:-2, 0, 0]
            + T[1:-1, 1, 0]
            + T[1:-1, 0, -1]
            + T[1:-1, -1, 0]
            + T[1:-1, 0, 1]
        )
    return wse, T


def build_advdiff(T0, steps):
    """Off-axis taps (diagonal cross-diffusion) + upwind advection."""
    wse = WSE_Interface()
    T = WSE_Array("T_adv", init_data=T0)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = (
            T[1:-1, 0, 0]
            + 0.05
            * (
                T[2:, 0, 0]
                + T[:-2, 0, 0]
                + T[1:-1, 1, 0]
                + T[1:-1, -1, 0]
                + T[1:-1, 0, 1]
                + T[1:-1, 0, -1]
                - 6.0 * T[1:-1, 0, 0]
            )
            - 0.1 * (T[1:-1, 0, 0] - T[1:-1, -1, 0])
            - 0.07 * (T[1:-1, 0, 0] - T[1:-1, 0, -1])
            + 0.02 * (T[1:-1, 1, 1] + T[1:-1, -1, -1] - 2.0 * T[1:-1, 0, 0])
        )
    return wse, T


# -- layout primitives --------------------------------------------------------


def test_layout_enter_exit_roundtrip_bitwise(rng):
    env = {
        "a": rng.normal(size=(7, 9, 5)).astype(np.float32),
        "b": rng.normal(size=(7, 9, 4)).astype(np.float32),
    }
    lay = HaloLayout(pad=3, shapes={n: v.shape for n, v in env.items()})
    back = lay.exit(lay.enter(env))
    for n, v in env.items():
        assert np.asarray(back[n]).shape == v.shape
        assert (np.asarray(back[n]) == v).all()
    # pad=0 degrades to identity
    lay0 = HaloLayout(pad=0, shapes={})
    assert (np.asarray(lay0.exit(lay0.enter(env))["a"]) == env["a"]).all()


@pytest.mark.parametrize("K, h", [(1, 1), (3, 2), (3, 3)])
def test_wrap_refresh_matches_jnp_pad_wrap(rng, K, h):
    x = rng.normal(size=(8, 6, 4)).astype(np.float32)
    lay = HaloLayout(pad=K, shapes={"x": x.shape})
    resident = wrap_refresh(lay.enter({"x": x})["x"], K, h)
    ref = jnp.pad(jnp.asarray(x), ((h, h), (h, h), (0, 0)), mode="wrap")
    lo = K - h
    window = resident[lo : lo + 8 + 2 * h, lo : lo + 6 + 2 * h, :]
    assert (np.asarray(window) == np.asarray(ref)).all()


# -- resident stepping == repacking stepping (fp32, in-process) ---------------


def test_resident_matches_repack_bitwise_heat():
    T0 = heat_init()
    wse, T = build_heat(T0, 6)
    res = wse.make(answer=T, backend="pallas").copy()
    wse, T = build_heat(T0, 6)
    leg = wse.make(answer=T, backend="pallas", resident=False).copy()
    assert (res == leg).all()


def test_resident_matches_repack_bitwise_advdiff():
    rng = np.random.default_rng(3)
    T0 = rng.uniform(0.0, 1.0, size=(10, 9, 6)).astype(np.float32)
    wse, T = build_advdiff(T0, 5)
    res = wse.make(answer=T, backend="pallas").copy()
    wse, T = build_advdiff(T0, 5)
    leg = wse.make(answer=T, backend="pallas", resident=False).copy()
    assert (res == leg).all()


def test_resident_matches_repack_bitwise_tiled_remainder():
    T0 = heat_init()
    wse, T = build_heat(T0, 7)
    res = wse.make(answer=T, backend="pallas", time_tile=4).copy()
    wse, T = build_heat(T0, 7)
    leg = wse.make(answer=T, backend="pallas", time_tile=4, resident=False).copy()
    assert (res == leg).all()


def test_resident_accounting_two_repacks_per_run():
    T0 = heat_init()
    reset_stats()
    wse, T = build_heat(T0, 6)
    wse.make(answer=T, backend="pallas", time_tile=1)
    assert stats.resident_runs == 1
    assert stats.repacks == 2  # layout enter + exit — not one per launch
    assert stats.exchanges == 6  # margin refreshes, one per launch
    reset_stats()
    wse, T = build_heat(T0, 6)
    wse.make(answer=T, backend="pallas", time_tile=1, resident=False)
    assert stats.resident_runs == 0
    assert stats.repacks == 6  # legacy: one full wrap pad per launch


def test_mixed_plan_counts_conversions_around_interp_segments():
    """fused loop → non-affine loop (interpreter) → fused loop: the resident
    run exits/re-enters the layout around the interpreter segment, and the
    accounting must report all four conversions, not a flat two."""
    T0 = heat_init((8, 8, 6))
    wse = WSE_Interface()
    T = WSE_Array("T_m", init_data=T0)
    with WSE_For_Loop("a", 2):
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0] + 0.1 * T[1:-1, 1, 0]
    with WSE_For_Loop("b", 2):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 1, 0]
    with WSE_For_Loop("c", 2):
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0] + 0.1 * T[1:-1, -1, 0]
    reset_stats()
    res = wse.make(answer=T, backend="pallas").copy()
    assert stats.resident_runs == 1
    assert stats.repacks == 4  # enter, exit-around-interp, enter, exit
    wse = WSE_Interface()
    T = WSE_Array("T_m", init_data=T0)
    with WSE_For_Loop("a", 2):
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0] + 0.1 * T[1:-1, 1, 0]
    with WSE_For_Loop("b", 2):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] * T[1:-1, 0, 0] * T[1:-1, 1, 0]
    with WSE_For_Loop("c", 2):
        T[1:-1, 0, 0] = 0.5 * T[1:-1, 0, 0] + 0.1 * T[1:-1, -1, 0]
    leg = wse.make(answer=T, backend="pallas", resident=False).copy()
    assert (res == leg).all()


def test_plan_layout_margin_is_max_tile_window():
    T0 = np.asarray(heat_init((24, 24, 8)))
    wse, T = build_heat(T0, 8)
    try:
        p = plan(wse.program, backend="pallas", time_tile=4)
    finally:
        wse.__exit__()
    assert p.layout.pad == 4  # k=4, h=1
    wse, T = build_heat(T0, 8)
    try:
        p = plan(wse.program, backend="jit")
    finally:
        wse.__exit__()
    assert p.layout.pad == 0  # interpreter plans never pad


# -- donation -----------------------------------------------------------------


def test_single_runner_donates_entry_buffers():
    T0 = heat_init()
    wse, T = build_heat(T0, 4)
    try:
        p = plan(wse.program, backend="pallas")
    finally:
        wse.__exit__()
    runner = single_runner(p)
    env = {"T_n": jnp.asarray(T0)}
    lowered = runner.lower(env).as_text()
    assert "jax.buffer_donor" in lowered or "tf.aliasing_output" in lowered
    out = runner(env)
    jax.block_until_ready(out["T_n"])
    # where the backend effects donation (CPU does), the entry buffer is gone
    if hasattr(env["T_n"], "is_deleted"):
        assert env["T_n"].is_deleted()


def test_solver_step_fn_protects_caller_arrays():
    """make_solver donates its jitted entry state; step_fn must hand it a
    buffer the caller never owned, so reusing one jax array across calls
    stays legal and bitwise stable."""
    from repro.solver import btcs_program, make_solver

    T0 = heat_init((8, 8, 8))
    prog = btcs_program((8, 8, 8), 0.1, init_data=T0)
    step = make_solver(prog, "T", method="cg", backend="jit", tol=1e-6)
    x = jnp.asarray(T0)
    a, _ = step(x)
    b, _ = step(x)  # donated run must not have consumed the caller's x
    assert not x.is_deleted()
    assert (np.asarray(a) == np.asarray(b)).all()


# -- fp64 + sharded exactness (subprocesses) ----------------------------------


def run_py(code: str, devices: int = 1, x64: bool = False, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


BUILDERS = """
import numpy as np
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface

def build_heat(T0, steps, c=0.1, dtype=None):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    kw = {} if dtype is None else {"dtype": dtype}
    T = WSE_Array("T_n", init_data=T0, **kw)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = center * T[1:-1, 0, 0] + c * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
            + T[1:-1, 0, -1] + T[1:-1, -1, 0] + T[1:-1, 0, 1])
    return wse, T

def build_advdiff(T0, steps, dtype=None):
    wse = WSE_Interface()
    kw = {} if dtype is None else {"dtype": dtype}
    T = WSE_Array("T_adv", init_data=T0, **kw)
    with WSE_For_Loop("t", steps):
        T[1:-1, 0, 0] = (T[1:-1, 0, 0]
            + 0.05 * (T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0]
                      + T[1:-1, -1, 0] + T[1:-1, 0, 1] + T[1:-1, 0, -1]
                      - 6.0 * T[1:-1, 0, 0])
            - 0.1 * (T[1:-1, 0, 0] - T[1:-1, -1, 0])
            - 0.07 * (T[1:-1, 0, 0] - T[1:-1, 0, -1])
            + 0.02 * (T[1:-1, 1, 1] + T[1:-1, -1, -1]
                      - 2.0 * T[1:-1, 0, 0]))
    return wse, T

T0 = np.full((8, 12, 10), 500.0, np.float64)
T0[1:-1, 1:-1, 0] = 300.0
T0[1:-1, 1:-1, -1] = 400.0
rng = np.random.default_rng(3)
A0 = rng.uniform(0.0, 1.0, size=(8, 12, 10))
"""


def test_fp64_resident_bitwise_single_device():
    out = run_py(BUILDERS + """
for builder, T_init in [(build_heat, T0), (build_advdiff, A0)]:
    wse, T = builder(T_init, 6, dtype=np.float64)
    res = wse.make(answer=T, backend="pallas").copy()
    assert res.dtype == np.float64, res.dtype
    wse, T = builder(T_init, 6, dtype=np.float64)
    leg = wse.make(answer=T, backend="pallas", resident=False).copy()
    assert (res == leg).all(), builder
wse, T = build_heat(T0, 8, dtype=np.float64)
rk = wse.make(answer=T, backend="pallas", time_tile=4).copy()
wse, T = build_heat(T0, 8, dtype=np.float64)
lk = wse.make(answer=T, backend="pallas", time_tile=4, resident=False).copy()
assert (rk == lk).all()
print("OK")
""", x64=True)
    assert "OK" in out


def test_fp64_resident_bitwise_sharded():
    out = run_py(BUILDERS + """
import jax
from repro.core.halo import run_sharded
from repro.core.jaxcompat import make_mesh
from repro.engine import reset_stats, stats
mesh = make_mesh((2, 2), ("data", "model"))
for builder, T_init, name in [(build_heat, T0, "T_n"),
                              (build_advdiff, A0, "T_adv")]:
    wse, T = builder(T_init, 5, dtype=np.float64)
    wse.__exit__()
    reset_stats()
    res = run_sharded(wse.program, {name: T_init}, mesh=mesh,
                      use_pallas=True)[name].copy()
    assert stats.resident_runs == 1 and stats.repacks == 2, vars(stats)
    wse, T = builder(T_init, 5, dtype=np.float64)
    wse.__exit__()
    leg = run_sharded(wse.program, {name: T_init}, mesh=mesh,
                      use_pallas=True, resident=False)[name].copy()
    assert (res == leg).all(), name
    # sharded == single-device, both resident
    wse, T = builder(T_init, 5, dtype=np.float64)
    single = wse.make(answer=T, backend="pallas")
    assert (res == single).all(), name
print("OK")
""", devices=4, x64=True)
    assert "OK" in out

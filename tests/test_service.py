"""The serving layer: admission, scheduling, warm caches, faults, stats.

End-to-end: a warm service serves a concurrent mixed-signature stream with
zero kernel compiles after warm-up (every request a plan-cache hit) and
returns bit-identical results to the engine run of the same recorded
program.  Unit level: the scheduler's admission bound, priority order,
signature grouping and deadline expiry; the injected-fault
restore-and-continue path; retry exhaustion; the logged interpreter
degraded mode; and the per-request / service-level stats surfaces.
"""

import threading

import numpy as np
import pytest

from repro.compiler import stats as kstats
from repro.engine import hooks, reset_stats
from repro.engine.stats import stats as estats
from repro.runtime.fault import FaultInjector, InjectedFault
from repro.service import (
    DeadlineExceeded,
    PlanSignature,
    RequestFailed,
    ServiceOverloaded,
    SignatureScheduler,
    SimulationService,
    SolveRequest,
    StepRequest,
    Ticket,
    get_workload,
    service_stats,
)

SIGS = [
    PlanSignature("heat3d", (12, 10, 6)),
    PlanSignature("advdiff", (10, 10, 6)),
    PlanSignature("jacobi3d", (8, 8, 6), time_tile=2),
]
SOLVE_SIG = PlanSignature("btcs_heat", (8, 8, 6))


@pytest.fixture(scope="module")
def warm_service():
    reset_stats()
    svc = SimulationService(
        workers=2, capacity=512, manifest=SIGS + [SOLVE_SIG],
        default_chunk=4,
    )
    svc.start()
    yield svc
    svc.stop()


# -- request model ------------------------------------------------------------


def test_signature_key_and_json_roundtrip():
    sig = PlanSignature("heat3d", (4, 5, 6), dtype="float64", time_tile=3)
    assert sig.key() == "heat3d:4x5x6:float64:k3:pallas"
    assert PlanSignature.from_json(sig.to_json()) == sig


def test_request_validation():
    sig = SIGS[0]
    with pytest.raises(ValueError, match="shape must be"):
        PlanSignature("heat3d", (4, 5))
    with pytest.raises(ValueError, match="steps must be"):
        StepRequest(sig, steps=0)
    with pytest.raises(ValueError, match="requires an explicit ckpt_key"):
        StepRequest(sig, steps=1, resume=True)
    with pytest.raises(ValueError, match="init shape"):
        StepRequest(sig, steps=1, init=np.zeros((3, 3, 3), np.float32))
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("nope")


def test_ticket_timeout():
    t = Ticket(StepRequest(SIGS[0], steps=1))
    with pytest.raises(TimeoutError):
        t.result(timeout=0.01)
    assert not t.done() and t.error() is None


# -- scheduler ----------------------------------------------------------------


def _ticket(sig=None, priority=0, deadline_s=None):
    return Ticket(
        StepRequest(
            sig or SIGS[0], steps=1, priority=priority, deadline_s=deadline_s
        )
    )


def test_scheduler_admission_bound():
    sched = SignatureScheduler(capacity=2)
    sched.submit(_ticket())
    sched.submit(_ticket())
    with pytest.raises(ServiceOverloaded):
        sched.submit(_ticket())


def test_scheduler_priority_then_fifo():
    sched = SignatureScheduler(group_max=1)
    lo1, hi, lo2 = _ticket(priority=0), _ticket(priority=5), _ticket(priority=0)
    for t in (lo1, hi, lo2):
        sched.submit(t)
    order = [sched.get_group(timeout=1)[0] for _ in range(3)]
    assert order == [hi, lo1, lo2]


def test_scheduler_groups_by_signature():
    sched = SignatureScheduler(group_max=8)
    a1, b, a2 = _ticket(SIGS[0]), _ticket(SIGS[1]), _ticket(SIGS[0])
    for t in (a1, b, a2):
        sched.submit(t)
    group = sched.get_group(timeout=1)
    assert group == [a1, a2]  # same signature drained past the interloper
    assert sched.get_group(timeout=1) == [b]


def test_scheduler_group_max_caps_the_drain():
    sched = SignatureScheduler(group_max=2)
    tickets = [_ticket() for _ in range(5)]
    for t in tickets:
        sched.submit(t)
    assert len(sched.get_group(timeout=1)) == 2
    assert len(sched) == 3


def test_scheduler_expires_overdue_requests_at_dispatch():
    sched = SignatureScheduler()
    dead = _ticket(deadline_s=0.0)
    live = _ticket(SIGS[1])
    sched.submit(dead)
    sched.submit(live)
    group = sched.get_group(timeout=1)
    assert group == [live]
    assert sched.expired == [dead]
    with pytest.raises(DeadlineExceeded):
        dead.result(timeout=1)


def test_scheduler_close_drains_then_signals_exit():
    sched = SignatureScheduler()
    t = _ticket()
    sched.submit(t)
    sched.close()
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(_ticket())
    assert sched.get_group(timeout=1) == [t]  # queued work still served
    assert sched.get_group(timeout=1) == []  # then the exit signal


# -- end-to-end serving -------------------------------------------------------


def _reference(sig: PlanSignature, steps: int) -> np.ndarray:
    """The engine's own answer for a workload signature (no service)."""
    from repro.engine.executor import run_program

    spec = get_workload(sig.workload)
    program, answer = spec.record(sig.shape, np.dtype(sig.dtype), steps)
    out = run_program(
        program, backend=sig.backend, time_tile=sig.time_tile
    )
    return out[answer]


def test_serves_concurrent_mixed_stream_with_zero_compiles(warm_service):
    svc = warm_service
    built = kstats.kernels_built
    tickets = []
    for i in range(64):
        if i % 8 == 7:
            tickets.append(svc.submit(SolveRequest(SOLVE_SIG, maxiter=40)))
        else:
            tickets.append(
                svc.submit(
                    StepRequest(SIGS[i % 3], steps=8, priority=i % 2)
                )
            )
    results = [t.result(timeout=300) for t in tickets]
    assert all(np.all(np.isfinite(np.asarray(r))) for r in results)
    assert len({t.stats.signature for t in tickets}) == 4
    # the warm-pool contract: no compiles, no plan builds, no retries
    assert kstats.kernels_built == built
    assert all(t.stats.plan_cache_hit for t in tickets)
    assert sum(t.stats.retries for t in tickets) == 0
    assert not any(t.stats.degraded for t in tickets)
    # per-request observability is populated
    st = tickets[0].stats
    assert st.steps == 8 and st.chunks == 2 and st.launches >= 2
    assert st.queue_wait_s >= 0.0 and st.latency_s > 0.0
    assert st.worker in (0, 1)


def test_service_results_match_engine_bitwise(warm_service):
    for sig in SIGS:
        t = warm_service.submit(StepRequest(sig, steps=9))
        out = t.result(timeout=300)
        ref = _reference(sig, 9)
        assert out.dtype == ref.dtype
        assert (out == ref).all(), sig.key()


def test_solve_request_converges(warm_service):
    t = warm_service.submit(SolveRequest(SOLVE_SIG, tol=1e-5, maxiter=80))
    out = t.result(timeout=300)
    assert np.all(np.isfinite(out))
    assert t.stats.iterations >= 1


def test_custom_init_overrides_default(warm_service):
    sig = SIGS[0]
    init = np.full(sig.shape, 7.25, np.float32)
    t = warm_service.submit(StepRequest(sig, steps=1, init=init))
    out = t.result(timeout=300)
    assert not np.allclose(out, _reference(sig, 1))


def test_submit_requires_started_service():
    svc = SimulationService(workers=1)
    with pytest.raises(RuntimeError, match="not started"):
        svc.submit(StepRequest(SIGS[0], steps=1))


def test_rejected_submission_counts(warm_service, monkeypatch):
    before = estats.requests_rejected

    def full(ticket):
        raise ServiceOverloaded("queue full (test)")

    monkeypatch.setattr(warm_service.scheduler, "submit", full)
    with pytest.raises(ServiceOverloaded):
        warm_service.submit(StepRequest(SIGS[0], steps=1))
    assert estats.requests_rejected == before + 1


# -- fault tolerance ----------------------------------------------------------


def test_injected_fault_completes_via_restore(warm_service, tmp_path):
    warm_service.ckpt_root = str(tmp_path)
    req = StepRequest(SIGS[0], steps=8, ckpt_every=2)
    with FaultInjector(fail_at=[4], match_tag=req.request_id):
        t = warm_service.submit(req)
        out = t.result(timeout=300)
    assert (out == _reference(SIGS[0], 8)).all()  # still bitwise
    assert t.stats.retries == 1 and t.stats.restores == 1
    assert t.stats.checkpoints == 4


def test_fault_without_checkpoints_restarts_from_scratch(warm_service):
    req = StepRequest(SIGS[1], steps=8)
    with FaultInjector(fail_at=[4], match_tag=req.request_id):
        t = warm_service.submit(req)
        out = t.result(timeout=300)
    assert (out == _reference(SIGS[1], 8)).all()
    assert t.stats.retries == 1 and t.stats.restores == 0


def test_retry_budget_exhaustion_fails_the_ticket(warm_service):
    req = StepRequest(SIGS[0], steps=4)

    def always_fail(step, tag=""):
        if tag == req.request_id:
            raise InjectedFault("permanent injected fault")

    failed_before = estats.requests_failed
    prev = hooks.set_step_hook(always_fail)
    try:
        t = warm_service.submit(req)
        with pytest.raises(RequestFailed, match="after 3 retries"):
            t.result(timeout=300)
    finally:
        hooks.set_step_hook(prev)
    assert t.stats.retries == warm_service.max_retries + 1
    assert estats.requests_failed == failed_before + 1


def test_permanent_errors_do_not_burn_retries(warm_service):
    t = warm_service.submit(
        SolveRequest(SOLVE_SIG, method="not-a-method", maxiter=5)
    )
    with pytest.raises((ValueError, KeyError)):
        t.result(timeout=300)
    assert t.stats.retries == 0


def test_compile_failure_serves_degraded_and_logged(warm_service, caplog):
    degraded_sig = PlanSignature("advdiff", (11, 11, 6))  # plan-cache miss
    fb_before = kstats.fallbacks
    with caplog.at_level("WARNING"):
        with FaultInjector(fail_compile=["service_advdiff"]):
            t = warm_service.submit(StepRequest(degraded_sig, steps=4))
            out = t.result(timeout=300)
    assert np.all(np.isfinite(out))
    assert t.stats.degraded
    assert "injected compile failure" in t.stats.degraded_reason
    assert kstats.fallbacks == fb_before + 1
    assert any("DEGRADED" in r.message for r in caplog.records)
    # degraded is a mode, not an error: later requests for the same
    # signature reuse the interpreter plan and are flagged the same way
    t2 = warm_service.submit(StepRequest(degraded_sig, steps=2))
    t2.result(timeout=300)
    assert t2.stats.degraded and t2.stats.plan_cache_hit


def test_expired_deadline_fails_before_running(warm_service):
    t = warm_service.submit(
        StepRequest(SIGS[2], steps=2, deadline_s=0.0)
    )
    with pytest.raises(DeadlineExceeded):
        t.result(timeout=300)
    assert t.stats.steps == 0  # never dispatched to a chunk


# -- observability + manifest -------------------------------------------------


def test_service_stats_shape(warm_service):
    s = warm_service.service_stats()
    assert s["requests"]["completed"] >= 64
    assert s["plans"]["cache_hits"] >= 64
    assert s["kernels"]["cache_hits"] >= 0
    assert s["faults"]["checkpoints"] >= 1
    assert s["service"]["workers"] == 2
    assert set(s["service"]["plan_cache"]) >= {sig.key() for sig in SIGS}
    # the module-level accessor reads the same counters
    assert service_stats()["requests"] == s["requests"]


def test_manifest_roundtrip_warms_next_instance(tmp_path):
    path = str(tmp_path / "manifest.json")
    svc = SimulationService(workers=1, manifest=[SIGS[0]])
    svc.start()
    try:
        svc.submit(StepRequest(SIGS[1], steps=1)).result(timeout=300)
        svc.save_manifest(path)
    finally:
        svc.stop()

    svc2 = SimulationService(workers=1, manifest=path)
    assert {s.key() for s in svc2._manifest_sigs} == {
        SIGS[0].key(), SIGS[1].key(),
    }
    svc2.start()
    try:
        t = svc2.submit(StepRequest(SIGS[1], steps=2))
        t.result(timeout=300)
        assert t.stats.plan_cache_hit  # warmed from the manifest file
    finally:
        svc2.stop()


def test_straggler_flagging_reaches_service_stats():
    reset_stats()
    svc = SimulationService(
        workers=1, default_chunk=2, straggler_threshold=5.0
    )
    svc.start()
    try:
        sig = SIGS[0]
        # build a duration history, then slow one chunk 1000x
        svc.submit(StepRequest(sig, steps=8)).result(timeout=300)
        req = StepRequest(sig, steps=4)
        with FaultInjector(
            slow_at={2: 0.5}, match_tag=req.request_id
        ):
            svc.submit(req).result(timeout=300)
    finally:
        svc.stop()
    assert estats.service_stragglers >= 1


def test_worker_threads_exit_on_stop():
    svc = SimulationService(workers=2)
    svc.start()
    threads = list(svc._threads)
    svc.stop()
    assert all(not th.is_alive() for th in threads)
    assert threading.active_count() < 50  # no thread leak across tests

"""Data pipeline, optimizer, compression, checkpoint, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenDataset, pack_documents
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_error_feedback, cosine_schedule,
                         dequantize_int8, quantize_int8)
from repro.runtime import HeartbeatMonitor, ResilientLoop


# -- data --------------------------------------------------------------------

def test_dataset_deterministic_and_restartable():
    ds = TokenDataset(1000, 32, 4, seed=7)
    b1 = [ds.next_batch() for _ in range(3)]
    state = ds.state()
    b_next = ds.next_batch()
    ds2 = TokenDataset(1000, 32, 4, seed=7)
    ds2.restore(state)
    b_replay = ds2.next_batch()
    np.testing.assert_array_equal(b_next["tokens"], b_replay["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["tokens"][:, 1:],
                                  b1[0]["labels"][:, :-1])


def test_packing():
    docs = [np.arange(1, 10, dtype=np.int32)] * 5
    rows = list(pack_documents(iter(docs), seq_len=16))
    assert all(r.shape == (17,) for r in rows)
    assert sum(r.size for r in rows) <= 5 * 10 + 17


# -- optimizer ----------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}           # d/dw ||w||²
        params, opt = adamw_update(params, grads, opt, lr=0.05,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup=10,
                                 total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-3)


# -- compression --------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_steps():
    """With constant grads, error feedback recovers the true mean exactly."""
    g = {"w": jnp.asarray([0.013, -0.031, 0.004], jnp.float32)}
    resid = jax.tree.map(lambda p: jnp.zeros_like(p), g)
    total = jnp.zeros(3)
    n = 64
    for _ in range(n):
        deq, resid = compress_error_feedback(g, resid)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                               atol=1e-3)


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "opt": (jnp.zeros(()), jnp.ones((2,)))}
    mgr.save(10, tree, extra={"data": {"seed": 1, "step": 5}})
    restored, step, extra = mgr.restore(tree)
    assert step == 10 and extra["data"]["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((3,))}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=False)
    mgr.wait()
    assert mgr.steps() == [2, 3]
    assert mgr.latest_step() == 3


# -- fault tolerance ----------------------------------------------------------

def test_resilient_loop_recovers_from_injected_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    ds = TokenDataset(100, 8, 2, seed=0)
    state0 = {"count": jnp.zeros(())}
    mgr.save(0, state0, extra={"data": ds.state()})
    fail_at = {4, 7}

    def step_fn(state, batch):
        step = int(state["count"])
        if step in fail_at:
            fail_at.discard(step)          # fail once then succeed
            raise RuntimeError("injected device failure")
        return {"count": state["count"] + 1}, {"loss": 0.0}

    def save_fn(step, state):
        mgr.save(step, state, extra={"data": ds.state()})

    def restore_fn():
        restored, step, extra = mgr.restore(state0)
        ds.restore(extra["data"])
        return restored, step

    loop = ResilientLoop(step_fn, save_fn, restore_fn, ds, ckpt_every=2,
                         max_failures=3)
    state, step, _ = loop.run(state0, 0, 10)
    assert int(state["count"]) == 10


def test_resilient_loop_gives_up(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    ds = TokenDataset(100, 8, 2, seed=0)
    state0 = {"count": jnp.zeros(())}
    mgr.save(0, state0, extra={"data": ds.state()})

    def step_fn(state, batch):
        raise RuntimeError("hard failure")

    loop = ResilientLoop(
        step_fn, lambda s, st: None,
        lambda: (state0, 0), ds, max_failures=2)
    with pytest.raises(RuntimeError):
        loop.run(state0, 0, 5)


def test_heartbeat_flags_straggler():
    import time
    mon = HeartbeatMonitor(threshold=5.0)
    for i in range(6):
        mon.start_step(i)
        time.sleep(0.002)
        mon.end_step()
    mon.start_step(6)
    time.sleep(0.1)
    mon.end_step()
    assert 6 in mon.flagged


def test_elastic_shrink_plan():
    from repro.runtime.elastic import shrink_plan
    plan = shrink_plan(old_dp=16, new_dp=8, global_batch=256,
                       num_microbatches=4)
    assert plan["keep_global_batch"]["num_microbatches"] == 8
    assert plan["keep_microbatches"]["global_batch"] == 128
    assert plan["keep_microbatches"]["lr_scale"] == 0.5

"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test extra (see pyproject.toml); the module
skips cleanly when it is absent so the tier-1 suite stays runnable.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.explicit import ftcs_step  # noqa: E402
from repro.core.implicit import make_operator  # noqa: E402
from repro.core.perfmodel import (roofline_time, StepCost,  # noqa: E402
                                  wse_dot_time, wse_explicit_rate,
                                  wse_implicit_rate)

SMALL = dict(deadline=None, max_examples=20)


def _field(draw_shape, values):
    return values.reshape(draw_shape).astype(np.float32)


@given(st.integers(4, 8), st.integers(4, 8), st.integers(4, 8),
       st.floats(0.01, 1.0 / 6.0), st.integers(0, 1000))
@settings(**SMALL)
def test_ftcs_maximum_principle(nx, ny, nz, w, seed):
    """FTCS with stable ω obeys the discrete maximum principle: values stay
    inside [min(T0), max(T0)] (no new extrema — the paper's stability
    condition ω ≤ 1/6)."""
    rng = np.random.default_rng(seed)
    T0 = rng.uniform(200.0, 600.0, size=(nx, ny, nz)).astype(np.float32)
    T = jnp.asarray(T0)
    for _ in range(3):
        T = ftcs_step(T, w)
    assert float(T.max()) <= T0.max() + 1e-2
    assert float(T.min()) >= T0.min() - 1e-2


@given(st.integers(4, 7), st.integers(4, 7), st.integers(4, 7),
       st.integers(0, 100))
@settings(**SMALL)
def test_ftcs_linearity(nx, ny, nz, seed):
    """The update is affine: step(a+b) - step(b) is linear in a on the
    interior (superposition — it is a linear PDE)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(nx, ny, nz)).astype(np.float32)
    b = rng.normal(size=(nx, ny, nz)).astype(np.float32)
    w = 0.1
    sa = np.asarray(ftcs_step(jnp.asarray(a), w))
    sb = np.asarray(ftcs_step(jnp.asarray(b), w))
    sab = np.asarray(ftcs_step(jnp.asarray(a + b), w))
    np.testing.assert_allclose(sab, sa + sb - np.asarray(
        ftcs_step(jnp.zeros_like(jnp.asarray(a)), w)), atol=1e-3)


@given(st.integers(4, 7), st.integers(4, 7), st.integers(4, 7),
       st.integers(0, 100), st.floats(0.01, 0.16))
@settings(**SMALL)
def test_operator_symmetric_on_interior(nx, ny, nz, seed, w):
    """(x, Ay) == (Ax, y) for interior-supported x, y — CG's requirement."""
    A, rhs, dot, mask = make_operator(w, (nx, ny, nz))
    rng = np.random.default_rng(seed)
    x = jnp.where(mask, jnp.asarray(
        rng.normal(size=(nx, ny, nz)).astype(np.float32)), 0.0)
    y = jnp.where(mask, jnp.asarray(
        rng.normal(size=(nx, ny, nz)).astype(np.float32)), 0.0)
    lhs = float(dot(x, A(y)))
    rhs_ = float(dot(A(x), y))
    np.testing.assert_allclose(lhs, rhs_, rtol=1e-3, atol=1e-3)


@given(st.integers(1, 10 ** 6))
@settings(**SMALL)
def test_eq6_monotone_in_workload(w):
    """Eq. 6: iteration rate strictly decreases with workload."""
    assert wse_explicit_rate(w) > wse_explicit_rate(w + 1)


@given(st.integers(1, 10 ** 5), st.integers(1, 750), st.integers(1, 950))
@settings(**SMALL)
def test_eq16_le_eq6(w, x, y):
    """CG is never faster than the explicit step at equal W (paper §3.2.2)."""
    assert wse_implicit_rate(w, x, y) < wse_explicit_rate(w)


@given(st.integers(1, 10 ** 5), st.integers(1, 750), st.integers(1, 950))
@settings(**SMALL)
def test_dot_time_additive_in_fabric(w, x, y):
    """Eq. 17 latency grows exactly linearly in fabric extents."""
    t0 = wse_dot_time(w, x, y)
    t1 = wse_dot_time(w, x + 1, y)
    np.testing.assert_allclose((t1 - t0) * 850e6, 1.0, rtol=1e-6)


@given(st.floats(1e6, 1e15), st.floats(1e3, 1e12), st.floats(0, 1e9))
@settings(**SMALL)
def test_roofline_bound_identification(flops, bytes_, coll):
    r = roofline_time(StepCost(flops, bytes_, coll, hops=0))
    assert r["t_total"] >= max(r["t_compute"], r["t_memory"])
    assert r["bound"] in ("compute", "memory", "collective")


# -- MoE routing invariants ---------------------------------------------------

@given(st.integers(2, 16), st.integers(1, 4), st.integers(0, 50))
@settings(**SMALL)
def test_router_weights_normalized(n_experts, k, seed):
    from repro.models.moe import _route
    k = min(k, n_experts)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(32, n_experts)).astype(np.float32))
    topw, topi, probs = _route(logits, k, norm_topk=True)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, rtol=1e-4)
    assert int(topi.max()) < n_experts
    # chosen experts are the k largest gates
    np.testing.assert_allclose(
        np.sort(np.asarray(topw), axis=-1)[:, ::-1], np.asarray(topw)
        if k == 1 else np.sort(np.asarray(topw), axis=-1)[:, ::-1],
        rtol=1e-5)


@given(st.integers(1, 6), st.integers(0, 20))
@settings(**SMALL)
def test_moe_dispatch_conserves_tokens(cap_scale, seed):
    """With ample capacity every (token, choice) lands in exactly one slot."""
    from repro.models.moe import _dispatch_group
    rng = np.random.default_rng(seed)
    t, d, e, k = 16, 8, 4, 2
    x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
    topw = jnp.ones((t, k), jnp.float32) / k
    topi = jnp.asarray(rng.integers(0, e, size=(t, k)), jnp.int32)
    capacity = t * k
    buf, meta = _dispatch_group(x, topw, topi, e, capacity)
    # total mass conserved: every row of x appears k times across buf
    np.testing.assert_allclose(float(jnp.abs(buf).sum()),
                               k * float(jnp.abs(x).sum()), rtol=1e-4)

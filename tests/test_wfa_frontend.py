"""The WFA NumPy-like frontend (paper Fig. 3): numpy + jit backends."""
import numpy as np
import pytest

from conftest import ftcs_oracle, heat_init
from repro.core import WSE_Array, WSE_For_Loop, WSE_Interface


def build_heat_program(T_init, steps, c=0.1):
    wse = WSE_Interface()
    center = 1.0 - 6.0 * c
    T_n = WSE_Array("T_n", init_data=T_init)
    with WSE_For_Loop("time_loop", steps):
        T_n[1:-1, 0, 0] = center * T_n[1:-1, 0, 0] \
            + c * (T_n[2:, 0, 0] + T_n[:-2, 0, 0]
                   + T_n[1:-1, 1, 0] + T_n[1:-1, 0, -1]
                   + T_n[1:-1, -1, 0] + T_n[1:-1, 0, 1])
    return wse, T_n


@pytest.mark.parametrize("backend", ["numpy", "jit"])
def test_fig3_heat_equation(backend):
    T0 = heat_init()
    wse, T_n = build_heat_program(T0, steps=7)
    out = wse.make(answer=T_n, backend=backend)
    np.testing.assert_allclose(out, ftcs_oracle(T0, 0.1, 7), atol=2e-4)


def test_backends_agree():
    T0 = heat_init((8, 9, 11))
    wse, T_n = build_heat_program(T0, steps=5)
    a = wse.make(answer=T_n, backend="numpy")
    wse, T_n = build_heat_program(T0, steps=5)
    b = wse.make(answer=T_n, backend="jit")
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_boundaries_pinned():
    T0 = heat_init()
    wse, T_n = build_heat_program(T0, steps=10)
    out = wse.make(answer=T_n, backend="jit")
    np.testing.assert_array_equal(out[0, :, :], T0[0, :, :])
    np.testing.assert_array_equal(out[:, :, 0], T0[:, :, 0])
    np.testing.assert_array_equal(out[:, :, -1], T0[:, :, -1])


def test_update_requires_program():
    T = WSE_Array("T_orphan_ctx", shape=(4, 4, 4))
    # field created outside any program: updating must fail cleanly
    with pytest.raises(RuntimeError):
        T[1:-1, 0, 0] = 2.0 * T[1:-1, 0, 0]


def test_mismatched_slice_length_rejected():
    wse = WSE_Interface()
    try:
        T = WSE_Array("T_badslice", shape=(6, 4, 4))
        with pytest.raises(ValueError):
            T[1:-1, 0, 0] = T[2:, 0, 0] + T[1:, 0, 0]   # 4 vs 5 cells
    finally:
        wse.__exit__()


def test_nested_expression_and_scalars():
    T0 = heat_init((6, 6, 8))
    wse = WSE_Interface()
    T = WSE_Array("T_n", init_data=T0)
    with WSE_For_Loop("t", 3):
        T[1:-1, 0, 0] = (T[1:-1, 0, 0] * 0.5 + 0.5 * T[1:-1, 0, 0]) \
            - 0.0 * T[1:-1, 1, 0]
    out = wse.make(answer=T, backend="jit")
    np.testing.assert_allclose(out, T0, atol=1e-5)


def test_variable_coefficient_diffusion():
    """The frontend expresses variable-coefficient fields with no core
    changes (the paper's finite-volume CFD direction): ω becomes a field."""
    T0 = heat_init((8, 9, 10))
    rng = np.random.default_rng(0)
    C0 = rng.uniform(0.02, 0.15, size=T0.shape).astype(np.float32)

    wse = WSE_Interface()
    T = WSE_Array("T_n", init_data=T0)
    C = WSE_Array("C_f", init_data=C0)
    with WSE_For_Loop("t", 4):
        T[1:-1, 0, 0] = T[1:-1, 0, 0] + C[1:-1, 0, 0] * (
            T[2:, 0, 0] + T[:-2, 0, 0] + T[1:-1, 1, 0] + T[1:-1, 0, -1]
            + T[1:-1, -1, 0] + T[1:-1, 0, 1] - 6.0 * T[1:-1, 0, 0])
    out = wse.make(answer=T, backend="jit")

    # numpy oracle
    Tn = T0.copy()
    for _ in range(4):
        new = Tn.copy()
        lap = (Tn[2:, 1:-1, 1:-1] + Tn[:-2, 1:-1, 1:-1]
               + Tn[1:-1, 2:, 1:-1] + Tn[1:-1, :-2, 1:-1]
               + Tn[1:-1, 1:-1, 2:] + Tn[1:-1, 1:-1, :-2]
               - 6.0 * Tn[1:-1, 1:-1, 1:-1])
        new[1:-1, 1:-1, 1:-1] = (Tn[1:-1, 1:-1, 1:-1]
                                 + C0[1:-1, 1:-1, 1:-1] * lap)
        Tn = new
    np.testing.assert_allclose(out, Tn, atol=2e-3)

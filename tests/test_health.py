"""Numerical health & recovery: guarded iterations, taxonomy, safe modes.

The acceptance surface of the robustness PR:

* no solver path — any method, any precision, sharded or batched — returns
  a non-finite answer labeled ``CONVERGED``: seeded NaN/Inf injection lands
  on ``NAN_RESIDUAL`` with the iteration index of first detection;
* the in-loop guard word is pay-for-what-you-get: a healthy guarded run is
  bitwise identical to the unguarded baseline (the guard adds **zero**
  extra reductions), and the explicit-path sentinel amortizes its probes at
  the checkpoint-chunk granule;
* deterministic constructions trip every failure class: BiCGSTAB rho
  breakdown (90° rotation), stagnation (identity fixed point), divergence
  (doubling fixed point);
* the recovery ladder is bounded and honest: each rung is logged in
  ``RecoveryTrace``, the fp64 safe-mode rung genuinely widens (dots
  included — the overflow construction converges at fp64 after fp32 fails),
  and an exhausted ladder raises ``NumericalFault`` carrying the trace;
* batched solves isolate poison per member: the sick member reports
  ``NAN_RESIDUAL``, the healthy members converge bitwise-unperturbed;
* the committed overhead benchmark stays inside the ≤2 % sentinel budget
  with zero interpreter fallbacks.
"""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

import repro as wfa
from repro.engine import reset_stats, stats
from repro.solver import GuardConfig, NumericalFault, RecoveryPolicy
from repro.solver import health, krylov
from repro.solver.api import solve
from repro.solver.presets import record_btcs

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

METHODS = ("cg", "pipecg", "bicgstab", "chebyshev", "jacobi")


def run_py(code: str, devices: int = 1, x64: bool = False, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def poisoned_T0(shape=(8, 8, 6)):
    T0 = np.full(shape, 500.0, np.float32)
    T0[1:-1, 1:-1, 0] = 300.0
    T0[shape[0] // 2, shape[1] // 2, shape[2] // 2] = np.nan
    return T0


def growth_program(n, init):
    """n steps of T <- 4·T: finite inits stay finite, 1e38 overflows at
    step 1 — a deterministic mid-run poisoning for the explicit sentinel."""
    wse = wfa.WFAInterface()
    T = wfa.Field("T", init_data=init)
    with wfa.ForLoop("t", n):
        T[:, 0, 0] = 4.0 * T[:, 0, 0]
    return wse, T


# -- taxonomy vocabulary ------------------------------------------------------


def test_outcome_vocabulary():
    assert health.outcome_name(health.CONVERGED) == "CONVERGED"
    assert [health.outcome_name(c) for c in health.FAILURES] == [
        "NAN_RESIDUAL", "BREAKDOWN", "STAGNATED", "DIVERGED"]
    assert not health.is_failure(health.CONVERGED)
    assert not health.is_failure(health.MAXITER)
    assert health.any_failure(np.array([health.MAXITER]), on_maxiter=True)
    # severity: a NaN outranks everything, MAXITER is the mildest word
    codes = [health.MAXITER, health.STAGNATED, health.DIVERGED,
             health.BREAKDOWN, health.NAN_RESIDUAL]
    assert health.worst(np.array(codes)) == health.NAN_RESIDUAL
    assert health.worst(np.array(codes[:2])) == health.STAGNATED
    assert list(health.outcome_names(np.array([0, 2]))) == [
        "CONVERGED", "NAN_RESIDUAL"]


# -- deterministic failure constructions at the krylov level ------------------


def _dot(a, b):
    return jnp.sum(a * b, dtype=jnp.float32)


def test_bicgstab_rho_breakdown():
    """A 90° rotation with b ⟂ A·b: (r0, v) = 0 at the first step — the
    textbook Lanczos breakdown, flagged as BREAKDOWN (not the NaN it would
    cascade into)."""
    A = jnp.asarray(np.array([[0.0, -1.0], [1.0, 0.0]], np.float32))
    b = jnp.asarray(np.array([1.0, 0.0], np.float32))
    x, it, rr, st = krylov.bicgstab(lambda v: A @ v, _dot, b,
                                    jnp.zeros(2, jnp.float32),
                                    tol=1e-10, maxiter=50)
    assert health.outcome_name(int(st)) == "BREAKDOWN"
    assert int(it) <= 2


def test_stationary_stagnation_and_divergence():
    rhs = jnp.asarray(np.array([1.0, 0.0], np.float32))
    rnorm2 = lambda x: _dot(rhs - x, rhs - x)
    # identity step: the residual never moves -> STAGNATED at the window
    x, it, rr, st = krylov.stationary(lambda x: x, rnorm2,
                                      jnp.zeros(2, jnp.float32),
                                      tol=1e-12, maxiter=1000)
    assert health.outcome_name(int(st)) == "STAGNATED"
    assert int(it) == health.DEFAULT_GUARD.stagnation_window
    # doubling step: the residual explodes -> DIVERGED long before maxiter
    x, it, rr, st = krylov.stationary(
        lambda x: 2.0 * x - rhs, rnorm2,
        jnp.asarray(np.array([0.5, 0.0], np.float32)),
        tol=1e-12, maxiter=1000)
    assert health.outcome_name(int(st)) == "DIVERGED"
    assert int(it) < 1000


def test_cg_nan_rhs_detected_at_entry():
    A = jnp.asarray(np.array([[2.0, 0.0], [0.0, 2.0]], np.float32))
    bn = jnp.asarray(np.array([np.nan, 0.0], np.float32))
    x, it, rr, st = krylov.cg(lambda v: A @ v, _dot, bn,
                              jnp.zeros(2, jnp.float32), tol=1e-10, maxiter=50)
    assert health.outcome_name(int(st)) == "NAN_RESIDUAL"
    assert int(it) == 0


def test_guard_config_knobs():
    g = GuardConfig(divergence_factor=2.0, stagnation_window=3)
    rhs = jnp.asarray(np.array([1.0, 0.0], np.float32))
    rnorm2 = lambda x: _dot(rhs - x, rhs - x)
    x, it, rr, st = krylov.stationary(lambda x: x, rnorm2,
                                      jnp.zeros(2, jnp.float32),
                                      tol=1e-12, maxiter=1000, guard=g)
    assert health.outcome_name(int(st)) == "STAGNATED" and int(it) == 3


# -- no path returns non-finite CONVERGED (every method) ----------------------


@pytest.mark.parametrize("method", METHODS)
def test_poisoned_solve_is_labeled(method):
    wse, T = record_btcs(poisoned_T0(), 0.1)
    x, info = solve(wse.program, T, method=method, tol=1e-6, maxiter=60,
                    return_info=True, options=wfa.RunOptions(backend="jit"))
    assert info.outcomes == ["NAN_RESIDUAL"]
    assert not np.all(np.isfinite(x))  # honest: the answer really is sick
    assert "CONVERGED" not in info.outcomes


def test_healthy_solve_unaffected_by_guard():
    """The guard rides the scalars the iteration already computes: healthy
    solves still converge with the same residual story."""
    wse, T = record_btcs(np.full((8, 8, 6), 400.0, np.float32), 0.1)
    x, info = solve(wse.program, T, method="cg", tol=1e-6, maxiter=200,
                    return_info=True, options=wfa.RunOptions(backend="jit"))
    assert info.outcomes == ["CONVERGED"]
    assert np.all(np.isfinite(x))


def test_poisoned_solve_fp64_subprocess():
    out = run_py("""
import numpy as np
import repro as wfa
from repro.solver import record_btcs
from repro.solver.api import solve
T0 = np.full((8, 8, 6), 500.0, np.float64); T0[1:-1, 1:-1, 0] = 300.0
T0[4, 4, 3] = np.inf
wse, T = record_btcs(T0, 0.1)
x, info = solve(wse.program, T, method="cg", tol=1e-10, maxiter=60,
                return_info=True, options=wfa.RunOptions(backend="jit"))
print(info.outcomes[0], np.all(np.isfinite(x)))
""", x64=True)
    assert out.split() == ["NAN_RESIDUAL", "False"]


def test_poisoned_solve_sharded_subprocess():
    """2x2 mesh: the in-loop guard word travels through the fused psum
    reductions; recovery declines sharded solves with a single-attempt
    trace instead of silently re-running."""
    out = run_py("""
import numpy as np
import repro as wfa
from repro.core.jaxcompat import make_mesh
from repro.solver import record_btcs, NumericalFault, RecoveryPolicy
from repro.solver.api import solve
mesh = make_mesh((2, 2), ("x", "y"))
T0 = np.full((8, 8, 6), 500.0, np.float32); T0[1:-1, 1:-1, 0] = 300.0
T0[4, 4, 3] = np.nan
wse, T = record_btcs(T0, 0.1)
x, info = solve(wse.program, T, method="cg", tol=1e-6, maxiter=60,
                return_info=True,
                options=wfa.RunOptions(backend="jit", mesh=mesh))
print(info.outcomes[0], np.all(np.isfinite(x)))
wse2, T2 = record_btcs(T0, 0.1)
try:
    solve(wse2.program, T2, method="cg", tol=1e-6, maxiter=60,
          options=wfa.RunOptions(backend="jit", mesh=mesh,
                                 recovery=RecoveryPolicy()))
    print("NO-RAISE")
except NumericalFault as e:
    print("FAULT", e.outcome, len(e.trace.attempts))
""", devices=4)
    lines = out.splitlines()
    assert lines[0].split() == ["NAN_RESIDUAL", "False"]
    assert lines[1].split() == ["FAULT", "NAN_RESIDUAL", "1"]


def test_batched_poison_isolated_per_member():
    """B=4 with one sick member: the poison is labeled on that member only
    and the healthy members' answers are bitwise identical to an
    all-healthy batch (masked freeze, no cross-member contamination)."""
    T0 = np.full((8, 8, 6), 500.0, np.float32)
    T0[1:-1, 1:-1, 0] = 300.0
    stack = np.broadcast_to(T0, (4,) + T0.shape).copy()
    stack[2, 4, 4, 3] = np.nan

    wse, T = record_btcs(T0, 0.1)
    xb, infob = solve(wse.program, T, method="cg", tol=1e-6, maxiter=300,
                      return_info=True, member_env={"T": stack},
                      options=wfa.RunOptions(backend="jit", batch=4))
    wse2, T2 = record_btcs(T0, 0.1)
    xr, infor = solve(wse2.program, T2, method="cg", tol=1e-6, maxiter=300,
                      return_info=True,
                      options=wfa.RunOptions(backend="jit", batch=4))

    outs = np.asarray(infob.outcomes).ravel().tolist()
    assert outs == ["CONVERGED", "CONVERGED", "NAN_RESIDUAL", "CONVERGED"]
    assert not np.all(np.isfinite(xb[2]))
    for i in (0, 1, 3):
        assert np.array_equal(xb[i], xr[i])
    # the sick member froze at detection, it did not spin to maxiter
    assert int(np.asarray(infob.iterations).ravel()[2]) == 0


# -- the recovery ladder ------------------------------------------------------


def overflow_T0(shape=(10, 10, 6)):
    """Amplitudes whose dots overflow fp32 (|b|^2 ~ 1e41·N > 3.4e38) but
    sit comfortably inside fp64 — the fp32 attempt NaNs, fp64 converges."""
    T0 = np.full(shape, 5.0e20, np.float32)
    T0[1:-1, 1:-1, 0] = 3.0e20
    return T0


def test_recovery_ladder_reaches_fp64():
    wse, T = record_btcs(overflow_T0(), 0.1)
    reset_stats()
    x, info = solve(wse.program, T, method="cg", tol=1e-6, maxiter=200,
                    return_info=True,
                    options=wfa.RunOptions(backend="jit",
                                           recovery=RecoveryPolicy()))
    trace = info.recovery
    assert trace is not None and trace.succeeded
    assert info.outcomes == ["CONVERGED"]
    assert x.dtype == np.float32 and np.all(np.isfinite(x))
    # the ladder is logged: fp32 cg -> fp32 bicgstab -> fp64 cg
    assert [a.method for a in trace.attempts] == ["cg", "bicgstab", "cg"]
    assert [a.dtype for a in trace.attempts] == [
        "float32", "float32", "float64"]
    assert [a.outcome for a in trace.attempts] == [
        "NAN_RESIDUAL", "NAN_RESIDUAL", "CONVERGED"]
    assert stats.recovery_attempts == 2
    assert stats.numerical_faults == 0


def test_recovery_exhausted_raises_with_trace():
    """NaN in the state survives every rung (restart, escalation, fp64):
    the ladder is bounded and terminates in a NumericalFault that carries
    the full attempt log."""
    wse, T = record_btcs(poisoned_T0(), 0.1)
    reset_stats()
    with pytest.raises(NumericalFault) as exc:
        solve(wse.program, T, method="cg", tol=1e-6, maxiter=60,
              options=wfa.RunOptions(backend="jit", recovery=RecoveryPolicy()))
    e = exc.value
    assert e.outcome == "NAN_RESIDUAL"
    assert len(e.trace.attempts) == 3  # initial + escalate + fp64
    assert not e.trace.succeeded
    assert stats.numerical_faults == 1
    assert "NAN_RESIDUAL" in stats.solve_outcomes


def test_recovery_policy_off_rungs():
    """Disarmed rungs stay disarmed: with everything off the first failure
    is terminal after exactly one attempt."""
    wse, T = record_btcs(poisoned_T0(), 0.1)
    pol = RecoveryPolicy(max_restarts=0, escalate=False, safe_mode_fp64=False)
    with pytest.raises(NumericalFault) as exc:
        solve(wse.program, T, method="cg", tol=1e-6, maxiter=60,
              options=wfa.RunOptions(backend="jit", recovery=pol))
    assert len(exc.value.trace.attempts) == 1


# -- explicit-path sentinels --------------------------------------------------


def test_guarded_run_bitwise_parity_and_amortized_probes():
    init = np.full((8, 8, 4), 1.0e-3, np.float32)
    w1, T1 = growth_program(32, init)
    ref = wfa.make(w1, T1, options=wfa.RunOptions(backend="jit"))
    reset_stats()
    w2, T2 = growth_program(32, init)
    out = wfa.make(w2, T2,
                   options=wfa.RunOptions(backend="jit", check_finite=8))
    assert np.array_equal(ref, out)  # the sentinel never touches the math
    # probes amortize at the chunk granule: entry + ~steps/every + final
    assert stats.health_probes <= 32 // 8 + 2
    assert stats.numerical_faults == 0


def test_guarded_run_trips_with_last_good_state():
    w, T = growth_program(32, np.full((8, 8, 4), 1.0e38, np.float32))
    reset_stats()
    with pytest.raises(NumericalFault) as exc:
        wfa.make(w, T, options=wfa.RunOptions(backend="jit", check_finite=4))
    e = exc.value
    assert e.step == 4  # first probe after the step-1 overflow
    assert e.last_good is not None
    assert np.all(np.isfinite(e.last_good["T"]))  # rollback point is clean
    assert stats.numerical_faults == 1


def test_guarded_run_poisoned_entry_faults_at_step_zero():
    bad = np.full((8, 8, 4), 1.0, np.float32)
    bad[2, 2, 2] = np.nan
    w, T = growth_program(8, bad)
    with pytest.raises(NumericalFault) as exc:
        wfa.make(w, T, options=wfa.RunOptions(backend="jit", check_finite=2))
    assert exc.value.step == 0
    assert exc.value.last_good is None  # nothing upstream was ever finite


def test_numpy_backend_sentinel():
    w, T = growth_program(32, np.full((8, 8, 4), 1.0e38, np.float32))
    with np.errstate(over="ignore"):
        with pytest.raises(NumericalFault) as exc:
            wfa.make(w, T, options=wfa.RunOptions(backend="numpy",
                                                  check_finite=4))
    assert exc.value.step == 4


def test_explicit_deescalation_retries_conservative_schedule():
    """An aggressive plan (time-tiled) that trips the sentinel is retried
    once at time_tile=1/overlap-off; a genuinely sick program still faults
    after the single bounded retry."""
    w, T = growth_program(32, np.full((8, 8, 4), 1.0e38, np.float32))
    reset_stats()
    with pytest.raises(NumericalFault):
        wfa.make(w, T, options=wfa.RunOptions(backend="pallas",
                                              check_finite=4, time_tile=4,
                                              recovery=RecoveryPolicy()))
    assert stats.recovery_attempts == 1
    # both the tiled attempt and the conservative retry probed and faulted
    assert stats.numerical_faults == 2


def test_guarded_pallas_tiled_parity():
    init = np.full((8, 8, 4), 1.0e-3, np.float32)
    w1, T1 = growth_program(16, init)
    ref = wfa.make(w1, T1, options=wfa.RunOptions(backend="pallas",
                                                  time_tile=4))
    w2, T2 = growth_program(16, init)
    out = wfa.make(w2, T2, options=wfa.RunOptions(backend="pallas",
                                                  time_tile=4,
                                                  check_finite=8))
    assert np.array_equal(ref, out)


# -- the committed overhead budget -------------------------------------------


def test_bench_health_budget():
    """The committed benchmark run stays inside the sentinel budget: ≤2 %
    per-step overhead at the default granule, zero interpreter fallbacks.
    (The live gate re-runs this on CI via ``run.py --check-health``.)"""
    import re

    path = os.path.join(ROOT, "BENCH_health.json")
    with open(path) as f:
        data = json.load(f)
    guarded = [r for r in data["rows"] if str(r["name"]).startswith("health_guard_on")]
    assert guarded, data["rows"]
    for row in data["rows"]:
        m = re.search(r"fallbacks=(\d+)", str(row["derived"]))
        assert m and int(m.group(1)) == 0, row
    for row in guarded:
        m = re.search(r"overhead_pct=(-?[\d.]+)", str(row["derived"]))
        assert m, row
        assert float(m.group(1)) <= 2.0, row

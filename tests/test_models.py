"""Per-arch smoke tests (reduced configs) + decode/prefill parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M


def _tokens(cfg, key, b, s):
    shape = (b, s) if cfg.n_codebooks == 1 else (b, s, cfg.n_codebooks)
    return jax.random.randint(key, shape, 1, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: shapes + no NaN."""
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    tokens = _tokens(cfg, key, 2, 16)

    logits, aux = M.forward(params, tokens, cfg)
    want = ((2, 16, cfg.vocab_size) if cfg.n_codebooks == 1
            else (2, 16, cfg.n_codebooks, cfg.vocab_size))
    assert logits.shape == want
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    from repro.launch.steps import make_opt_state, make_train_step
    step = jax.jit(make_train_step(cfg))
    opt = make_opt_state(params)
    batch = {"tokens": tokens, "labels": tokens}
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2.step) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode equals the training forward, token by token."""
    cfg = get_config(arch).smoke()
    if cfg.moe:   # avoid train-path capacity drops in the comparison
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    b, s, s0, s_max = 2, 12, 8, 16
    tokens = _tokens(cfg, key, b, s)
    full, _ = M.forward(params, tokens, cfg)

    logits_p, cache = M.prefill(params, tokens[:, :s0], cfg, s_max)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, s0 - 1]), atol=3e-4)
    for t in range(s0, s):
        lg, cache = M.decode_step(params, cache, tokens[:, t:t + 1], t, cfg)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]), atol=3e-4)


def test_flat_mode_matches_scan():
    """scan_layers=False (calibration mode) is numerically identical."""
    cfg = get_config("qwen3-0.6b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg, jax.random.PRNGKey(2), 2, 8)
    a, _ = M.forward(params, tokens, cfg)
    flat_cfg = dataclasses.replace(cfg, scan_layers=False)
    b, _ = M.forward(params, tokens, flat_cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_causality():
    """Future tokens must not influence current logits."""
    cfg = get_config("qwen3-0.6b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    t1 = _tokens(cfg, jax.random.PRNGKey(3), 1, 12)
    t2 = t1.at[:, 6:].set((t1[:, 6:] + 7) % cfg.vocab_size + 1)
    l1, _ = M.forward(params, t1, cfg)
    l2, _ = M.forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :6]), np.asarray(l2[:, :6]),
                               atol=2e-5)


def test_recurrent_causality():
    """Same property for the recurrent archs (rwkv, zamba2)."""
    for arch in ("rwkv6-7b", "zamba2-2.7b"):
        cfg = get_config(arch).smoke()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        t1 = _tokens(cfg, jax.random.PRNGKey(4), 1, 12)
        t2 = t1.at[:, 6:].set((t1[:, 6:] + 7) % cfg.vocab_size + 1)
        l1, _ = M.forward(params, t1, cfg)
        l2, _ = M.forward(params, t2, cfg)
        np.testing.assert_allclose(np.asarray(l1[:, :6]),
                                   np.asarray(l2[:, :6]), atol=2e-5,
                                   err_msg=arch)


def test_mla_absorbed_decode_matches_naive():
    """The absorbed (latent-space) MLA decode is numerically identical to
    the naive expand-K/V decode — the beyond-paper serving optimization."""
    cfg = get_config("minicpm3-4b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg, jax.random.PRNGKey(6), 2, 10)
    _, cache_a = M.prefill(params, tokens[:, :8], cfg, 12)
    _, cache_b = M.prefill(params, tokens[:, :8], cfg, 12)
    cfg_abs = dataclasses.replace(cfg, mla_absorbed=True)
    for t in (8, 9):
        la, cache_a = M.decode_step(params, cache_a, tokens[:, t:t + 1], t,
                                    cfg)
        lb, cache_b = M.decode_step(params, cache_b, tokens[:, t:t + 1], t,
                                    cfg_abs)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=5e-4)


def test_sliding_window_limits_context():
    """SWA mask at the attention primitive: one layer's output at position p
    is independent of K/V beyond the window (across the full model the
    receptive field legitimately stacks ~layers × window, so the isolation
    property must be asserted per layer)."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(0)
    b, s, kv, g, hd, w = 1, 16, 2, 2, 8, 8
    q = jnp.asarray(rng.normal(size=(b, s, kv, g, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    pos = jnp.arange(s)
    out1 = chunked_attention(q, k, v, pos, pos, window=w)
    # perturb K/V at positions 0..1 — outside position 15's window (8..15)
    k2 = k.at[:, :2].add(3.0)
    v2 = v.at[:, :2].add(3.0)
    out2 = chunked_attention(q, k2, v2, pos, pos, window=w)
    np.testing.assert_allclose(np.asarray(out1[:, 15]),
                               np.asarray(out2[:, 15]), atol=1e-6)
    # position 3 is inside the perturbed range: must change
    assert float(jnp.abs(out1[:, 3] - out2[:, 3]).max()) > 1e-3
    # and without a window, position 15 must change
    out3 = chunked_attention(q, k, v, pos, pos, window=None)
    out4 = chunked_attention(q, k2, v2, pos, pos, window=None)
    assert float(jnp.abs(out3[:, 15] - out4[:, 15]).max()) > 1e-4
